//! Filesystem startup-performance models — the substrate of the paper's
//! Fig 2 (`from mpi4py import MPI` wall time vs MPI ranks vs environment).
//!
//! Python's import machinery performs thousands of metadata operations
//! (stat/open along `sys.path`) plus tens of MB of shared-library reads.
//! At scale the dominant term is metadata-server contention: R concurrent
//! ranks hammer the same MDS. Container runtimes sidestep this by serving
//! the environment from a node-local squashfs image (page-cache hot after
//! the first rank), which is why the paper finds containers beating shared
//! filesystems at scale.
//!
//! Each environment is an [`FsPerfModel`] with documented parameters; the
//! six presets ([`Environment::all`]) are tuned so the *shape* of Fig 2
//! holds: monotone growth with ranks for shared filesystems, a knee at the
//! single-node→multi-node transition (128 ranks/node on Perlmutter CPU
//! nodes), container curves nearly flat, `shifter` best at scale,
//! `podman-hpc` comparable to the best shared filesystems.

pub mod dynlink;

pub use dynlink::{DynlinkWorkload, MPI4PY_IMPORT};

/// The environments of Fig 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Environment {
    /// `$HOME` (NFS-backed, low bandwidth, strict quotas).
    Home,
    /// `$SCRATCH` (Lustre: high bandwidth, contended MDS).
    Scratch,
    /// `/global/common/software` — the "NERSC module" path, a read-only
    /// filesystem mounted+cached for parallel library loading.
    CommonSw,
    /// CVMFS (HTTP-backed, aggressive node-local caching).
    Cvmfs,
    /// shifter container runtime (node-local squash, years of tuning).
    Shifter,
    /// podman-hpc container runtime (node-local squash, newer stack).
    PodmanHpc,
}

impl Environment {
    pub const fn label(&self) -> &'static str {
        match self {
            Environment::Home => "HOME",
            Environment::Scratch => "SCRATCH",
            Environment::CommonSw => "NERSC module",
            Environment::Cvmfs => "CVMFS",
            Environment::Shifter => "shifter",
            Environment::PodmanHpc => "podman-hpc",
        }
    }

    pub fn all() -> [Environment; 6] {
        [
            Environment::Home,
            Environment::Scratch,
            Environment::CommonSw,
            Environment::Cvmfs,
            Environment::Shifter,
            Environment::PodmanHpc,
        ]
    }

    /// The tuned performance model for this environment.
    pub fn model(&self) -> FsPerfModel {
        match self {
            // Shared filesystems: real metadata round-trips per rank, MDS
            // contention grows with total concurrent ranks.
            Environment::Home => FsPerfModel {
                meta_latency_us: 180.0,
                contention_per_rank_us: 14.0,
                contention_exponent: 1.15,
                bandwidth_mbs: 300.0,
                node_local_cache: false,
                multinode_penalty: 2.0,
            },
            Environment::Scratch => FsPerfModel {
                meta_latency_us: 90.0,
                contention_per_rank_us: 9.0,
                contention_exponent: 1.12,
                bandwidth_mbs: 4_000.0,
                node_local_cache: false,
                multinode_penalty: 1.8,
            },
            Environment::CommonSw => FsPerfModel {
                meta_latency_us: 40.0,
                contention_per_rank_us: 4.0,
                contention_exponent: 1.05,
                bandwidth_mbs: 6_000.0,
                node_local_cache: false,
                multinode_penalty: 1.4,
            },
            Environment::Cvmfs => FsPerfModel {
                meta_latency_us: 120.0,
                contention_per_rank_us: 2.0,
                contention_exponent: 1.0,
                bandwidth_mbs: 800.0,
                node_local_cache: true,
                multinode_penalty: 1.3,
            },
            Environment::Shifter => FsPerfModel {
                meta_latency_us: 8.0,
                contention_per_rank_us: 0.25,
                contention_exponent: 1.0,
                bandwidth_mbs: 9_000.0,
                node_local_cache: true,
                multinode_penalty: 1.05,
            },
            // "podman-hpc not having had the benefit of years of
            // performance optimization": squash architecture, but its
            // (2022-era) rootless runtime still pays per-rank setup against
            // shared infrastructure, so scaling tracks the optimized shared
            // filesystems rather than shifter ("comparable with the
            // highly-optimized file systems").
            Environment::PodmanHpc => FsPerfModel {
                meta_latency_us: 25.0,
                contention_per_rank_us: 6.0,
                contention_exponent: 1.05,
                bandwidth_mbs: 8_000.0,
                node_local_cache: false,
                multinode_penalty: 1.15,
            },
        }
    }

    /// Mean `from mpi4py import MPI` time at `ranks` total MPI ranks
    /// (seconds) for the standard workload and 128 ranks/node.
    pub fn import_time(&self, ranks: u32) -> f64 {
        self.model()
            .startup_time(&DynlinkWorkload::mpi4py_anaconda(), ranks, 128)
    }
}

/// Parameterized startup-performance model of one environment.
#[derive(Debug, Clone, PartialEq)]
pub struct FsPerfModel {
    /// Uncontended per-metadata-op latency (µs).
    pub meta_latency_us: f64,
    /// Added metadata latency per concurrent rank (µs) — MDS contention.
    pub contention_per_rank_us: f64,
    /// Super-linear contention exponent (lock convoys, RPC retries).
    pub contention_exponent: f64,
    /// Aggregate read bandwidth per node (MB/s).
    pub bandwidth_mbs: f64,
    /// Node-local cache (squash/CVMFS): only the first rank per node pays
    /// the metadata + read cost; the rest hit the page cache.
    pub node_local_cache: bool,
    /// Multiplier on metadata cost once the job spans >1 node (network
    /// fan-in at the shared service).
    pub multinode_penalty: f64,
}

impl FsPerfModel {
    /// Mean startup (import) time in seconds for `workload` at `ranks`
    /// total ranks with `ranks_per_node` packing.
    pub fn startup_time(&self, workload: &DynlinkWorkload, ranks: u32, ranks_per_node: u32) -> f64 {
        assert!(ranks >= 1 && ranks_per_node >= 1);
        let nodes = ranks.div_ceil(ranks_per_node);
        let multi = if nodes > 1 { self.multinode_penalty } else { 1.0 };

        // Effective clients hitting the backing store concurrently.
        let (meta_clients, read_clients) = if self.node_local_cache {
            // One warm-up per node; peers wait on the page cache (cheap).
            (nodes as f64, nodes as f64)
        } else {
            (ranks as f64, ranks as f64)
        };

        let meta_us = self.meta_latency_us
            + self.contention_per_rank_us * meta_clients.powf(self.contention_exponent);
        let meta_total_s = workload.meta_ops as f64 * meta_us * multi / 1e6;

        // Reads: backing bandwidth is shared by concurrent readers.
        let eff_bw = self.bandwidth_mbs / read_clients.max(1.0);
        let read_total_s = workload.read_mb / eff_bw;

        // Page-cache replay cost for cached environments (non-first ranks).
        let cache_replay_s = if self.node_local_cache {
            workload.meta_ops as f64 * 1.5 / 1e6 + workload.read_mb / 20_000.0
        } else {
            0.0
        };

        workload.cpu_seconds + meta_total_s + read_total_s + cache_replay_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const RANKS: [u32; 8] = [1, 4, 16, 64, 128, 192, 256, 512];

    #[test]
    fn shared_fs_monotone_in_ranks() {
        for env in [Environment::Home, Environment::Scratch, Environment::CommonSw] {
            let mut prev = 0.0;
            for r in RANKS {
                let t = env.import_time(r);
                assert!(t > prev, "{env:?} not monotone at {r} ranks");
                prev = t;
            }
        }
    }

    #[test]
    fn multinode_knee_at_128() {
        // "sudden rise in load time at 128 ranks corresponds to going from
        // single node to multiple nodes": the marginal increase across the
        // node boundary exceeds the one before it for shared filesystems.
        for env in [Environment::Home, Environment::Scratch] {
            let t64 = env.import_time(64);
            let t128 = env.import_time(128);
            let t192 = env.import_time(192);
            let before = t128 - t64;
            let after = t192 - t128;
            assert!(
                after > before,
                "{env:?}: no knee (before={before:.3}, after={after:.3})"
            );
        }
    }

    #[test]
    fn shifter_beats_everything_at_scale() {
        for r in [128, 256, 512] {
            let shifter = Environment::Shifter.import_time(r);
            for env in Environment::all() {
                if env != Environment::Shifter {
                    assert!(
                        shifter < env.import_time(r),
                        "shifter not fastest at {r} ranks vs {env:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn podman_comparable_to_optimized_fs_at_scale() {
        // "podman-hpc's performance at scale is comparable with the
        // highly-optimized file systems": within 2x of NERSC module, and
        // better than HOME/SCRATCH at 512 ranks.
        let r = 512;
        let podman = Environment::PodmanHpc.import_time(r);
        let common = Environment::CommonSw.import_time(r);
        assert!(podman < common * 2.0 && podman > common * 0.2,
            "podman {podman:.2}s vs common {common:.2}s not comparable");
        assert!(podman < Environment::Home.import_time(r));
        assert!(podman < Environment::Scratch.import_time(r));
    }

    #[test]
    fn containers_flat_shared_fs_steep() {
        let steep = Environment::Scratch.import_time(512) / Environment::Scratch.import_time(1);
        let flat = Environment::Shifter.import_time(512) / Environment::Shifter.import_time(1);
        assert!(steep > 10.0, "scratch should degrade a lot: {steep:.1}x");
        assert!(flat < 4.0, "shifter should stay nearly flat: {flat:.1}x");
    }

    #[test]
    fn single_rank_times_order_of_seconds() {
        for env in Environment::all() {
            let t = env.import_time(1);
            assert!((0.05..30.0).contains(&t), "{env:?}: {t}s implausible");
        }
    }
}
