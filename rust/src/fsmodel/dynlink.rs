//! Dynamic-linking workload descriptions.
//!
//! Characterizes what a process startup actually does to the filesystem:
//! metadata operations (`stat`/`open` probes along search paths) and bulk
//! shared-object reads. The numbers for the mpi4py/Anaconda benchmark are
//! from published import-tracing studies of conda environments on HPC
//! systems (thousands of path probes, tens of MB of .so text).

/// A startup workload: what importing/linking a stack costs.
#[derive(Debug, Clone, PartialEq)]
pub struct DynlinkWorkload {
    /// Human label.
    pub name: &'static str,
    /// Metadata operations (stat/open/readdir probes).
    pub meta_ops: u64,
    /// Bytes read (MB) — shared objects, bytecode, config.
    pub read_mb: f64,
    /// Pure-CPU interpreter/relocation time (seconds), environment
    /// independent.
    pub cpu_seconds: f64,
}

/// The Fig 2 benchmark: `from mpi4py import MPI` in an Anaconda env.
pub const MPI4PY_IMPORT: DynlinkWorkload = DynlinkWorkload {
    name: "from mpi4py import MPI (Anaconda)",
    meta_ops: 6_500,
    read_mb: 120.0,
    cpu_seconds: 0.35,
};

impl DynlinkWorkload {
    pub fn mpi4py_anaconda() -> Self {
        MPI4PY_IMPORT.clone()
    }

    /// A Geant4 application startup (larger shared-object footprint:
    /// physics data files + toolkit libraries).
    pub fn geant4_app() -> Self {
        Self {
            name: "Geant4 application startup",
            meta_ops: 9_000,
            read_mb: 450.0,
            cpu_seconds: 1.2,
        }
    }

    /// A lean statically-linked binary (the baseline that barely touches
    /// the filesystem — used in ablations).
    pub fn static_binary() -> Self {
        Self {
            name: "static binary",
            meta_ops: 40,
            read_mb: 15.0,
            cpu_seconds: 0.01,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_presets_sane() {
        let m = DynlinkWorkload::mpi4py_anaconda();
        assert!(m.meta_ops > 1_000);
        assert!(m.read_mb > 10.0);
        let g = DynlinkWorkload::geant4_app();
        assert!(g.meta_ops > m.meta_ops);
        let s = DynlinkWorkload::static_binary();
        assert!(s.meta_ops < 100);
    }
}
