//! The flight recorder: when a round fails, explain it from the ring.
//!
//! The global [`crate::trace::TraceSink`] ring survives a failed barrier
//! round (the records are in process memory, not on the failing path), so
//! any `Error` path can call [`dump_for_job`] to persist the job's last
//! spans plus the failure's who/where — the rank and barrier phase pulled
//! from the most recent [`crate::trace::names::PHASE_FAIL`] event. That is
//! invariant 11: a failed round is always explainable from its dump.
//! Dumps are JSON files named `flight-<job>-<seq>.json` in the job's
//! checkpoint directory; [`scan`] walks a workdir and summarizes them for
//! `nersc-cr trace` and the campaign report.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Error, Result};
use crate::trace::export::{esc, span_json};
use crate::trace::{installed, names, SpanRecord};

/// How many trailing spans of the failing job a dump keeps.
pub const DEFAULT_LAST_N: usize = 64;

static NEXT_DUMP: AtomicU64 = AtomicU64::new(0);

/// Replace filesystem-hostile characters in a job id.
fn sanitize(job: &str) -> String {
    job.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '.' || c == '_' || c == '-' {
                c
            } else {
                '_'
            }
        })
        .collect()
}

/// Pull `(rank, phase, all ranks)` from the `PHASE_FAIL` events in a
/// span slice: the most recent event names the headline rank/phase, and
/// every `PHASE_FAIL` of the *same round and phase* contributes to the
/// full victim set (a correlated failure — fabric partition, node kill —
/// fells several ranks in one round).
fn failure_coords(spans: &[SpanRecord]) -> (Option<u64>, Option<String>, Vec<u64>) {
    let latest = spans.iter().rev().find(|r| r.name == names::PHASE_FAIL);
    let Some(latest) = latest else {
        return (None, None, Vec::new());
    };
    let rank = latest.attr("rank").and_then(|v| v.parse::<u64>().ok());
    let phase = latest.attr("phase").map(|v| v.to_string());
    let round = latest.attr("round").map(|v| v.to_string());
    let mut ranks: Vec<u64> = spans
        .iter()
        .filter(|r| {
            r.name == names::PHASE_FAIL
                && r.attr("phase") == latest.attr("phase")
                && r.attr("round").map(|v| v.to_string()) == round
        })
        .filter_map(|r| r.attr("rank").and_then(|v| v.parse::<u64>().ok()))
        .collect();
    ranks.sort_unstable();
    ranks.dedup();
    (rank, phase, ranks)
}

/// Serialize one dump document. `domain` tags which fault domain the dump
/// blames (`None` infers: two or more failed ranks in one round is a
/// fabric-wide event, otherwise a single-victim session failure).
fn render(job: &str, reason: &str, spans: &[SpanRecord], domain: Option<&str>) -> String {
    let (rank, phase, ranks) = failure_coords(spans);
    let domain = domain.unwrap_or(if ranks.len() >= 2 { "fabric" } else { "session" });
    let mut out = String::from("{\"flight_dump\":1,");
    out.push_str(&format!("\"job\":\"{}\",", esc(job)));
    out.push_str(&format!("\"reason\":\"{}\",", esc(reason)));
    out.push_str(&format!("\"fault_domain\":\"{}\",", esc(domain)));
    match rank {
        Some(r) => out.push_str(&format!("\"failed_rank\":{r},")),
        None => out.push_str("\"failed_rank\":null,"),
    }
    match &phase {
        Some(p) => out.push_str(&format!("\"failed_phase\":\"{}\",", esc(p))),
        None => out.push_str("\"failed_phase\":null,"),
    }
    out.push_str("\"failed_ranks\":[");
    for (i, r) in ranks.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&r.to_string());
    }
    out.push_str("],");
    out.push_str(&format!("\"n_spans\":{},", spans.len()));
    out.push_str("\"spans\":[");
    for (i, rec) in spans.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push('\n');
        out.push_str(&span_json(rec));
    }
    out.push_str("\n]}\n");
    out
}

/// Dump the last [`DEFAULT_LAST_N`] spans recorded for `job` into `dir`
/// as `flight-<job>-<seq>.json`, tagged with `reason` and the failing
/// rank/phase from the latest `PHASE_FAIL` event. Returns the dump path,
/// or `None` when no sink is installed (tracing off — the default) or
/// the write failed (failure paths must stay failure-proof; the error is
/// logged, not propagated).
pub fn dump_for_job(job: &str, reason: &str, dir: &Path) -> Option<PathBuf> {
    dump_inner(job, reason, dir, None)
}

/// [`dump_for_job`] with an explicit fault domain tag (`node`, `store`,
/// `fabric`, `session`) instead of the inferred one — the correlated
/// fault injectors know which domain struck and say so in the dump.
pub fn dump_for_job_in_domain(
    job: &str,
    reason: &str,
    dir: &Path,
    domain: &str,
) -> Option<PathBuf> {
    dump_inner(job, reason, dir, Some(domain))
}

fn dump_inner(job: &str, reason: &str, dir: &Path, domain: Option<&str>) -> Option<PathBuf> {
    let sink = installed()?;
    let spans = sink.snapshot_job(job, DEFAULT_LAST_N);
    let doc = render(job, reason, &spans, domain);
    let seq = NEXT_DUMP.fetch_add(1, Ordering::Relaxed);
    let path = dir.join(format!("flight-{}-{}.json", sanitize(job), seq));
    let tmp = dir.join(format!(".flight-{}-{}.json.tmp", sanitize(job), seq));
    let write = std::fs::create_dir_all(dir)
        .and_then(|()| std::fs::write(&tmp, doc.as_bytes()))
        .and_then(|()| std::fs::rename(&tmp, &path));
    match write {
        Ok(()) => {
            crate::trace::event(names::FLIGHT_DUMP, |a| {
                a.str("job", job.to_string());
                a.str("path", path.display().to_string());
            });
            log::warn!("flight recorder: dumped {} spans to {}", spans.len(), path.display());
            Some(path)
        }
        Err(e) => {
            log::warn!("flight recorder: dump to {} failed: {e}", path.display());
            None
        }
    }
}

/// Summary of one dump file, as [`scan`] reads it back.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FlightSummary {
    /// Where the dump lives.
    pub path: PathBuf,
    /// The job that failed.
    pub job: String,
    /// The error that triggered the dump.
    pub reason: String,
    /// The rank the latest `PHASE_FAIL` named, if any.
    pub failed_rank: Option<u64>,
    /// The barrier phase the latest `PHASE_FAIL` named, if any.
    pub failed_phase: Option<String>,
    /// Every distinct rank that failed in the same round/phase as the
    /// latest `PHASE_FAIL` (sorted) — more than one means a correlated
    /// multi-victim event.
    pub failed_ranks: Vec<u64>,
    /// Which fault domain the dump blames (`session`, `node`, `store`,
    /// `fabric`); absent in pre-domain dumps.
    pub fault_domain: Option<String>,
    /// Spans held in the dump.
    pub n_spans: usize,
}

/// Un-escape a JSON string body (the subset [`esc`] emits).
fn unescape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('r') => out.push('\r'),
            Some('t') => out.push('\t'),
            Some('u') => {
                let hex: String = chars.by_ref().take(4).collect();
                if let Some(u) = u32::from_str_radix(&hex, 16).ok().and_then(char::from_u32) {
                    out.push(u);
                }
            }
            Some(other) => out.push(other),
            None => {}
        }
    }
    out
}

/// Extract the raw (still-escaped) body of the first `"key":"..."` field.
fn string_field(doc: &str, key: &str) -> Option<String> {
    let marker = format!("\"{key}\":\"");
    let start = doc.find(&marker)? + marker.len();
    let rest = &doc[start..];
    let mut end = None;
    let mut escaped = false;
    for (i, c) in rest.char_indices() {
        if escaped {
            escaped = false;
        } else if c == '\\' {
            escaped = true;
        } else if c == '"' {
            end = Some(i);
            break;
        }
    }
    Some(unescape(&rest[..end?]))
}

/// Extract the first `"key":<number>` field (`None` for `null`).
fn number_field(doc: &str, key: &str) -> Option<u64> {
    let marker = format!("\"{key}\":");
    let start = doc.find(&marker)? + marker.len();
    let digits: String = doc[start..]
        .chars()
        .take_while(|c| c.is_ascii_digit())
        .collect();
    digits.parse().ok()
}

/// Extract the first `"key":[n, n, ...]` number-array field (empty when
/// the key is absent — pre-domain dumps have no `failed_ranks`).
fn number_array_field(doc: &str, key: &str) -> Vec<u64> {
    let marker = format!("\"{key}\":[");
    let Some(start) = doc.find(&marker).map(|i| i + marker.len()) else {
        return Vec::new();
    };
    let Some(end) = doc[start..].find(']') else {
        return Vec::new();
    };
    doc[start..start + end]
        .split(',')
        .filter_map(|s| s.trim().parse::<u64>().ok())
        .collect()
}

/// Read one dump file back into a summary.
pub fn read_summary(path: &Path) -> Result<FlightSummary> {
    let doc = std::fs::read_to_string(path)?;
    if !doc.starts_with("{\"flight_dump\":1,") {
        return Err(Error::Manifest(format!(
            "{}: not a flight-recorder dump",
            path.display()
        )));
    }
    Ok(FlightSummary {
        path: path.to_path_buf(),
        job: string_field(&doc, "job")
            .ok_or_else(|| Error::Manifest(format!("{}: dump has no job", path.display())))?,
        reason: string_field(&doc, "reason").unwrap_or_default(),
        failed_rank: number_field(&doc, "failed_rank"),
        failed_phase: string_field(&doc, "failed_phase"),
        failed_ranks: number_array_field(&doc, "failed_ranks"),
        fault_domain: string_field(&doc, "fault_domain"),
        n_spans: number_field(&doc, "n_spans").unwrap_or(0) as usize,
    })
}

/// Recursively collect every `flight-*.json` dump under `root`, sorted by
/// path. Unreadable or malformed files are skipped (a torn dump must not
/// hide the others).
pub fn scan(root: &Path) -> Vec<FlightSummary> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let Ok(entries) = std::fs::read_dir(&dir) else {
            continue;
        };
        for entry in entries.flatten() {
            let path = entry.path();
            if path.is_dir() {
                stack.push(path);
            } else if path
                .file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("flight-") && n.ends_with(".json"))
            {
                if let Ok(s) = read_summary(&path) {
                    out.push(s);
                }
            }
        }
    }
    out.sort_by(|a, b| a.path.cmp(&b.path));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::SpanRecord;

    fn fail_rec(rank: u64, phase: &str) -> SpanRecord {
        SpanRecord {
            id: 1,
            name: names::PHASE_FAIL,
            start_us: 5,
            dur_us: 0,
            instant: true,
            tid: 1,
            attrs: vec![
                ("job", "j1".into()),
                ("rank", rank.to_string()),
                ("phase", phase.into()),
            ],
        }
    }

    #[test]
    fn render_and_read_back_round_trips() {
        let spans = vec![fail_rec(2, "Drain")];
        let doc = render("j\"1", "barrier failed: \"why\"", &spans, None);
        let dir = std::env::temp_dir().join(format!("ncr_flight_rt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("flight-j1-0.json");
        std::fs::write(&path, &doc).unwrap();
        let s = read_summary(&path).unwrap();
        assert_eq!(s.job, "j\"1");
        assert_eq!(s.reason, "barrier failed: \"why\"");
        assert_eq!(s.failed_rank, Some(2));
        assert_eq!(s.failed_phase.as_deref(), Some("Drain"));
        assert_eq!(s.failed_ranks, vec![2]);
        assert_eq!(s.fault_domain.as_deref(), Some("session"));
        assert_eq!(s.n_spans, 1);
        let found = scan(&dir);
        assert_eq!(found.len(), 1);
        assert_eq!(found[0], s);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn no_phase_fail_means_null_coords() {
        let doc = render("j2", "teardown", &[], None);
        assert!(doc.contains("\"failed_rank\":null"));
        assert!(doc.contains("\"failed_phase\":null"));
        assert!(doc.contains("\"failed_ranks\":[]"));
        let dir = std::env::temp_dir().join(format!("ncr_flight_null_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("flight-j2-0.json");
        std::fs::write(&path, &doc).unwrap();
        let s = read_summary(&path).unwrap();
        assert_eq!(s.failed_rank, None);
        assert_eq!(s.failed_phase, None);
        assert!(s.failed_ranks.is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn correlated_failures_name_every_rank_and_the_domain() {
        // Three ranks of one round fail the same phase; an older failure
        // from a different phase must not leak into the victim set.
        let mut old = fail_rec(7, "Suspend");
        old.attrs.push(("round", "3".into()));
        let mut spans = vec![old];
        for r in [3, 1, 3] {
            let mut rec = fail_rec(r, "Drain");
            rec.attrs.push(("round", "4".into()));
            spans.push(rec);
        }
        let doc = render("g1", "fabric partition", &spans, None);
        assert!(doc.contains("\"failed_ranks\":[1,3]"), "{doc}");
        assert!(doc.contains("\"fault_domain\":\"fabric\""), "{doc}");
        // An explicit domain wins over the inferred one.
        let doc = render("g1", "node kill", &spans, Some("node"));
        assert!(doc.contains("\"fault_domain\":\"node\""), "{doc}");
        let dir = std::env::temp_dir().join(format!("ncr_flight_corr_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("flight-g1-0.json");
        std::fs::write(&path, &doc).unwrap();
        let s = read_summary(&path).unwrap();
        assert_eq!(s.failed_ranks, vec![1, 3]);
        assert_eq!(s.fault_domain.as_deref(), Some("node"));
        assert_eq!(s.failed_phase.as_deref(), Some("Drain"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn scan_skips_garbage() {
        let dir = std::env::temp_dir().join(format!("ncr_flight_garbage_{}", std::process::id()));
        std::fs::create_dir_all(dir.join("sub")).unwrap();
        std::fs::write(dir.join("flight-bad-0.json"), b"not a dump").unwrap();
        std::fs::write(
            dir.join("sub").join("flight-ok-1.json"),
            render("ok", "r", &[], None),
        )
        .unwrap();
        std::fs::write(dir.join("other.json"), b"{}").unwrap();
        let found = scan(&dir);
        assert_eq!(found.len(), 1);
        assert_eq!(found[0].job, "ok");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn dump_without_sink_is_none() {
        // Tracing may be installed by sibling tests in this binary; only
        // assert the no-sink behavior when nothing is installed.
        if crate::trace::installed().is_none() {
            assert_eq!(dump_for_job("j", "r", Path::new("/nonexistent")), None);
        }
    }
}
