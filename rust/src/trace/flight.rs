//! The flight recorder: when a round fails, explain it from the ring.
//!
//! The global [`crate::trace::TraceSink`] ring survives a failed barrier
//! round (the records are in process memory, not on the failing path), so
//! any `Error` path can call [`dump_for_job`] to persist the job's last
//! spans plus the failure's who/where — the rank and barrier phase pulled
//! from the most recent [`crate::trace::names::PHASE_FAIL`] event. That is
//! invariant 11: a failed round is always explainable from its dump.
//! Dumps are JSON files named `flight-<job>-<seq>.json` in the job's
//! checkpoint directory; [`scan`] walks a workdir and summarizes them for
//! `nersc-cr trace` and the campaign report.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Error, Result};
use crate::trace::export::{esc, span_json};
use crate::trace::{installed, names, SpanRecord};

/// How many trailing spans of the failing job a dump keeps.
pub const DEFAULT_LAST_N: usize = 64;

static NEXT_DUMP: AtomicU64 = AtomicU64::new(0);

/// Replace filesystem-hostile characters in a job id.
fn sanitize(job: &str) -> String {
    job.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '.' || c == '_' || c == '-' {
                c
            } else {
                '_'
            }
        })
        .collect()
}

/// Pull `(rank, phase)` from the most recent `PHASE_FAIL` event in a
/// span slice.
fn failure_coords(spans: &[SpanRecord]) -> (Option<u64>, Option<String>) {
    for rec in spans.iter().rev() {
        if rec.name == names::PHASE_FAIL {
            let rank = rec.attr("rank").and_then(|v| v.parse::<u64>().ok());
            let phase = rec.attr("phase").map(|v| v.to_string());
            return (rank, phase);
        }
    }
    (None, None)
}

/// Serialize one dump document.
fn render(job: &str, reason: &str, spans: &[SpanRecord]) -> String {
    let (rank, phase) = failure_coords(spans);
    let mut out = String::from("{\"flight_dump\":1,");
    out.push_str(&format!("\"job\":\"{}\",", esc(job)));
    out.push_str(&format!("\"reason\":\"{}\",", esc(reason)));
    match rank {
        Some(r) => out.push_str(&format!("\"failed_rank\":{r},")),
        None => out.push_str("\"failed_rank\":null,"),
    }
    match &phase {
        Some(p) => out.push_str(&format!("\"failed_phase\":\"{}\",", esc(p))),
        None => out.push_str("\"failed_phase\":null,"),
    }
    out.push_str(&format!("\"n_spans\":{},", spans.len()));
    out.push_str("\"spans\":[");
    for (i, rec) in spans.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push('\n');
        out.push_str(&span_json(rec));
    }
    out.push_str("\n]}\n");
    out
}

/// Dump the last [`DEFAULT_LAST_N`] spans recorded for `job` into `dir`
/// as `flight-<job>-<seq>.json`, tagged with `reason` and the failing
/// rank/phase from the latest `PHASE_FAIL` event. Returns the dump path,
/// or `None` when no sink is installed (tracing off — the default) or
/// the write failed (failure paths must stay failure-proof; the error is
/// logged, not propagated).
pub fn dump_for_job(job: &str, reason: &str, dir: &Path) -> Option<PathBuf> {
    let sink = installed()?;
    let spans = sink.snapshot_job(job, DEFAULT_LAST_N);
    let doc = render(job, reason, &spans);
    let seq = NEXT_DUMP.fetch_add(1, Ordering::Relaxed);
    let path = dir.join(format!("flight-{}-{}.json", sanitize(job), seq));
    let tmp = dir.join(format!(".flight-{}-{}.json.tmp", sanitize(job), seq));
    let write = std::fs::create_dir_all(dir)
        .and_then(|()| std::fs::write(&tmp, doc.as_bytes()))
        .and_then(|()| std::fs::rename(&tmp, &path));
    match write {
        Ok(()) => {
            crate::trace::event(names::FLIGHT_DUMP, |a| {
                a.str("job", job.to_string());
                a.str("path", path.display().to_string());
            });
            log::warn!("flight recorder: dumped {} spans to {}", spans.len(), path.display());
            Some(path)
        }
        Err(e) => {
            log::warn!("flight recorder: dump to {} failed: {e}", path.display());
            None
        }
    }
}

/// Summary of one dump file, as [`scan`] reads it back.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FlightSummary {
    /// Where the dump lives.
    pub path: PathBuf,
    /// The job that failed.
    pub job: String,
    /// The error that triggered the dump.
    pub reason: String,
    /// The rank the latest `PHASE_FAIL` named, if any.
    pub failed_rank: Option<u64>,
    /// The barrier phase the latest `PHASE_FAIL` named, if any.
    pub failed_phase: Option<String>,
    /// Spans held in the dump.
    pub n_spans: usize,
}

/// Un-escape a JSON string body (the subset [`esc`] emits).
fn unescape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('r') => out.push('\r'),
            Some('t') => out.push('\t'),
            Some('u') => {
                let hex: String = chars.by_ref().take(4).collect();
                if let Some(u) = u32::from_str_radix(&hex, 16).ok().and_then(char::from_u32) {
                    out.push(u);
                }
            }
            Some(other) => out.push(other),
            None => {}
        }
    }
    out
}

/// Extract the raw (still-escaped) body of the first `"key":"..."` field.
fn string_field(doc: &str, key: &str) -> Option<String> {
    let marker = format!("\"{key}\":\"");
    let start = doc.find(&marker)? + marker.len();
    let rest = &doc[start..];
    let mut end = None;
    let mut escaped = false;
    for (i, c) in rest.char_indices() {
        if escaped {
            escaped = false;
        } else if c == '\\' {
            escaped = true;
        } else if c == '"' {
            end = Some(i);
            break;
        }
    }
    Some(unescape(&rest[..end?]))
}

/// Extract the first `"key":<number>` field (`None` for `null`).
fn number_field(doc: &str, key: &str) -> Option<u64> {
    let marker = format!("\"{key}\":");
    let start = doc.find(&marker)? + marker.len();
    let digits: String = doc[start..]
        .chars()
        .take_while(|c| c.is_ascii_digit())
        .collect();
    digits.parse().ok()
}

/// Read one dump file back into a summary.
pub fn read_summary(path: &Path) -> Result<FlightSummary> {
    let doc = std::fs::read_to_string(path)?;
    if !doc.starts_with("{\"flight_dump\":1,") {
        return Err(Error::Manifest(format!(
            "{}: not a flight-recorder dump",
            path.display()
        )));
    }
    Ok(FlightSummary {
        path: path.to_path_buf(),
        job: string_field(&doc, "job")
            .ok_or_else(|| Error::Manifest(format!("{}: dump has no job", path.display())))?,
        reason: string_field(&doc, "reason").unwrap_or_default(),
        failed_rank: number_field(&doc, "failed_rank"),
        failed_phase: string_field(&doc, "failed_phase"),
        n_spans: number_field(&doc, "n_spans").unwrap_or(0) as usize,
    })
}

/// Recursively collect every `flight-*.json` dump under `root`, sorted by
/// path. Unreadable or malformed files are skipped (a torn dump must not
/// hide the others).
pub fn scan(root: &Path) -> Vec<FlightSummary> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let Ok(entries) = std::fs::read_dir(&dir) else {
            continue;
        };
        for entry in entries.flatten() {
            let path = entry.path();
            if path.is_dir() {
                stack.push(path);
            } else if path
                .file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("flight-") && n.ends_with(".json"))
            {
                if let Ok(s) = read_summary(&path) {
                    out.push(s);
                }
            }
        }
    }
    out.sort_by(|a, b| a.path.cmp(&b.path));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::SpanRecord;

    fn fail_rec(rank: u64, phase: &str) -> SpanRecord {
        SpanRecord {
            id: 1,
            name: names::PHASE_FAIL,
            start_us: 5,
            dur_us: 0,
            instant: true,
            tid: 1,
            attrs: vec![
                ("job", "j1".into()),
                ("rank", rank.to_string()),
                ("phase", phase.into()),
            ],
        }
    }

    #[test]
    fn render_and_read_back_round_trips() {
        let spans = vec![fail_rec(2, "Drain")];
        let doc = render("j\"1", "barrier failed: \"why\"", &spans);
        let dir = std::env::temp_dir().join(format!("ncr_flight_rt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("flight-j1-0.json");
        std::fs::write(&path, &doc).unwrap();
        let s = read_summary(&path).unwrap();
        assert_eq!(s.job, "j\"1");
        assert_eq!(s.reason, "barrier failed: \"why\"");
        assert_eq!(s.failed_rank, Some(2));
        assert_eq!(s.failed_phase.as_deref(), Some("Drain"));
        assert_eq!(s.n_spans, 1);
        let found = scan(&dir);
        assert_eq!(found.len(), 1);
        assert_eq!(found[0], s);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn no_phase_fail_means_null_coords() {
        let doc = render("j2", "teardown", &[]);
        assert!(doc.contains("\"failed_rank\":null"));
        assert!(doc.contains("\"failed_phase\":null"));
        let dir = std::env::temp_dir().join(format!("ncr_flight_null_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("flight-j2-0.json");
        std::fs::write(&path, &doc).unwrap();
        let s = read_summary(&path).unwrap();
        assert_eq!(s.failed_rank, None);
        assert_eq!(s.failed_phase, None);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn scan_skips_garbage() {
        let dir = std::env::temp_dir().join(format!("ncr_flight_garbage_{}", std::process::id()));
        std::fs::create_dir_all(dir.join("sub")).unwrap();
        std::fs::write(dir.join("flight-bad-0.json"), b"not a dump").unwrap();
        std::fs::write(dir.join("sub").join("flight-ok-1.json"), render("ok", "r", &[])).unwrap();
        std::fs::write(dir.join("other.json"), b"{}").unwrap();
        let found = scan(&dir);
        assert_eq!(found.len(), 1);
        assert_eq!(found[0].job, "ok");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn dump_without_sink_is_none() {
        // Tracing may be installed by sibling tests in this binary; only
        // assert the no-sink behavior when nothing is installed.
        if crate::trace::installed().is_none() {
            assert_eq!(dump_for_job("j", "r", Path::new("/nonexistent")), None);
        }
    }
}
