//! The span-name registry. Every span or event name used anywhere in the
//! crate is a constant here, and every constant is listed in [`ALL`] — CI
//! lints both directions, so the five instrumented modules (daemon/
//! coordinator, store, ckpt_thread/restart, sessions, scheduler) cannot
//! drift into stringly-typed names.

/// One five-phase barrier round, daemon side (attrs: `job`, `round`,
/// `ranks`).
pub const BARRIER_ROUND: &str = "barrier.round";
/// One phase of a barrier round, daemon side (attrs: `job`, `round`,
/// `phase`, `clients`).
pub const BARRIER_PHASE: &str = "barrier.phase";
/// A barrier participant died or stalled (attrs: `job`, `rank`, `phase`,
/// `error`) — the event the flight recorder pivots on (invariant 11).
pub const PHASE_FAIL: &str = "barrier.phase_fail";

/// Client-side handling of one barrier phase in the checkpoint thread
/// (attrs: `job`, `rank`, `phase`).
pub const CLIENT_PHASE: &str = "client.phase";
/// Client-side checkpoint image write (attrs: `job`, `rank`, `bytes`).
pub const IMAGE_WRITE: &str = "client.image_write";

/// `Coordinator::checkpoint_all` — one whole checkpoint round as the
/// session sees it (attrs: `job`).
pub const COORD_CHECKPOINT: &str = "coordinator.checkpoint";
/// `Coordinator::checkpoint_gang` — one all-or-nothing gang round
/// (attrs: `job`, `ranks`).
pub const COORD_CHECKPOINT_GANG: &str = "coordinator.checkpoint_gang";

/// Store write of one image (attrs: `chunks_written`, `chunks_deduped`,
/// `stored_bytes`, `logical_bytes`).
pub const STORE_WRITE: &str = "store.write";
/// Chunk compress + publish fan-out inside a store write (attrs:
/// `chunks`).
pub const STORE_COMPRESS: &str = "store.compress";
/// Whole restore-assembly of a v2 image (attrs: `chunks`, `bytes`).
pub const STORE_RESTORE: &str = "store.restore";
/// Chunk-read phase of a restore, from [`crate::dmtcp::store::RestoreStats`]
/// (attrs: `chunks`).
pub const STORE_READ: &str = "store.read";
/// Decompress phase of a restore (attrs: `chunks`).
pub const STORE_DECOMPRESS: &str = "store.decompress";
/// CRC-verify phase of a restore (attrs: `chunks`).
pub const STORE_VERIFY: &str = "store.verify";

/// `dmtcp_restart` reconstructing a process from an image (attrs: `name`,
/// `vpid`, `generation`).
pub const RESTART_IMAGE: &str = "restart.image";

/// Session launch, first incarnation (attrs: `job`).
pub const SESSION_LAUNCH: &str = "session.launch";
/// One session-level checkpoint (attrs: `job`).
pub const SESSION_CHECKPOINT: &str = "session.checkpoint";
/// A session kill — injected fault or operator action (attrs: `job`).
pub const SESSION_KILL: &str = "session.kill";
/// A session restart from its latest image (attrs: `job`, `generation`).
pub const SESSION_RESTART: &str = "session.restart";
/// Fig 3 auto-workflow state transition (attrs: `job`, `state`).
pub const AUTO_STATE: &str = "session.auto_state";

/// Gang launch of all ranks (attrs: `job`, `ranks`).
pub const GANG_LAUNCH: &str = "gang.launch";
/// One gang checkpoint: barrier + manifest commit (attrs: `job`,
/// `ranks`).
pub const GANG_CHECKPOINT: &str = "gang.checkpoint";
/// A gang rank kill (attrs: `job`, `rank`).
pub const GANG_KILL: &str = "gang.kill_rank";
/// Gang restart of every rank from a consistent cut (attrs: `job`,
/// `ranks`).
pub const GANG_RESTART: &str = "gang.restart";

/// Admission control accepted an arrival (attrs: `session`).
pub const SCHED_ADMIT: &str = "sched.admit";
/// Admission control turned an arrival away (attrs: `session`,
/// `reason`).
pub const SCHED_REJECT: &str = "sched.reject";
/// The scheduler dispatched a queued request to a worker slot (attrs:
/// `session`, `policy`, `queue_wait_secs`).
pub const SCHED_DISPATCH: &str = "sched.dispatch";
/// A preemption notice fired and the executor is deciding/running the
/// final-checkpoint override (attrs: `session`).
pub const SCHED_PREEMPT_NOTICE: &str = "sched.preempt_notice";

/// A `log` facade record forwarded by [`crate::logging`] (attrs: `level`,
/// `target`, `msg`).
pub const LOG_EVENT: &str = "log.event";
/// A flight-recorder dump was written (attrs: `job`, `path`).
pub const FLIGHT_DUMP: &str = "flight.dump";

/// A node-scoped kill event felled every session/rank co-located on one
/// simulated node (attrs: `node`, `session`).
pub const NODE_KILL: &str = "fault.node.kill";
/// A fabric partition made a subset of a gang's ranks unreachable
/// mid-barrier (attrs: `job`, `ranks`, `phase`, `round`).
pub const FAULT_PARTITION: &str = "fault.fabric.partition";
/// The fleet-scale corruptor damaged a chunk file in a shared store
/// (attrs: `chunk`, `kind`).
pub const FAULT_CORRUPT: &str = "fault.store.corrupt";
/// The campaign clock read before its own epoch (pre-epoch skew); the
/// executor fell back to a zero offset (attrs: `context`).
pub const CLOCK_SKEW: &str = "campaign.clock.skew";

/// Every span name, in one table. CI asserts (a) every `names::X` usage
/// in the crate resolves to a constant defined here and (b) every
/// constant defined here appears in this list.
pub const ALL: &[&str] = &[
    BARRIER_ROUND,
    BARRIER_PHASE,
    PHASE_FAIL,
    CLIENT_PHASE,
    IMAGE_WRITE,
    COORD_CHECKPOINT,
    COORD_CHECKPOINT_GANG,
    STORE_WRITE,
    STORE_COMPRESS,
    STORE_RESTORE,
    STORE_READ,
    STORE_DECOMPRESS,
    STORE_VERIFY,
    RESTART_IMAGE,
    SESSION_LAUNCH,
    SESSION_CHECKPOINT,
    SESSION_KILL,
    SESSION_RESTART,
    AUTO_STATE,
    GANG_LAUNCH,
    GANG_CHECKPOINT,
    GANG_KILL,
    GANG_RESTART,
    SCHED_ADMIT,
    SCHED_REJECT,
    SCHED_DISPATCH,
    SCHED_PREEMPT_NOTICE,
    LOG_EVENT,
    FLIGHT_DUMP,
    NODE_KILL,
    FAULT_PARTITION,
    FAULT_CORRUPT,
    CLOCK_SKEW,
];

#[cfg(test)]
mod tests {
    use super::ALL;

    #[test]
    fn registry_has_no_duplicates() {
        let mut sorted: Vec<&str> = ALL.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), ALL.len(), "duplicate span name in ALL");
    }

    #[test]
    fn names_are_dotted_lowercase() {
        for n in ALL {
            assert!(
                n.contains('.')
                    && n.chars().all(|c| c.is_ascii_lowercase() || c == '.' || c == '_'),
                "bad span name {n:?}"
            );
        }
    }
}
