//! Chrome-trace (catapult) JSON export.
//!
//! [`chrome_json`] renders spans in the trace-event format
//! `chrome://tracing` / Perfetto load directly: an object with a
//! `traceEvents` array of complete (`"ph":"X"`) and instant (`"ph":"i"`)
//! events, microsecond timestamps. [`validate_chrome_json`] is the
//! matching structural checker (no JSON dependency in the offline
//! closure): it walks the document with a string-and-escape-aware scanner
//! and returns the event count, so round-trip tests and the CLI can
//! prove an export is well-formed.

use crate::error::{Error, Result};
use crate::trace::SpanRecord;

/// JSON-escape a string value (quotes, backslashes, control bytes).
pub fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render one span as a catapult trace event object.
pub fn span_json(rec: &SpanRecord) -> String {
    let mut args = String::new();
    for (i, (k, v)) in rec.attrs.iter().enumerate() {
        if i > 0 {
            args.push(',');
        }
        args.push_str(&format!("\"{}\":\"{}\"", esc(k), esc(v)));
    }
    if rec.instant {
        format!(
            "{{\"name\":\"{}\",\"cat\":\"cr\",\"ph\":\"i\",\"s\":\"t\",\"ts\":{},\"pid\":1,\"tid\":{},\"id\":\"{:016x}\",\"args\":{{{}}}}}",
            esc(rec.name),
            rec.start_us,
            rec.tid,
            rec.id,
            args
        )
    } else {
        format!(
            "{{\"name\":\"{}\",\"cat\":\"cr\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":1,\"tid\":{},\"id\":\"{:016x}\",\"args\":{{{}}}}}",
            esc(rec.name),
            rec.start_us,
            rec.dur_us,
            rec.tid,
            rec.id,
            args
        )
    }
}

/// Render spans as a complete Chrome-trace JSON document.
pub fn chrome_json(spans: &[SpanRecord]) -> String {
    let mut out = String::from("{\"traceEvents\":[");
    for (i, rec) in spans.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push('\n');
        out.push_str(&span_json(rec));
    }
    out.push_str("\n],\"displayTimeUnit\":\"ms\"}\n");
    out
}

/// Structurally validate a Chrome-trace document and return how many
/// trace events it holds. Checks: the document is one object whose first
/// key is `traceEvents` with an array value, every event in the array is
/// an object, strings escape correctly, and all brackets balance. This is
/// deliberately a scanner, not a parser — enough to prove the exporter
/// (or a flight dump embedding the same event shape) emitted well-formed
/// JSON without pulling a JSON crate into the offline closure.
pub fn validate_chrome_json(doc: &str) -> Result<usize> {
    let s = doc.trim_start();
    let prefix = "{\"traceEvents\":[";
    if !s.starts_with(prefix) {
        return Err(Error::Manifest(
            "chrome trace: document must start with {\"traceEvents\":[".into(),
        ));
    }
    let mut events = 0usize;
    let mut depth = 0i64; // brace/bracket depth across the whole document
    let mut in_string = false;
    let mut escaped = false;
    let mut array_depth: Option<i64> = None; // depth of the traceEvents array
    let mut chars = s.char_indices();
    while let Some((i, c)) = chars.next() {
        if in_string {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_string = false;
            }
            continue;
        }
        match c {
            '"' => in_string = true,
            '{' => {
                depth += 1;
                // An object opening directly inside the traceEvents array
                // is one event.
                if array_depth == Some(depth - 1) {
                    events += 1;
                }
            }
            '[' => {
                depth += 1;
                if i + 1 == prefix.len() {
                    array_depth = Some(depth);
                }
            }
            '}' | ']' => {
                depth -= 1;
                if depth < 0 {
                    return Err(Error::Manifest(format!(
                        "chrome trace: unbalanced close at byte {i}"
                    )));
                }
                if c == ']' && array_depth == Some(depth + 1) {
                    array_depth = None;
                }
            }
            _ => {}
        }
    }
    if in_string {
        return Err(Error::Manifest("chrome trace: unterminated string".into()));
    }
    if depth != 0 {
        return Err(Error::Manifest(format!(
            "chrome trace: {depth} unclosed brackets"
        )));
    }
    Ok(events)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(name: &'static str, instant: bool) -> SpanRecord {
        SpanRecord {
            id: 7,
            name,
            start_us: 10,
            dur_us: if instant { 0 } else { 25 },
            instant,
            tid: 3,
            attrs: vec![("job", "j\"quoted\"".to_string()), ("rank", "2".to_string())],
        }
    }

    #[test]
    fn export_validates_and_counts() {
        let spans = vec![
            rec(crate::trace::names::BARRIER_PHASE, false),
            rec(crate::trace::names::PHASE_FAIL, true),
        ];
        let doc = chrome_json(&spans);
        assert_eq!(validate_chrome_json(&doc).unwrap(), 2);
        assert!(doc.contains("\"ph\":\"X\""));
        assert!(doc.contains("\"ph\":\"i\""));
        assert!(doc.contains("j\\\"quoted\\\""));
    }

    #[test]
    fn empty_export_is_valid() {
        let doc = chrome_json(&[]);
        assert_eq!(validate_chrome_json(&doc).unwrap(), 0);
    }

    #[test]
    fn validator_rejects_damage() {
        let doc = chrome_json(&[rec(crate::trace::names::STORE_WRITE, false)]);
        assert!(validate_chrome_json(&doc[..doc.len() - 4]).is_err());
        assert!(validate_chrome_json("[1,2,3]").is_err());
        assert!(validate_chrome_json("{\"traceEvents\":[{\"name\":\"x]}").is_err());
    }

    #[test]
    fn escapes_control_bytes() {
        assert_eq!(esc("a\nb\t\"\\"), "a\\nb\\t\\\"\\\\");
        assert_eq!(esc("\u{1}"), "\\u0001");
    }
}
