//! Structured tracing across the C/R stack (DESIGN §14).
//!
//! One zero-dependency span layer gives every subsystem the same eyes the
//! paper's LDMS pipeline gave Fig 4: the five-phase gang barrier
//! ([`crate::dmtcp::daemon`], [`crate::dmtcp::coordinator`]), the store
//! hot path ([`crate::dmtcp::store`]), session lifecycle
//! ([`crate::cr::session`], [`crate::cr::gang`]), and scheduler decisions
//! ([`crate::campaign`]). Three pieces:
//!
//! * the global [`TraceSink`] — sharded, bounded in-memory span rings with
//!   seeded ids and a monotonic microsecond clock. Installed once per
//!   process ([`install`]); when no sink is installed (the default) every
//!   instrumentation point reduces to **one relaxed atomic load and no
//!   allocation** — the disabled fast path the `trace_overhead` bench
//!   gates at ≤2% wall-clock delta.
//! * RAII [`SpanGuard`]s ([`span`]) and instant events ([`event`]) carrying
//!   `(&'static str, String)` attributes. Span names are constants from
//!   [`names`] — CI lints that every name used anywhere is registered in
//!   [`names::ALL`], so the five instrumented modules cannot drift.
//! * consumers: the [`flight`] recorder (the ring survives a failed round;
//!   a dump names the failing rank and barrier phase — invariant 11) and
//!   the [`export`] Chrome-trace (catapult) JSON exporter.

pub mod export;
pub mod flight;
pub mod names;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Number of independent ring shards in a [`TraceSink`]. Writers on
/// different threads land on different shards (by thread id), so the
/// enabled path takes one short uncontended lock per record.
pub const N_SHARDS: usize = 8;

/// Configuration for [`install`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceConfig {
    /// Seed for span-id generation. Ids are `splitmix64(seed ^ seq)` over
    /// a global sequence counter: unique for the life of the sink (the
    /// mix is a bijection) and reproducible for a fixed seed and
    /// allocation order.
    pub seed: u64,
    /// Total ring capacity in records, split evenly across [`N_SHARDS`]
    /// shards. When a shard fills, its oldest record is evicted (and
    /// counted in [`TraceSink::dropped`]) — memory stays bounded no
    /// matter how long the process traces.
    pub capacity: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            seed: 0x5eed_7ace,
            capacity: 4096,
        }
    }
}

/// One recorded span or instant event.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpanRecord {
    /// Seeded unique id.
    pub id: u64,
    /// Span name — always a constant from [`names`].
    pub name: &'static str,
    /// Microseconds since the sink was installed (monotonic clock).
    pub start_us: u64,
    /// Duration in microseconds; `0` for instant events.
    pub dur_us: u64,
    /// `true` for instant events ([`event`]), `false` for spans.
    pub instant: bool,
    /// Small dense per-process thread id (allocation order, not the OS
    /// tid) — stable for the life of the thread.
    pub tid: u64,
    /// Attributes, in the order they were attached.
    pub attrs: Vec<(&'static str, String)>,
}

impl SpanRecord {
    /// The value of attribute `key`, if attached.
    pub fn attr(&self, key: &str) -> Option<&str> {
        self.attrs
            .iter()
            .find(|(k, _)| *k == key)
            .map(|(_, v)| v.as_str())
    }
}

/// The bounded, sharded span sink. One per process, installed with
/// [`install`]; benches and tests hold the returned [`Arc`] to drain or
/// snapshot what the instrumentation recorded.
pub struct TraceSink {
    epoch: Instant,
    seed: u64,
    next_seq: AtomicU64,
    shard_cap: usize,
    shards: Vec<Mutex<VecDeque<SpanRecord>>>,
    dropped: AtomicU64,
}

impl TraceSink {
    fn new(cfg: TraceConfig) -> Self {
        let shard_cap = (cfg.capacity / N_SHARDS).max(1);
        TraceSink {
            epoch: Instant::now(),
            seed: cfg.seed,
            next_seq: AtomicU64::new(0),
            shard_cap,
            shards: (0..N_SHARDS)
                .map(|_| Mutex::new(VecDeque::with_capacity(0)))
                .collect(),
            dropped: AtomicU64::new(0),
        }
    }

    /// Microseconds since install (the span clock).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    fn next_id(&self) -> u64 {
        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        splitmix64(self.seed ^ seq)
    }

    fn push(&self, rec: SpanRecord) {
        let shard = &self.shards[(rec.tid as usize) % N_SHARDS];
        let mut q = shard.lock().expect("trace shard poisoned");
        if q.len() >= self.shard_cap {
            q.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        q.push_back(rec);
    }

    /// Records currently held across all shards.
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().expect("trace shard poisoned").len())
            .sum()
    }

    /// `true` when no records are held.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total record capacity (`len()` can never exceed this).
    pub fn capacity(&self) -> usize {
        self.shard_cap * N_SHARDS
    }

    /// Records evicted because their shard was full.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Rough heap footprint of the held records (record size plus
    /// attribute string bytes) — the bound the `trace_overhead` bench
    /// checks against the configured capacity.
    pub fn approx_bytes(&self) -> usize {
        let mut total = 0usize;
        for shard in &self.shards {
            let q = shard.lock().expect("trace shard poisoned");
            for rec in q.iter() {
                total += std::mem::size_of::<SpanRecord>();
                for (_, v) in &rec.attrs {
                    total += std::mem::size_of::<(&str, String)>() + v.len();
                }
            }
        }
        total
    }

    /// Copy every held record, sorted by `(start_us, id)`.
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        let mut out = Vec::new();
        for shard in &self.shards {
            out.extend(shard.lock().expect("trace shard poisoned").iter().cloned());
        }
        out.sort_by(|a, b| (a.start_us, a.id).cmp(&(b.start_us, b.id)));
        out
    }

    /// The last `last_n` records whose `job` attribute equals `job`,
    /// oldest first — the flight-recorder view of one job's recent
    /// history.
    pub fn snapshot_job(&self, job: &str, last_n: usize) -> Vec<SpanRecord> {
        let mut out: Vec<SpanRecord> = self
            .snapshot()
            .into_iter()
            .filter(|r| r.attr("job") == Some(job))
            .collect();
        let excess = out.len().saturating_sub(last_n);
        out.drain(..excess);
        out
    }

    /// Remove and return every held record, sorted by `(start_us, id)`.
    pub fn drain(&self) -> Vec<SpanRecord> {
        let mut out = Vec::new();
        for shard in &self.shards {
            out.extend(shard.lock().expect("trace shard poisoned").drain(..));
        }
        out.sort_by(|a, b| (a.start_us, a.id).cmp(&(b.start_us, b.id)));
        out
    }
}

/// `splitmix64` mix — a bijection on `u64`, so distinct inputs give
/// distinct span ids.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

// The disabled fast path is this one atomic: no sink lock, no allocation.
static ENABLED: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Option<Arc<TraceSink>>> = Mutex::new(None);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

fn tid() -> u64 {
    TID.with(|t| *t)
}

/// Install the global sink (idempotent: a second install returns the
/// already-installed sink unchanged) and enable recording. Returns the
/// sink so the caller can drain/snapshot it later.
pub fn install(cfg: TraceConfig) -> Arc<TraceSink> {
    let mut slot = SINK.lock().expect("trace sink slot poisoned");
    if let Some(sink) = slot.as_ref() {
        ENABLED.store(true, Ordering::SeqCst);
        return Arc::clone(sink);
    }
    let sink = Arc::new(TraceSink::new(cfg));
    *slot = Some(Arc::clone(&sink));
    ENABLED.store(true, Ordering::SeqCst);
    sink
}

/// Remove the global sink and disable recording. Existing [`Arc`]s from
/// [`install`] keep their records.
pub fn uninstall() {
    ENABLED.store(false, Ordering::SeqCst);
    *SINK.lock().expect("trace sink slot poisoned") = None;
}

/// Toggle recording without uninstalling the sink — the
/// installed-but-disabled mode the overhead bench measures.
pub fn set_enabled(on: bool) {
    let slot = SINK.lock().expect("trace sink slot poisoned");
    if slot.is_some() {
        ENABLED.store(on, Ordering::SeqCst);
    }
}

/// `true` when a sink is installed and recording — the hot-path check.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// The installed sink, if any (recording or not).
pub fn installed() -> Option<Arc<TraceSink>> {
    SINK.lock().expect("trace sink slot poisoned").clone()
}

/// Growable attribute list handed to [`event`] fill closures.
pub struct Attrs(Vec<(&'static str, String)>);

impl Attrs {
    /// Attach a string attribute.
    pub fn str(&mut self, key: &'static str, val: impl Into<String>) {
        self.0.push((key, val.into()));
    }

    /// Attach an integer attribute.
    pub fn u64(&mut self, key: &'static str, val: u64) {
        self.0.push((key, val.to_string()));
    }

    /// Attach a float attribute (6 decimal places).
    pub fn f64(&mut self, key: &'static str, val: f64) {
        self.0.push((key, format!("{val:.6}")));
    }
}

/// Record an instant event. `fill` runs only when recording is enabled —
/// attribute formatting costs nothing on the disabled path.
pub fn event(name: &'static str, fill: impl FnOnce(&mut Attrs)) {
    if !enabled() {
        return;
    }
    let Some(sink) = installed() else { return };
    let mut attrs = Attrs(Vec::new());
    fill(&mut attrs);
    let rec = SpanRecord {
        id: sink.next_id(),
        name,
        start_us: sink.now_us(),
        dur_us: 0,
        instant: true,
        tid: tid(),
        attrs: attrs.0,
    };
    sink.push(rec);
}

/// Record an already-measured span ending now (duration `dur`): the store
/// restore pipeline reports its read/decompress/verify phases this way,
/// from the same [`crate::dmtcp::store::RestoreStats`] it returns.
pub fn closed_span(name: &'static str, dur: Duration, fill: impl FnOnce(&mut Attrs)) {
    if !enabled() {
        return;
    }
    let Some(sink) = installed() else { return };
    let mut attrs = Attrs(Vec::new());
    fill(&mut attrs);
    let dur_us = dur.as_micros() as u64;
    let rec = SpanRecord {
        id: sink.next_id(),
        name,
        start_us: sink.now_us().saturating_sub(dur_us),
        dur_us,
        instant: false,
        tid: tid(),
        attrs: attrs.0,
    };
    sink.push(rec);
}

struct ActiveSpan {
    sink: Arc<TraceSink>,
    id: u64,
    name: &'static str,
    start: Instant,
    start_us: u64,
    attrs: Vec<(&'static str, String)>,
}

/// RAII span: records itself (with its wall duration) when dropped. When
/// tracing is disabled the guard is inert — constructing and dropping it
/// is the atomic check and nothing else.
#[must_use = "a span measures the scope it is bound to; binding to _ drops it immediately"]
pub struct SpanGuard(Option<ActiveSpan>);

impl SpanGuard {
    /// `true` when this guard is recording (sink installed and enabled at
    /// construction).
    pub fn is_active(&self) -> bool {
        self.0.is_some()
    }

    /// Builder-style string attribute; the closure runs only when active.
    pub fn with(mut self, key: &'static str, f: impl FnOnce() -> String) -> Self {
        if let Some(a) = &mut self.0 {
            a.attrs.push((key, f()));
        }
        self
    }

    /// Builder-style integer attribute.
    pub fn with_u64(mut self, key: &'static str, val: u64) -> Self {
        if let Some(a) = &mut self.0 {
            a.attrs.push((key, val.to_string()));
        }
        self
    }

    /// Builder-style float attribute (6 decimal places).
    pub fn with_f64(mut self, key: &'static str, val: f64) -> Self {
        if let Some(a) = &mut self.0 {
            a.attrs.push((key, format!("{val:.6}")));
        }
        self
    }

    /// Attach a string attribute mid-span; the closure runs only when
    /// active.
    pub fn note(&mut self, key: &'static str, f: impl FnOnce() -> String) {
        if let Some(a) = &mut self.0 {
            a.attrs.push((key, f()));
        }
    }

    /// Attach an integer attribute mid-span.
    pub fn note_u64(&mut self, key: &'static str, val: u64) {
        if let Some(a) = &mut self.0 {
            a.attrs.push((key, val.to_string()));
        }
    }

    /// Mark the span failed with an error message attribute.
    pub fn fail(&mut self, err: &str) {
        if let Some(a) = &mut self.0 {
            a.attrs.push(("error", err.to_string()));
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(a) = self.0.take() {
            let dur_us = a.start.elapsed().as_micros() as u64;
            a.sink.push(SpanRecord {
                id: a.id,
                name: a.name,
                start_us: a.start_us,
                dur_us,
                instant: false,
                tid: tid(),
                attrs: a.attrs,
            });
        }
    }
}

/// Open a span; it records itself when the returned guard drops. `name`
/// must be a constant from [`names`] (CI-linted).
pub fn span(name: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard(None);
    }
    let Some(sink) = installed() else {
        return SpanGuard(None);
    };
    let id = sink.next_id();
    let start_us = sink.now_us();
    SpanGuard(Some(ActiveSpan {
        sink,
        id,
        name,
        start: Instant::now(),
        start_us,
        attrs: Vec::new(),
    }))
}

/// Forward a `log` record into the sink as an instant event (the
/// [`crate::logging`] backend calls this for every emitted record when a
/// sink is recording).
pub fn log_event(level: &'static str, target: &str, msg: &str) {
    event(names::LOG_EVENT, |a| {
        a.str("level", level);
        a.str("target", target.to_string());
        a.str("msg", msg.to_string());
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    // The global sink is process-wide; every test that installs shares it,
    // so these tests use the returned Arc and never uninstall (other test
    // binaries run with tracing off, exercising the disabled path).
    fn sink() -> Arc<TraceSink> {
        install(TraceConfig {
            seed: 42,
            capacity: 256,
        })
    }

    #[test]
    fn disabled_span_is_inert_and_enable_records() {
        let s = sink();
        set_enabled(false);
        {
            let _g = span(names::SESSION_LAUNCH).with_u64("x", 1);
            event(names::LOG_EVENT, |a| a.u64("y", 2));
        }
        let before = s.len();
        set_enabled(true);
        {
            let mut g = span(names::SESSION_LAUNCH).with_u64("x", 1);
            g.note_u64("z", 3);
        }
        let after = s.snapshot();
        assert!(after.len() > before);
        let rec = after
            .iter()
            .rev()
            .find(|r| r.name == names::SESSION_LAUNCH)
            .expect("span recorded");
        assert_eq!(rec.attr("x"), Some("1"));
        assert_eq!(rec.attr("z"), Some("3"));
        assert!(!rec.instant);
    }

    #[test]
    fn ids_unique_and_ring_bounded() {
        let s = sink();
        set_enabled(true);
        for i in 0..s.capacity() * 2 {
            event(names::SCHED_DISPATCH, |a| a.u64("i", i as u64));
        }
        assert!(s.len() <= s.capacity());
        assert!(s.dropped() > 0);
        let snap = s.snapshot();
        let mut ids: Vec<u64> = snap.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), snap.len(), "span ids must never collide");
    }

    #[test]
    fn job_snapshot_filters_and_caps() {
        let s = sink();
        set_enabled(true);
        for i in 0..10u64 {
            event(names::BARRIER_PHASE, |a| {
                a.str("job", "jobA");
                a.u64("i", i);
            });
            event(names::BARRIER_PHASE, |a| {
                a.str("job", "jobB");
                a.u64("i", i);
            });
        }
        let recent = s.snapshot_job("jobA", 4);
        assert_eq!(recent.len(), 4);
        assert!(recent.iter().all(|r| r.attr("job") == Some("jobA")));
        // Oldest-first, and the cap keeps the most recent records.
        assert_eq!(recent.last().unwrap().attr("i"), Some("9"));
    }

    #[test]
    fn closed_span_backdates_start() {
        let s = sink();
        set_enabled(true);
        closed_span(names::STORE_VERIFY, Duration::from_micros(1500), |a| {
            a.u64("chunks", 3)
        });
        let snap = s.snapshot();
        let rec = snap
            .iter()
            .rev()
            .find(|r| r.name == names::STORE_VERIFY)
            .unwrap();
        assert_eq!(rec.dur_us, 1500);
        assert!(rec.start_us + rec.dur_us <= s.now_us() + 1);
    }

    #[test]
    fn splitmix_is_injective_on_a_range() {
        let mut seen: Vec<u64> = (0..10_000u64).map(splitmix64).collect();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 10_000);
    }
}
