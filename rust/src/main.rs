//! `nersc-cr` binary entrypoint.
fn main() {
    nersc_cr::logging::init();
    if let Err(e) = nersc_cr::cli::run(std::env::args().skip(1).collect()) {
        eprintln!("nersc-cr: {e}");
        std::process::exit(2);
    }
}
