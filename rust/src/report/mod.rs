//! Report emitters shared by benches and examples: aligned tables, CSV,
//! bench-smoke scaling and the JSON metric emitter CI archives.

/// A simple aligned-column table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
            }
            line.trim_end().to_string()
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .header
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// True when `BENCH_SMOKE` is set (and not `0`): benches run at a tiny
/// scale so CI can execute every bench on every push — a perf-report
/// *code* regression (panic, shape violation, broken emitter) cannot land
/// silently even though smoke timings themselves are meaningless.
pub fn bench_smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v != "0").unwrap_or(false)
}

/// `full` normally, `smoke` under `BENCH_SMOKE=1`.
pub fn smoke_scaled(full: usize, smoke: usize) -> usize {
    if bench_smoke() {
        smoke
    } else {
        full
    }
}

/// Write a bench's headline metrics as JSON to
/// `$BENCH_JSON_DIR/<bench>.json` (default `target/bench-json/`), for the
/// CI artifact upload. Non-finite values serialize as `null`.
pub fn emit_bench_json(
    bench: &str,
    metrics: &[(&str, f64)],
) -> std::io::Result<std::path::PathBuf> {
    let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| "target/bench-json".into());
    write_bench_json(std::path::Path::new(&dir), bench, metrics)
}

/// [`emit_bench_json`] with an explicit output directory (the env-free
/// core, also what the unit test drives).
fn write_bench_json(
    dir: &std::path::Path,
    bench: &str,
    metrics: &[(&str, f64)],
) -> std::io::Result<std::path::PathBuf> {
    std::fs::create_dir_all(dir)?;
    let esc = |s: &str| s.replace('\\', "\\\\").replace('"', "\\\"");
    let mut json = format!(
        "{{\n  \"bench\": \"{}\",\n  \"smoke\": {},\n  \"metrics\": {{\n",
        esc(bench),
        bench_smoke()
    );
    for (i, (k, v)) in metrics.iter().enumerate() {
        let val = if v.is_finite() {
            format!("{v}")
        } else {
            "null".into()
        };
        let comma = if i + 1 == metrics.len() { "" } else { "," };
        json.push_str(&format!("    \"{}\": {val}{comma}\n", esc(k)));
    }
    json.push_str("  }\n}\n");
    let path = dir.join(format!("{bench}.json"));
    std::fs::write(&path, json)?;
    Ok(path)
}

/// Format bytes human-readably.
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_render_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer-name".into(), "22".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[2].starts_with("a          "));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["x,y".into(), "q\"uote".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"uote\""));
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn bench_json_shape() {
        let dir = std::env::temp_dir().join(format!("ncr_benchjson_{}", std::process::id()));
        let p = write_bench_json(&dir, "unit_test", &[("a", 1.5), ("b", f64::NAN)]).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.contains("\"bench\": \"unit_test\""), "{s}");
        assert!(s.contains("\"a\": 1.5"), "{s}");
        assert!(s.contains("\"b\": null"), "{s}");
        assert!(!s.contains("NaN"), "{s}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
