//! The distributed gang workload: an N-rank halo-exchange stencil.
//!
//! This is the multi-rank harness the gang C/R layer is exercised by —
//! the moral equivalent of the paper's MPI applications under MANA. `N`
//! ranks each own a slab of a 1-D ring of `u64` cells; every step each
//! rank sends its two boundary cells to its neighbors over the in-process
//! [`Fabric`] and cannot advance until both neighbor halos for the current
//! step have arrived. All arithmetic is wrapping-integer, so a gang run is
//! bit-reproducible and `checkpoint → kill → gang restart → completion` can
//! be compared bit-for-bit against an uninterrupted reference.
//!
//! The C/R-relevant design points:
//!
//! * **In-flight messages are real.** A halo sent but not yet consumed
//!   lives in the receiver's fabric inbox. During the DRAIN phase (all
//!   ranks suspended) the [`HaloDrainPlugin`] moves every undelivered
//!   message into the receiver's checkpointable state
//!   ([`StencilState::pending_halos`]), making the per-rank image set a
//!   consistent cut of the computation. Workers consume state-held halos
//!   before polling the fabric, so REFILL needs no rewind.
//! * **The fabric is lower-half state.** Endpoint tables are minted per
//!   incarnation ([`Fabric::endpoint_blob`]) and exposed as a
//!   [`crate::dmtcp::mana::LIB_PREFIX`] segment: MANA-style exclusion
//!   drops them from images, and the MANA `reinit` hook rebuilds them on
//!   restart — restored endpoints would dangle either way.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::dmtcp::mana::LIB_PREFIX;
use crate::dmtcp::plugin::{Event, Plugin, PluginCtx};
use crate::dmtcp::process::{Checkpointable, GateVerdict, WorkerCtx};
use crate::error::{Error, Result};
use crate::util::bytes::{ByteReader, PutBytes};
use crate::util::rng::SplitMix64;

/// The workload label (process names, campaign specs, CLI).
pub const STENCIL_LABEL: &str = "halo-stencil";

/// Which boundary of the *receiver* a halo value feeds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Side {
    /// The receiver's left boundary (value comes from its left neighbor).
    Left = 0,
    /// The receiver's right boundary.
    Right = 1,
}

impl Side {
    fn from_u8(v: u8) -> Result<Self> {
        match v {
            0 => Ok(Side::Left),
            1 => Ok(Side::Right),
            _ => Err(Error::Image(format!("bad halo side {v}"))),
        }
    }
}

/// One halo message: the sender's boundary cell at the start of `step`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HaloMsg {
    /// The step this halo belongs to.
    pub step: u64,
    /// Sending rank (diagnostics; delivery is keyed by `(step, side)`).
    pub from: u32,
    /// Which boundary of the receiver it feeds.
    pub side: Side,
    /// The boundary cell value.
    pub value: u64,
}

/// Incarnation-scoped boot nonce source: two fabrics never share endpoint
/// tables, even at the same generation (two sessions, one process).
static FABRIC_NONCE: AtomicU64 = AtomicU64::new(1);

/// The in-process communication plane of one gang incarnation: one inbox
/// per rank, plus the incarnation-scoped endpoint tables (the lower half).
/// Rebuilt from scratch at every (re)start — nothing in it survives an
/// incarnation, which is exactly why it must not be checkpointed.
pub struct Fabric {
    n_ranks: u32,
    generation: u32,
    boot_nonce: u64,
    endpoint_bytes: usize,
    inboxes: Vec<Mutex<VecDeque<HaloMsg>>>,
}

impl Fabric {
    /// A fresh fabric for `n_ranks` ranks at restart generation
    /// `generation`, with `endpoint_bytes` of synthetic endpoint table per
    /// rank (the MPI-library/transport-cache stand-in MANA excludes).
    pub fn new(n_ranks: u32, generation: u32, endpoint_bytes: usize) -> Self {
        Self {
            n_ranks,
            generation,
            boot_nonce: FABRIC_NONCE.fetch_add(1, Ordering::Relaxed),
            endpoint_bytes,
            inboxes: (0..n_ranks).map(|_| Mutex::new(VecDeque::new())).collect(),
        }
    }

    /// Ranks this fabric connects.
    pub fn n_ranks(&self) -> u32 {
        self.n_ranks
    }

    /// Deliver `msg` into rank `to`'s inbox (never blocks, never drops).
    pub fn send(&self, to: u32, msg: HaloMsg) {
        self.inboxes[to as usize]
            .lock()
            .expect("fabric inbox poisoned")
            .push_back(msg);
    }

    /// Pop the oldest undelivered message for `rank`, if any.
    pub fn try_recv(&self, rank: u32) -> Option<HaloMsg> {
        self.inboxes[rank as usize]
            .lock()
            .expect("fabric inbox poisoned")
            .pop_front()
    }

    /// Undelivered messages currently queued for `rank` (tests/metrics).
    pub fn inbox_len(&self, rank: u32) -> usize {
        self.inboxes[rank as usize]
            .lock()
            .expect("fabric inbox poisoned")
            .len()
    }

    /// Rank `rank`'s endpoint table for *this* incarnation: deterministic
    /// in `(generation, boot nonce, rank)`, so it differs across restarts
    /// — a restored copy is recognizably stale.
    pub fn endpoint_blob(&self, rank: u32) -> Vec<u8> {
        let mut rng = SplitMix64::new(
            (self.generation as u64) ^ self.boot_nonce.rotate_left(17) ^ ((rank as u64) << 40),
        );
        (0..self.endpoint_bytes).map(|_| rng.next_u32() as u8).collect()
    }
}

/// One rank's checkpointable state.
#[derive(Debug, Clone, PartialEq)]
pub struct StencilState {
    /// This rank's position.
    pub rank: u32,
    /// Gang width.
    pub n_ranks: u32,
    /// The rank's slab of the ring.
    pub cells: Vec<u64>,
    /// Steps completed.
    pub step: u64,
    /// Steps to run in total.
    pub target_steps: u64,
    /// Whether this rank's halos for the in-progress step were sent.
    pub halos_sent: bool,
    /// Halos received (or drained) but not yet consumed, keyed by
    /// `(step, side)` — delivery order cannot matter.
    pub pending_halos: BTreeMap<(u64, u8), u64>,
    /// Lower half: the incarnation-scoped endpoint table copy, exposed as
    /// a `lib:` segment (excluded under MANA, rebuilt by `reinit`).
    pub endpoints: Vec<u8>,
}

/// Seed-derived initial cell value.
fn initial_cell(seed: u64, rank: u32, i: usize) -> u64 {
    let mut rng = SplitMix64::new(seed ^ ((rank as u64) << 32) ^ (i as u64).rotate_left(11));
    rng.next_u64()
}

/// The stencil update: deterministic wrapping mix of the left/center/right
/// values plus the step index (so there are no fixed points).
fn stencil_mix(l: u64, c: u64, r: u64, step: u64) -> u64 {
    l.rotate_left(7)
        .wrapping_add(c.wrapping_mul(0x9E37_79B9_7F4A_7C15))
        ^ r.rotate_right(13).wrapping_add(step)
}

impl StencilState {
    /// A fresh rank state at step 0.
    pub fn fresh(rank: u32, n_ranks: u32, cells_per_rank: usize, target_steps: u64, seed: u64) -> Self {
        Self {
            rank,
            n_ranks,
            cells: (0..cells_per_rank).map(|i| initial_cell(seed, rank, i)).collect(),
            step: 0,
            target_steps,
            halos_sent: false,
            pending_halos: BTreeMap::new(),
            endpoints: Vec::new(),
        }
    }

    /// An empty shell for `dmtcp_restart` to restore into.
    pub fn shell(rank: u32, n_ranks: u32) -> Self {
        Self {
            rank,
            n_ranks,
            cells: Vec::new(),
            step: 0,
            target_steps: 0,
            halos_sent: false,
            pending_halos: BTreeMap::new(),
            endpoints: Vec::new(),
        }
    }

    /// Left neighbor on the ring.
    pub fn left(&self) -> u32 {
        (self.rank + self.n_ranks - 1) % self.n_ranks
    }

    /// Right neighbor on the ring.
    pub fn right(&self) -> u32 {
        (self.rank + 1) % self.n_ranks
    }

    /// Whether the rank reached its target.
    pub fn done(&self) -> bool {
        self.step >= self.target_steps
    }

    /// Apply one stencil step given both halo values for the current step.
    fn advance(&mut self, left_halo: u64, right_halo: u64) {
        let prev = self.cells.clone();
        let n = prev.len();
        for i in 0..n {
            let l = if i == 0 { left_halo } else { prev[i - 1] };
            let r = if i + 1 == n { right_halo } else { prev[i + 1] };
            self.cells[i] = stencil_mix(l, prev[i], r, self.step);
        }
        self.step += 1;
        self.halos_sent = false;
    }

    /// Digest of the upper-half (science) state, for bit-identity checks
    /// that must not depend on the incarnation-scoped lower half.
    pub fn science_digest(&self) -> u64 {
        let mut h = self.step ^ ((self.rank as u64) << 48);
        for &c in &self.cells {
            h = stencil_mix(h, c, h.rotate_left(31), 0x5EED);
        }
        h
    }
}

impl Checkpointable for StencilState {
    fn segments(&self) -> Vec<(String, Vec<u8>)> {
        let mut cells = Vec::with_capacity(self.cells.len() * 8);
        for c in &self.cells {
            cells.extend_from_slice(&c.to_le_bytes());
        }
        let mut meta = Vec::new();
        meta.put_u32(self.rank);
        meta.put_u32(self.n_ranks);
        meta.put_u64(self.step);
        meta.put_u64(self.target_steps);
        meta.put_u8(self.halos_sent as u8);
        let mut halos = Vec::new();
        halos.put_u32(self.pending_halos.len() as u32);
        for (&(step, side), &value) in &self.pending_halos {
            halos.put_u64(step);
            halos.put_u8(side);
            halos.put_u64(value);
        }
        vec![
            ("cells".into(), cells),
            ("meta".into(), meta),
            ("halos".into(), halos),
            (format!("{LIB_PREFIX}endpoints"), self.endpoints.clone()),
        ]
    }

    fn restore(&mut self, segments: &[(String, Vec<u8>)]) -> Result<()> {
        let lib_endpoints = format!("{LIB_PREFIX}endpoints");
        let mut saw_meta = false;
        for (name, data) in segments {
            match name.as_str() {
                "cells" => {
                    if data.len() % 8 != 0 {
                        return Err(Error::Image(format!(
                            "stencil cells segment length {} not /8",
                            data.len()
                        )));
                    }
                    self.cells = data
                        .chunks_exact(8)
                        .map(|c| u64::from_le_bytes(c.try_into().expect("8-byte chunk")))
                        .collect();
                }
                "meta" => {
                    let mut r = ByteReader::new(data);
                    let rank = r.get_u32()?;
                    if rank != self.rank {
                        return Err(Error::Image(format!(
                            "stencil image is for rank {rank}, restoring shell is rank {}",
                            self.rank
                        )));
                    }
                    let n_ranks = r.get_u32()?;
                    if n_ranks != self.n_ranks {
                        return Err(Error::Image(format!(
                            "stencil image is for a {n_ranks}-rank gang, shell expects {} \
                             (gang restart preserves rank count)",
                            self.n_ranks
                        )));
                    }
                    self.step = r.get_u64()?;
                    self.target_steps = r.get_u64()?;
                    self.halos_sent = r.get_u8()? != 0;
                    saw_meta = true;
                }
                "halos" => {
                    let mut r = ByteReader::new(data);
                    let n = r.get_u32()?;
                    self.pending_halos.clear();
                    for _ in 0..n {
                        let step = r.get_u64()?;
                        let side = Side::from_u8(r.get_u8()?)? as u8;
                        let value = r.get_u64()?;
                        self.pending_halos.insert((step, side), value);
                    }
                }
                n if n == lib_endpoints => {
                    // Present only in whole-process (non-MANA) images; a
                    // restored endpoint table is stale and is rebuilt by
                    // the MANA reinit hook right after this restore.
                    self.endpoints = data.clone();
                }
                _ => {}
            }
        }
        if !saw_meta {
            return Err(Error::Image("stencil image missing meta segment".into()));
        }
        Ok(())
    }

    fn steps_done(&self) -> u64 {
        self.step
    }

    fn size_bytes(&self) -> usize {
        self.cells.len() * 8 + self.endpoints.len() + self.pending_halos.len() * 24 + 64
    }
}

/// The DRAIN-phase plugin: move every undelivered message for this rank
/// from the fabric inbox into the checkpointable state, so the image set
/// captures the consistent cut (in-flight data included). Fires only when
/// every rank of the computation is suspended — the global barrier orders
/// all SUSPENDs before any DRAIN — so the inbox is final when read.
pub struct HaloDrainPlugin {
    /// The rank whose inbox this plugin drains.
    pub rank: u32,
    /// The rank's state (drained messages land in `pending_halos`).
    pub state: Arc<Mutex<StencilState>>,
    /// This incarnation's fabric.
    pub fabric: Arc<Fabric>,
}

impl Plugin for HaloDrainPlugin {
    fn name(&self) -> &'static str {
        "halo-drain"
    }

    fn on_event(&mut self, event: Event, _ctx: &mut PluginCtx<'_>) -> Result<()> {
        if event == Event::Drain {
            let mut s = self.state.lock().expect("stencil state poisoned");
            let mut drained = 0u32;
            while let Some(m) = self.fabric.try_recv(self.rank) {
                s.pending_halos.insert((m.step, m.side as u8), m.value);
                drained += 1;
            }
            if drained > 0 {
                log::debug!("rank {}: drained {drained} in-flight halos", self.rank);
            }
        }
        Ok(())
    }
}

/// The rank worker: exchange halos and advance the slab until the target
/// step count (or a kill). `steps_per_quantum` bounds the work between
/// checkpoint safe-points. State-held halos are consumed before the
/// fabric is polled — the property that makes DRAIN lossless.
pub fn stencil_worker(
    ctx: WorkerCtx,
    state: Arc<Mutex<StencilState>>,
    fabric: Arc<Fabric>,
    steps_per_quantum: u32,
) {
    loop {
        if ctx.ckpt_point() == GateVerdict::Exit {
            return;
        }
        let mut advanced = false;
        for _ in 0..steps_per_quantum.max(1) {
            let mut s = state.lock().expect("stencil state poisoned");
            if s.done() {
                ctx.record_steps(s.step);
                return;
            }
            if !s.halos_sent {
                // Our left boundary feeds the left neighbor's RIGHT side;
                // our right boundary feeds the right neighbor's LEFT side.
                let (step, rank) = (s.step, s.rank);
                let first = *s.cells.first().expect("nonempty slab");
                let last = *s.cells.last().expect("nonempty slab");
                fabric.send(s.left(), HaloMsg { step, from: rank, side: Side::Right, value: first });
                fabric.send(s.right(), HaloMsg { step, from: rank, side: Side::Left, value: last });
                s.halos_sent = true;
            }
            while let Some(m) = fabric.try_recv(s.rank) {
                s.pending_halos.insert((m.step, m.side as u8), m.value);
            }
            let l = s.pending_halos.get(&(s.step, Side::Left as u8)).copied();
            let r = s.pending_halos.get(&(s.step, Side::Right as u8)).copied();
            match (l, r) {
                (Some(l), Some(r)) => {
                    let key_l = (s.step, Side::Left as u8);
                    let key_r = (s.step, Side::Right as u8);
                    s.pending_halos.remove(&key_l);
                    s.pending_halos.remove(&key_r);
                    s.advance(l, r);
                    let (step, bytes) = (s.step, s.size_bytes() as u64);
                    drop(s);
                    ctx.record_steps(step);
                    ctx.record_state_bytes(bytes);
                    advanced = true;
                }
                _ => break, // waiting on a neighbor
            }
        }
        if !advanced {
            std::thread::sleep(Duration::from_micros(100));
        }
    }
}

/// Run the gang lockstep in-process (no fabric, no threads): the
/// uninterrupted reference every gang run is verified bit-for-bit
/// against. Returns each rank's `(cells, step)` at completion.
pub fn reference_final_states(
    n_ranks: u32,
    cells_per_rank: usize,
    target_steps: u64,
    seed: u64,
) -> Vec<(Vec<u64>, u64)> {
    let n = n_ranks as usize;
    let mut slabs: Vec<Vec<u64>> = (0..n_ranks)
        .map(|r| (0..cells_per_rank).map(|i| initial_cell(seed, r, i)).collect())
        .collect();
    for step in 0..target_steps {
        let snapshot = slabs.clone();
        for r in 0..n {
            let left_halo = *snapshot[(r + n - 1) % n].last().expect("nonempty slab");
            let right_halo = *snapshot[(r + 1) % n].first().expect("nonempty slab");
            let prev = &snapshot[r];
            let m = prev.len();
            for i in 0..m {
                let l = if i == 0 { left_halo } else { prev[i - 1] };
                let rv = if i + 1 == m { right_halo } else { prev[i + 1] };
                slabs[r][i] = stencil_mix(l, prev[i], rv, step);
            }
        }
    }
    slabs.into_iter().map(|cells| (cells, target_steps)).collect()
}

/// Default lower-half size: big enough that MANA exclusion visibly wins.
pub const DEFAULT_ENDPOINT_BYTES: usize = 64 * 1024;

/// The halo-exchange gang application: mints rank states, owns the
/// incarnation-scoped [`Fabric`], and implements
/// [`crate::cr::app::GangApp`] so a [`crate::cr::gang::GangSession`] can
/// drive it.
pub struct StencilApp {
    /// Gang width.
    pub n_ranks: u32,
    /// Slab size per rank.
    pub cells_per_rank: usize,
    /// Synthetic endpoint-table bytes per rank (the MANA ablation lever).
    pub endpoint_bytes: usize,
    fabric: Arc<Mutex<Option<Arc<Fabric>>>>,
}

impl StencilApp {
    /// A gang of `n_ranks` ranks with `cells_per_rank`-cell slabs.
    pub fn new(n_ranks: u32, cells_per_rank: usize) -> Self {
        assert!(n_ranks >= 1, "a gang needs at least one rank");
        assert!(cells_per_rank >= 1, "a slab needs at least one cell");
        Self {
            n_ranks,
            cells_per_rank,
            endpoint_bytes: DEFAULT_ENDPOINT_BYTES,
            fabric: Arc::new(Mutex::new(None)),
        }
    }

    /// Override the per-rank lower-half size.
    pub fn endpoint_bytes(mut self, bytes: usize) -> Self {
        self.endpoint_bytes = bytes;
        self
    }

    /// Swap in a fresh fabric for restart generation `generation`.
    pub fn rebuild_fabric(&self, generation: u32) {
        *self.fabric.lock().expect("fabric holder poisoned") =
            Some(Arc::new(Fabric::new(self.n_ranks, generation, self.endpoint_bytes)));
    }

    /// The current incarnation's fabric.
    ///
    /// # Panics
    /// If no incarnation was begun ([`StencilApp::rebuild_fabric`]).
    pub fn fabric(&self) -> Arc<Fabric> {
        Arc::clone(
            self.fabric
                .lock()
                .expect("fabric holder poisoned")
                .as_ref()
                .expect("no incarnation begun (rebuild_fabric not called)"),
        )
    }

    /// Shared handle to the fabric slot (for `'static` reinit closures).
    pub(crate) fn fabric_holder(&self) -> Arc<Mutex<Option<Arc<Fabric>>>> {
        Arc::clone(&self.fabric)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fabric_delivers_in_order_per_inbox() {
        let f = Fabric::new(2, 0, 16);
        f.send(1, HaloMsg { step: 0, from: 0, side: Side::Left, value: 7 });
        f.send(1, HaloMsg { step: 1, from: 0, side: Side::Left, value: 8 });
        assert_eq!(f.inbox_len(1), 2);
        assert_eq!(f.try_recv(1).unwrap().value, 7);
        assert_eq!(f.try_recv(1).unwrap().value, 8);
        assert!(f.try_recv(1).is_none());
        assert!(f.try_recv(0).is_none());
    }

    #[test]
    fn endpoint_blobs_differ_across_incarnations_and_ranks() {
        let a = Fabric::new(2, 0, 256);
        let b = Fabric::new(2, 1, 256);
        assert_ne!(a.endpoint_blob(0), a.endpoint_blob(1));
        assert_ne!(a.endpoint_blob(0), b.endpoint_blob(0));
        // Within one fabric the table is stable.
        assert_eq!(a.endpoint_blob(0), a.endpoint_blob(0));
    }

    #[test]
    fn reference_is_deterministic_and_seed_sensitive() {
        let a = reference_final_states(4, 8, 10, 42);
        let b = reference_final_states(4, 8, 10, 42);
        assert_eq!(a, b);
        let c = reference_final_states(4, 8, 10, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn state_segments_roundtrip_with_pending_halos() {
        let mut s = StencilState::fresh(2, 4, 8, 100, 7);
        s.step = 3;
        s.halos_sent = true;
        s.pending_halos.insert((3, Side::Left as u8), 99);
        s.pending_halos.insert((4, Side::Right as u8), 17);
        s.endpoints = vec![1, 2, 3];
        let segs = s.segments();
        let mut shell = StencilState::shell(2, 4);
        shell.restore(&segs).unwrap();
        assert_eq!(s, shell);
    }

    #[test]
    fn restore_rejects_rank_and_width_mismatch() {
        let s = StencilState::fresh(1, 4, 8, 10, 7);
        let segs = s.segments();
        let mut wrong_rank = StencilState::shell(2, 4);
        assert!(wrong_rank.restore(&segs).is_err());
        let mut wrong_width = StencilState::shell(1, 8);
        assert!(wrong_width.restore(&segs).is_err());
    }

    #[test]
    fn drain_plugin_moves_inflight_halos_into_state() {
        let fabric = Arc::new(Fabric::new(2, 0, 16));
        let state = Arc::new(Mutex::new(StencilState::fresh(1, 2, 4, 10, 0)));
        fabric.send(1, HaloMsg { step: 0, from: 0, side: Side::Left, value: 5 });
        fabric.send(1, HaloMsg { step: 0, from: 0, side: Side::Right, value: 6 });
        let mut p = HaloDrainPlugin {
            rank: 1,
            state: Arc::clone(&state),
            fabric: Arc::clone(&fabric),
        };
        let mut records = std::collections::BTreeMap::new();
        let mut env = std::collections::BTreeMap::new();
        let mut ctx = PluginCtx {
            records: &mut records,
            env: &mut env,
            generation: 0,
        };
        p.on_event(Event::Drain, &mut ctx).unwrap();
        assert_eq!(fabric.inbox_len(1), 0, "inbox fully drained");
        let s = state.lock().unwrap();
        assert_eq!(s.pending_halos.get(&(0, Side::Left as u8)), Some(&5));
        assert_eq!(s.pending_halos.get(&(0, Side::Right as u8)), Some(&6));
    }

    #[test]
    fn single_rank_ring_matches_reference() {
        // rank 0's neighbors are itself: both halos come from its own slab.
        let reference = reference_final_states(1, 4, 5, 3);
        let mut s = StencilState::fresh(0, 1, 4, 5, 3);
        while !s.done() {
            let l = *s.cells.last().unwrap();
            let r = *s.cells.first().unwrap();
            s.advance(l, r);
        }
        assert_eq!(s.cells, reference[0].0);
    }
}
