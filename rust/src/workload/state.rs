//! Checkpointable simulation state + the worker-thread transport loop.
//!
//! [`G4SimState`] is the bridge between the three layers: it owns a
//! [`ParticleState`] (whose tensors the PJRT engine advances), carries the
//! run metadata, and implements [`Checkpointable`] so the DMTCP layer can
//! serialize it into images. Because the transport RNG is counter-based
//! and part of the state, checkpoint → kill → restart → run-to-completion
//! is bit-identical to an uninterrupted run.

use std::sync::{Arc, Mutex};

use crate::dmtcp::process::{Checkpointable, GateVerdict, WorkerCtx};
use crate::error::{Error, Result};
use crate::runtime::state::{ParticleState, StaticInputs};
use crate::runtime::ComputeHandle;
use crate::util::rng::SplitMix64;
use crate::workload::geant4::{static_inputs, G4Version};
use crate::workload::workloads::{Workload, WorkloadKind};

/// The application state of one Geant4-analog process.
#[derive(Debug, Clone, PartialEq)]
pub struct G4SimState {
    pub particles: ParticleState,
    /// Steps to run in total.
    pub target_steps: u64,
    /// Workload label (consistency check on restore).
    pub workload_label: String,
    /// Version label (consistency check on restore).
    pub version_label: String,
}

impl G4SimState {
    pub fn done(&self) -> bool {
        self.particles.steps_done >= self.target_steps
    }

    /// Fraction of requested steps completed.
    pub fn progress(&self) -> f64 {
        self.particles.steps_done as f64 / self.target_steps.max(1) as f64
    }
}

impl Checkpointable for G4SimState {
    fn segments(&self) -> Vec<(String, Vec<u8>)> {
        let mut segs = self.particles.to_segments();
        let mut meta = Vec::new();
        meta.extend_from_slice(&self.target_steps.to_le_bytes());
        segs.push(("target_steps".into(), meta));
        segs.push(("workload".into(), self.workload_label.as_bytes().to_vec()));
        segs.push(("version".into(), self.version_label.as_bytes().to_vec()));
        segs
    }

    fn restore(&mut self, segments: &[(String, Vec<u8>)]) -> Result<()> {
        self.particles = ParticleState::from_segments(segments)?;
        for (name, data) in segments {
            match name.as_str() {
                "target_steps" => {
                    if data.len() != 8 {
                        return Err(Error::Image("bad target_steps segment".into()));
                    }
                    self.target_steps = u64::from_le_bytes(data.as_slice().try_into().unwrap());
                }
                "workload" => {
                    let label = String::from_utf8_lossy(data).into_owned();
                    if !self.workload_label.is_empty() && self.workload_label != label {
                        return Err(Error::Image(format!(
                            "image is for workload {label:?}, process expects {:?}",
                            self.workload_label
                        )));
                    }
                    self.workload_label = label;
                }
                "version" => {
                    self.version_label = String::from_utf8_lossy(data).into_owned();
                }
                _ => {}
            }
        }
        Ok(())
    }

    fn steps_done(&self) -> u64 {
        self.particles.steps_done
    }

    fn size_bytes(&self) -> usize {
        self.particles.size_bytes() + 64
    }
}

/// A fully assembled application: workload geometry + physics version +
/// static inputs, ready to mint states and drive workers.
pub struct G4App {
    pub kind: WorkloadKind,
    pub version: G4Version,
    pub workload: Workload,
    pub si: Arc<StaticInputs>,
}

impl G4App {
    /// Build for the artifact dimensions `(grid_d from the manifest)`.
    pub fn build(kind: WorkloadKind, version: G4Version, grid_d: usize) -> Self {
        let workload = Workload::build(kind, grid_d);
        let si = Arc::new(static_inputs(workload.grid.clone(), grid_d, version));
        Self {
            kind,
            version,
            workload,
            si,
        }
    }

    /// Mint a fresh simulation state (batch size from the manifest).
    pub fn fresh_state(&self, batch: usize, target_steps: u64, seed: u64) -> G4SimState {
        let n_vox = self.si.grid.len();
        let origin = self.workload.source_origin;
        let source = self.workload.source;
        let mut energy_rng = SplitMix64::new(seed ^ 0x5EED_F00D);
        let particles = ParticleState::from_source(batch, n_vox, origin, seed, |_| {
            source.sample_energy(&mut energy_rng)
        });
        G4SimState {
            particles,
            target_steps,
            workload_label: self.kind.label(),
            version_label: self.version.label().to_string(),
        }
    }

    /// An empty shell state for `dmtcp_restart` to restore into.
    pub fn shell_state(&self) -> G4SimState {
        G4SimState {
            particles: ParticleState {
                pos: Vec::new(),
                dcos: Vec::new(),
                energy: Vec::new(),
                weight: Vec::new(),
                alive: Vec::new(),
                rng: Vec::new(),
                edep: Vec::new(),
                steps_done: 0,
            },
            target_steps: 0,
            workload_label: self.kind.label(),
            version_label: self.version.label().to_string(),
        }
    }
}

/// The user-thread body: advance the transport between checkpoint
/// safe-points until the target step count is reached (or the process is
/// killed). `scans_per_quantum` controls the work quantum between
/// safe-points (one scan = `manifest.scan_steps` kernel steps).
pub fn transport_worker(
    ctx: WorkerCtx,
    handle: ComputeHandle,
    state: Arc<Mutex<G4SimState>>,
    si: Arc<StaticInputs>,
    scans_per_quantum: u32,
) {
    loop {
        if ctx.ckpt_point() == GateVerdict::Exit {
            return;
        }
        // Take the state out, advance it on the engine, put it back.
        let (particles, remaining_scans) = {
            let s = state.lock().expect("sim state poisoned");
            if s.done() {
                return;
            }
            let steps_left = s.target_steps - s.particles.steps_done;
            let scan_steps = handle.manifest().scan_steps as u64;
            let scans = steps_left.div_ceil(scan_steps).min(scans_per_quantum as u64);
            (s.particles.clone(), scans as u32)
        };
        let t0 = std::time::Instant::now();
        match handle.scan(particles, &si, remaining_scans) {
            Ok(advanced) => {
                let mut s = state.lock().expect("sim state poisoned");
                s.particles = advanced;
                let (steps, bytes) = (s.particles.steps_done, s.size_bytes() as u64);
                drop(s);
                ctx.record_busy(t0.elapsed().as_nanos() as u64);
                ctx.record_steps(steps);
                ctx.record_state_bytes(bytes);
            }
            Err(e) => {
                // Engine loss is fatal for the worker (the coordinator
                // will requeue the job; state is intact at the last ckpt).
                log::error!("transport worker: engine error: {e}");
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::spectra::NeutronSource;

    fn app() -> G4App {
        G4App::build(
            WorkloadKind::NeutronHe3(NeutronSource::Cf252),
            G4Version::V10_7,
            16,
        )
    }

    #[test]
    fn fresh_state_deterministic() {
        let a = app().fresh_state(128, 100, 42);
        let b = app().fresh_state(128, 100, 42);
        assert_eq!(a, b);
        let c = app().fresh_state(128, 100, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn segments_roundtrip() {
        let s = app().fresh_state(64, 500, 7);
        let segs = s.segments();
        let mut shell = app().shell_state();
        shell.restore(&segs).unwrap();
        assert_eq!(s, shell);
    }

    #[test]
    fn restore_rejects_wrong_workload() {
        let s = app().fresh_state(64, 500, 7);
        let segs = s.segments();
        let other = G4App::build(WorkloadKind::WaterPhantom, G4Version::V10_7, 16);
        let mut shell = other.shell_state();
        let err = shell.restore(&segs).unwrap_err();
        assert!(err.to_string().contains("workload"));
    }

    #[test]
    fn source_energies_match_spectrum() {
        let s = app().fresh_state(4096, 1, 3);
        let mean: f32 =
            s.particles.energy.iter().sum::<f32>() / s.particles.energy.len() as f32;
        // Cf-252 mean ≈ 2.1 MeV
        assert!((1.0..3.5).contains(&mean), "mean energy {mean}");
    }

    #[test]
    fn progress_and_done() {
        let mut s = app().fresh_state(16, 100, 1);
        assert!(!s.done());
        s.particles.steps_done = 100;
        assert!(s.done());
        assert_eq!(s.progress(), 1.0);
    }
}
