//! CP2K-analog workload (the paper's §VII material-science direction) —
//! including a faithful reproduction of its known C/R defect.
//!
//! "Tests with CP2K are ongoing; so far, we've made progress with
//! checkpointing, although we have encountered some issues with
//! restarting. We are collaborating with the developers of DMTCP and CP2K
//! to address these problems."
//!
//! The compute analog is an SCF-like fixed-point iteration (damped Jacobi
//! on a 2-D Laplace problem with a source term) — iterative, convergent,
//! deterministic, with a residual history. The *restart defect* is modeled
//! on the actual failure class seen with scratch-file-heavy codes: CP2K
//! keeps per-process scratch paths derived from the real PID; after
//! restart the real PID differs, the recorded path dangles, and the run
//! aborts. [`Cp2kScratchPlugin`] is the fix under development with the
//! DMTCP developers: it re-virtualizes the scratch path on `PostRestart`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::dmtcp::plugin::{Event, Plugin, PluginCtx};
use crate::dmtcp::process::{Checkpointable, GateVerdict, WorkerCtx};
use crate::error::{Error, Result};
use crate::util::bytes::{bytes_to_f32s, f32s_to_bytes};

/// SCF-like iterative state.
#[derive(Debug, Clone, PartialEq)]
pub struct Cp2kState {
    /// Grid edge length.
    pub n: usize,
    /// Current field (n*n, row-major).
    pub field: Vec<f32>,
    /// Fixed source term (n*n).
    pub source: Vec<f32>,
    /// Iterations completed.
    pub iterations: u64,
    /// Target iterations.
    pub target_iterations: u64,
    /// Residual after each iteration (convergence log).
    pub residuals: Vec<f32>,
    /// Scratch-file path, PID-derived (the defect: not virtualized).
    pub scratch_path: String,
    /// Strict mode reproduces the restart failure; disabled only when the
    /// scratch plugin has rewritten the path.
    pub strict_scratch: bool,
}

impl Cp2kState {
    /// A Laplace problem with a centered source blob.
    pub fn new(n: usize, target_iterations: u64, real_pid: u64) -> Self {
        let mut source = vec![0.0f32; n * n];
        for dy in 0..3 {
            for dx in 0..3 {
                source[(n / 2 + dy - 1) * n + (n / 2 + dx - 1)] = 1.0;
            }
        }
        Self {
            n,
            field: vec![0.0; n * n],
            source,
            iterations: 0,
            target_iterations,
            residuals: Vec::new(),
            scratch_path: format!("/tmp/cp2k_scratch.{real_pid}"),
            strict_scratch: true,
        }
    }

    /// One damped-Jacobi sweep; returns the residual.
    pub fn iterate(&mut self) -> f32 {
        let n = self.n;
        let mut next = self.field.clone();
        let mut residual = 0.0f32;
        for y in 1..n - 1 {
            for x in 1..n - 1 {
                let i = y * n + x;
                let neigh = self.field[i - 1]
                    + self.field[i + 1]
                    + self.field[i - n]
                    + self.field[i + n];
                let target = 0.25 * (neigh + self.source[i]);
                let v = 0.7 * target + 0.3 * self.field[i];
                residual += (v - self.field[i]).abs();
                next[i] = v;
            }
        }
        self.field = next;
        self.iterations += 1;
        self.residuals.push(residual);
        residual
    }

    pub fn done(&self) -> bool {
        self.iterations >= self.target_iterations
    }

    /// Field checksum for bitwise comparisons.
    pub fn digest(&self) -> u64 {
        self.field
            .iter()
            .fold(0u64, |acc, &v| acc.rotate_left(7) ^ v.to_bits() as u64)
    }
}

impl Checkpointable for Cp2kState {
    fn segments(&self) -> Vec<(String, Vec<u8>)> {
        let mut meta = Vec::new();
        meta.extend_from_slice(&(self.n as u64).to_le_bytes());
        meta.extend_from_slice(&self.iterations.to_le_bytes());
        meta.extend_from_slice(&self.target_iterations.to_le_bytes());
        vec![
            ("meta".into(), meta),
            ("field".into(), f32s_to_bytes(&self.field)),
            ("source".into(), f32s_to_bytes(&self.source)),
            ("residuals".into(), f32s_to_bytes(&self.residuals)),
            ("scratch_path".into(), self.scratch_path.as_bytes().to_vec()),
        ]
    }

    fn restore(&mut self, segments: &[(String, Vec<u8>)]) -> Result<()> {
        for (name, data) in segments {
            match name.as_str() {
                "meta" => {
                    if data.len() != 24 {
                        return Err(Error::Image("cp2k meta malformed".into()));
                    }
                    self.n = u64::from_le_bytes(data[0..8].try_into().unwrap()) as usize;
                    self.iterations = u64::from_le_bytes(data[8..16].try_into().unwrap());
                    self.target_iterations =
                        u64::from_le_bytes(data[16..24].try_into().unwrap());
                }
                "field" => self.field = bytes_to_f32s(data)?,
                "source" => self.source = bytes_to_f32s(data)?,
                "residuals" => self.residuals = bytes_to_f32s(data)?,
                "scratch_path" => {
                    let recorded = String::from_utf8_lossy(data).into_owned();
                    if self.strict_scratch && recorded != self.scratch_path {
                        // THE KNOWN DEFECT: the image's scratch path embeds
                        // the old incarnation's real PID; this process's
                        // differs, CP2K aborts on the dangling handle.
                        return Err(Error::Workload(format!(
                            "CP2K restart failure (known issue, paper §VII): \
                             scratch file {recorded:?} does not exist in this \
                             incarnation (ours: {:?}); register \
                             Cp2kScratchPlugin to re-virtualize it",
                            self.scratch_path
                        )));
                    }
                    self.scratch_path = recorded;
                }
                _ => {}
            }
        }
        Ok(())
    }

    fn steps_done(&self) -> u64 {
        self.iterations
    }

    fn size_bytes(&self) -> usize {
        (self.field.len() + self.source.len() + self.residuals.len()) * 4 + 64
    }
}

/// The one workload label for the CP2K-analog, shared by the `CrApp`
/// implementation, the CLI dispatch, and the CLI `workloads` listing.
pub const CP2K_SCF_LABEL: &str = "cp2k-scf";

/// Driver configuration for running the CP2K-analog through the C/R layer
/// (`cr::CrApp` is implemented for this type in `cr::app`).
#[derive(Debug, Clone)]
pub struct Cp2kApp {
    /// Grid edge length of the Laplace problem.
    pub n: usize,
    /// Register [`Cp2kScratchPlugin`] so restart re-virtualizes the
    /// scratch path. Disable to reproduce the paper's §VII restart defect
    /// through the full C/R stack.
    pub scratch_fix: bool,
    /// Artificial per-quantum pause, pacing the toy sweep like a
    /// realistically sized SCF step (so checkpoints and preemptions land
    /// mid-run instead of after completion).
    pub sweep_pause: Duration,
}

impl Cp2kApp {
    /// Driver for an `n`×`n` problem with the scratch fix on and a 50 µs
    /// sweep pause.
    pub fn new(n: usize) -> Self {
        Self {
            n,
            scratch_fix: true,
            sweep_pause: Duration::from_micros(50),
        }
    }

    /// Synthetic per-incarnation "real pid" for scratch-path derivation
    /// (mirrors the DMTCP launch-layer pid allocator; each incarnation
    /// must get a distinct one for the defect model to hold).
    pub fn next_scratch_pid() -> u64 {
        static NEXT: AtomicU64 = AtomicU64::new(5_000);
        NEXT.fetch_add(1, Ordering::Relaxed)
    }
}

/// The user-thread body driving a shared [`Cp2kState`]: iterate between
/// checkpoint safe-points until the target iteration count is reached (or
/// the process is killed). Sweeps run under the state lock, so any number
/// of workers interleave deterministically.
pub fn cp2k_worker(
    ctx: WorkerCtx,
    state: Arc<Mutex<Cp2kState>>,
    sweeps_per_quantum: u32,
    pause: Duration,
) {
    loop {
        if ctx.ckpt_point() == GateVerdict::Exit {
            return;
        }
        let (steps, bytes) = {
            let mut s = state.lock().expect("cp2k state poisoned");
            if s.done() {
                return;
            }
            for _ in 0..sweeps_per_quantum.max(1) {
                if s.done() {
                    break;
                }
                s.iterate();
            }
            (s.iterations, s.size_bytes() as u64)
        };
        ctx.record_steps(steps);
        ctx.record_state_bytes(bytes);
        if !pause.is_zero() {
            std::thread::sleep(pause);
        }
    }
}

/// The fix under development: a DMTCP plugin that records the scratch path
/// at checkpoint and re-virtualizes it on restart (copies the scratch over
/// to the new incarnation's path, conceptually).
pub struct Cp2kScratchPlugin {
    /// The wrapped state's shared handle.
    pub state: std::sync::Arc<std::sync::Mutex<Cp2kState>>,
}

impl Plugin for Cp2kScratchPlugin {
    fn name(&self) -> &'static str {
        "cp2k-scratch"
    }

    fn on_event(&mut self, event: Event, ctx: &mut PluginCtx<'_>) -> Result<()> {
        match event {
            Event::PreCheckpoint => {
                let s = self.state.lock().expect("cp2k state poisoned");
                ctx.records
                    .insert("cp2k_scratch".into(), s.scratch_path.as_bytes().to_vec());
            }
            Event::PostRestart => {
                // Rebind: accept the recorded scratch as this incarnation's
                // (the real fix migrates the file; our model disables the
                // strict dangling-handle check).
                let mut s = self.state.lock().expect("cp2k state poisoned");
                s.strict_scratch = false;
            }
            _ => {}
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges() {
        let mut s = Cp2kState::new(16, 600, 1234);
        let r0 = s.iterate();
        for _ in 0..599 {
            s.iterate();
        }
        assert!(s.done());
        let r_last = *s.residuals.last().unwrap();
        assert!(r_last < r0 * 0.05, "not converging: {r0} -> {r_last}");
        // Residual history is monotone-ish decreasing overall.
        let mid = s.residuals[s.residuals.len() / 2];
        assert!(r_last < mid, "residual not decreasing in the tail");
    }

    #[test]
    fn deterministic() {
        let mut a = Cp2kState::new(12, 50, 1);
        let mut b = Cp2kState::new(12, 50, 1);
        for _ in 0..50 {
            a.iterate();
            b.iterate();
        }
        assert_eq!(a.digest(), b.digest());
    }

    #[test]
    fn restart_defect_reproduced() {
        // Checkpoint under PID 1000...
        let mut s = Cp2kState::new(8, 100, 1000);
        s.iterate();
        let segs = s.segments();
        // ...restart under PID 2000: the recorded scratch path dangles.
        let mut restored = Cp2kState::new(8, 100, 2000);
        let err = restored.restore(&segs).unwrap_err();
        assert!(
            err.to_string().contains("known issue"),
            "wrong error: {err}"
        );
    }

    #[test]
    fn scratch_plugin_fixes_restart() {
        use std::sync::{Arc, Mutex};
        let mut s = Cp2kState::new(8, 100, 1000);
        for _ in 0..7 {
            s.iterate();
        }
        let segs = s.segments();
        let digest_at_ckpt = s.digest();

        let restored = Arc::new(Mutex::new(Cp2kState::new(8, 100, 2000)));
        // Fire the plugin's PostRestart first (as dmtcp_restart does for
        // registered plugins), then restore.
        let mut plugin = Cp2kScratchPlugin { state: Arc::clone(&restored) };
        let mut records = std::collections::BTreeMap::new();
        let mut env = std::collections::BTreeMap::new();
        let mut ctx = PluginCtx { records: &mut records, env: &mut env, generation: 1 };
        plugin.on_event(Event::PostRestart, &mut ctx).unwrap();
        restored.lock().unwrap().restore(&segs).unwrap();

        let mut r = restored.lock().unwrap();
        assert_eq!(r.digest(), digest_at_ckpt);
        assert_eq!(r.iterations, 7);
        // Continue to completion bitwise-identically to uninterrupted.
        let mut uninterrupted = Cp2kState::new(8, 100, 1000);
        for _ in 0..100 {
            uninterrupted.iterate();
        }
        while !r.done() {
            r.iterate();
        }
        assert_eq!(r.digest(), uninterrupted.digest());
    }
}
