//! Source spectra: the neutron and gamma sources of the paper's §VI.
//!
//! "neutron measurement and characterization simulations, employing a
//! variety of sources such as AmLi, AmBe, and Cf-252 ... simulation tests
//! for the characteristic study of gamma emissions from various isotopes,
//! including Na-22, K-40, and Co-60". Each source is a deterministic
//! energy sampler (MeV) over a [`SplitMix64`] stream.

use crate::util::rng::SplitMix64;

/// Neutron calibration sources.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NeutronSource {
    /// Am-Li: soft spectrum, mean ≈ 0.5 MeV, endpoint ≈ 1.5 MeV.
    AmLi,
    /// Am-Be: hard (α,n) spectrum, broad to ≈ 11 MeV, mean ≈ 4.2 MeV.
    AmBe,
    /// Cf-252: spontaneous-fission Watt spectrum, mean ≈ 2.1 MeV.
    Cf252,
}

impl NeutronSource {
    pub fn label(&self) -> &'static str {
        match self {
            NeutronSource::AmLi => "AmLi",
            NeutronSource::AmBe => "AmBe",
            NeutronSource::Cf252 => "Cf-252",
        }
    }

    /// Sample one neutron energy (MeV).
    pub fn sample_energy(&self, rng: &mut SplitMix64) -> f32 {
        match self {
            // Soft quasi-Maxwellian capped at the reaction endpoint.
            NeutronSource::AmLi => {
                let e = rng.gen_exp(0.45);
                e.min(1.5).max(0.02) as f32
            }
            // Broad multi-peak spectrum: mixture of two humps.
            NeutronSource::AmBe => {
                let e = if rng.next_f64() < 0.55 {
                    3.0 + 2.0 * rng.gen_normal().abs()
                } else {
                    rng.gen_f64(0.5, 7.0)
                };
                e.clamp(0.1, 11.0) as f32
            }
            // Watt: E ~ a sinh-weighted fission spectrum; sampled via the
            // standard two-exponential trick (a=1.025 MeV, b=2.926 /MeV).
            NeutronSource::Cf252 => {
                let a = 1.025f64;
                let b = 2.926f64;
                let w = a * ((a * b / 4.0) + rng.gen_exp(1.0) * a - 0.0);
                // Simple accept-free approximation: exp sample shifted by
                // the sinh term's mean contribution; clamps keep it sane.
                let e = rng.gen_exp(a) + (w * b).sqrt() * 0.25 * rng.next_f64();
                e.clamp(0.05, 12.0) as f32
            }
        }
    }

    /// Approximate spectrum mean (MeV), for tests and reports.
    pub fn nominal_mean(&self) -> f32 {
        match self {
            NeutronSource::AmLi => 0.45,
            NeutronSource::AmBe => 4.2,
            NeutronSource::Cf252 => 2.1,
        }
    }

    pub fn all() -> [NeutronSource; 3] {
        [NeutronSource::AmLi, NeutronSource::AmBe, NeutronSource::Cf252]
    }
}

/// Gamma calibration isotopes (line energies in MeV with branching).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GammaIsotope {
    /// Na-22: 0.511 (annihilation, 1.8/decay) + 1.2745 MeV.
    Na22,
    /// K-40: 1.4608 MeV.
    K40,
    /// Co-60: 1.1732 + 1.3325 MeV cascade.
    Co60,
}

impl GammaIsotope {
    pub fn label(&self) -> &'static str {
        match self {
            GammaIsotope::Na22 => "Na-22",
            GammaIsotope::K40 => "K-40",
            GammaIsotope::Co60 => "Co-60",
        }
    }

    /// The discrete lines `(energy_mev, relative_intensity)`.
    pub fn lines(&self) -> &'static [(f32, f32)] {
        match self {
            GammaIsotope::Na22 => &[(0.511, 0.64), (1.2745, 0.36)],
            GammaIsotope::K40 => &[(1.4608, 1.0)],
            GammaIsotope::Co60 => &[(1.1732, 0.5), (1.3325, 0.5)],
        }
    }

    /// Sample one photon energy (MeV) by line intensity.
    pub fn sample_energy(&self, rng: &mut SplitMix64) -> f32 {
        let lines = self.lines();
        let total: f32 = lines.iter().map(|(_, w)| w).sum();
        let mut u = rng.next_f32() * total;
        for &(e, w) in lines {
            if u < w {
                return e;
            }
            u -= w;
        }
        lines.last().unwrap().0
    }

    pub fn all() -> [GammaIsotope; 3] {
        [GammaIsotope::Na22, GammaIsotope::K40, GammaIsotope::Co60]
    }
}

/// Beam sources for the calorimeter / phantom workloads.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Beam {
    /// Fixed particle energy (MeV).
    pub energy_mev: f32,
    /// Gaussian energy spread fraction.
    pub spread: f32,
}

impl Beam {
    pub fn sample_energy(&self, rng: &mut SplitMix64) -> f32 {
        let e = self.energy_mev as f64 * (1.0 + self.spread as f64 * rng.gen_normal());
        e.max(0.05) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mean_of(mut f: impl FnMut(&mut SplitMix64) -> f32, n: usize) -> f32 {
        let mut rng = SplitMix64::new(12345);
        (0..n).map(|_| f(&mut rng)).sum::<f32>() / n as f32
    }

    #[test]
    fn neutron_spectra_ordering_and_ranges() {
        let amli = mean_of(|r| NeutronSource::AmLi.sample_energy(r), 20_000);
        let ambe = mean_of(|r| NeutronSource::AmBe.sample_energy(r), 20_000);
        let cf = mean_of(|r| NeutronSource::Cf252.sample_energy(r), 20_000);
        assert!(amli < cf && cf < ambe, "means: AmLi={amli} Cf={cf} AmBe={ambe}");
        assert!((amli - 0.45).abs() < 0.15, "AmLi mean {amli}");
        assert!((ambe - 4.2).abs() < 1.2, "AmBe mean {ambe}");
        assert!((cf - 2.1).abs() < 1.0, "Cf mean {cf}");
    }

    #[test]
    fn gamma_lines_exact() {
        let mut rng = SplitMix64::new(7);
        for iso in GammaIsotope::all() {
            let lines: Vec<f32> = iso.lines().iter().map(|&(e, _)| e).collect();
            for _ in 0..1_000 {
                let e = iso.sample_energy(&mut rng);
                assert!(
                    lines.iter().any(|&l| (l - e).abs() < 1e-6),
                    "{iso:?}: {e} not a line"
                );
            }
        }
    }

    #[test]
    fn na22_branching_ratio() {
        let mut rng = SplitMix64::new(9);
        let n = 50_000;
        let low = (0..n)
            .filter(|_| GammaIsotope::Na22.sample_energy(&mut rng) < 1.0)
            .count();
        let frac = low as f64 / n as f64;
        assert!((frac - 0.64).abs() < 0.02, "511 keV fraction {frac}");
    }

    #[test]
    fn sampling_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(
                NeutronSource::Cf252.sample_energy(&mut a),
                NeutronSource::Cf252.sample_energy(&mut b)
            );
        }
    }

    #[test]
    fn beam_spread() {
        let beam = Beam { energy_mev: 100.0, spread: 0.01 };
        let m = mean_of(|r| beam.sample_energy(r), 10_000);
        assert!((m - 100.0).abs() < 1.0, "beam mean {m}");
    }
}
