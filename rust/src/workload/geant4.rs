//! Geant4-analog application layer: versions, materials, physics tables.
//!
//! The paper exercises C/R across "Geant4 versions, namely 10.5, 10.7 and
//! 11.0". For the transport engine a "version" is a revision of the
//! physics tables: successive releases retuned cross-sections by a few
//! percent. Versions therefore produce *different but individually
//! deterministic* results — exactly the property the robustness matrix
//! needs (a restarted 10.7 run must bitwise-match an uninterrupted 10.7
//! run, while 10.5 and 11.0 runs legitimately differ).

use crate::runtime::state::StaticInputs;

/// Geant4 release analogs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum G4Version {
    V10_5,
    V10_7,
    V11_0,
}

impl G4Version {
    pub fn label(&self) -> &'static str {
        match self {
            G4Version::V10_5 => "10.5",
            G4Version::V10_7 => "10.7",
            G4Version::V11_0 => "11.0",
        }
    }

    pub fn all() -> [G4Version; 3] {
        [G4Version::V10_5, G4Version::V10_7, G4Version::V11_0]
    }

    /// Per-release retuning of `(sigma_scale, absorption_scale)`.
    pub fn physics_revision(&self) -> (f32, f32) {
        match self {
            G4Version::V10_5 => (1.00, 1.00),
            G4Version::V10_7 => (1.03, 0.97), // FTFP_BERT retune
            G4Version::V11_0 => (0.98, 1.05), // new evaluated data
        }
    }
}

/// The material catalog shared by all workloads (index = grid value).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Material {
    /// Near-vacuum / air gap.
    Air = 0,
    /// Water (phantom bulk, moderator).
    Water = 1,
    /// Lead (EM absorber).
    Lead = 2,
    /// Plastic scintillator (sandwich active layers).
    Scintillator = 3,
    /// Polyethylene (neutron moderator).
    Polyethylene = 4,
    /// He-3 proportional-counter gas.
    He3 = 5,
    /// High-purity germanium crystal.
    Germanium = 6,
    /// Tungsten (collimator / dense absorber).
    Tungsten = 7,
}

pub const N_MATERIALS: usize = 8;

impl Material {
    pub fn all() -> [Material; N_MATERIALS] {
        [
            Material::Air,
            Material::Water,
            Material::Lead,
            Material::Scintillator,
            Material::Polyethylene,
            Material::He3,
            Material::Germanium,
            Material::Tungsten,
        ]
    }

    /// Base cross-section row `(s0, s1, f_abs, f_loss, g)`:
    /// `sigma(E) = s0 + s1/sqrt(E)` [1/length], absorption fraction,
    /// energy-loss fraction per scatter, scattering anisotropy.
    pub fn xs_row(&self) -> [f32; 5] {
        match self {
            Material::Air => [0.002, 0.0005, 0.05, 0.02, 0.1],
            Material::Water => [0.30, 0.12, 0.12, 0.35, 0.45],
            Material::Lead => [0.85, 0.10, 0.55, 0.55, 0.70],
            Material::Scintillator => [0.25, 0.08, 0.10, 0.30, 0.40],
            Material::Polyethylene => [0.45, 0.30, 0.08, 0.45, 0.30],
            Material::He3 => [0.08, 0.60, 0.85, 0.90, 0.05],
            Material::Germanium => [0.60, 0.15, 0.60, 0.60, 0.60],
            Material::Tungsten => [1.00, 0.12, 0.60, 0.60, 0.75],
        }
    }
}

/// Build the `[M,6]` cross-section table for one Geant4 version.
pub fn xs_table(version: G4Version) -> Vec<f32> {
    let (sig, abs) = version.physics_revision();
    let mut xs = Vec::with_capacity(N_MATERIALS * 6);
    for m in Material::all() {
        let [s0, s1, fa, fl, g] = m.xs_row();
        xs.extend_from_slice(&[
            s0 * sig,
            s1 * sig,
            (fa * abs).min(0.95),
            fl,
            g,
            0.0, // pad
        ]);
    }
    xs
}

/// World/physics parameters shared by all workloads.
pub fn standard_params(grid_d: usize) -> [f32; 8] {
    [
        1.0,            // voxel_size
        1.0,            // 1/voxel_size
        0.01,           // e_cut (MeV)
        2.0,            // max_step (voxel units)
        grid_d as f32,  // D
        0.0, 0.0, 0.0,  // pad
    ]
}

/// Assemble [`StaticInputs`] from a material grid and version.
pub fn static_inputs(grid: Vec<i32>, grid_d: usize, version: G4Version) -> StaticInputs {
    StaticInputs {
        grid,
        xs: xs_table(version),
        params: standard_params(grid_d),
        n_mat: N_MATERIALS,
        grid_d,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xs_table_shape_and_ranges() {
        for v in G4Version::all() {
            let xs = xs_table(v);
            assert_eq!(xs.len(), N_MATERIALS * 6);
            for m in 0..N_MATERIALS {
                let row = &xs[m * 6..m * 6 + 6];
                assert!(row[0] > 0.0, "s0 must be positive");
                assert!((0.0..=0.95).contains(&row[2]), "f_abs out of range");
                assert!((0.0..=1.0).contains(&row[3]), "f_loss out of range");
                assert!((0.0..1.0).contains(&row[4]), "g out of range");
            }
        }
    }

    #[test]
    fn versions_differ_but_are_deterministic() {
        let a = xs_table(G4Version::V10_5);
        let b = xs_table(G4Version::V10_7);
        let c = xs_table(G4Version::V11_0);
        assert_ne!(a, b);
        assert_ne!(b, c);
        assert_eq!(xs_table(G4Version::V10_7), b);
    }

    #[test]
    fn he3_is_absorber_poly_is_moderator() {
        let he3 = Material::He3.xs_row();
        let poly = Material::Polyethylene.xs_row();
        assert!(he3[2] > 0.8, "He-3 must capture");
        assert!(he3[1] > poly[1], "He-3 capture is 1/v dominated");
        assert!(poly[3] > 0.3, "poly must moderate (high energy loss)");
        assert!(poly[2] < 0.1, "poly must not absorb much");
    }

    #[test]
    fn static_inputs_validate() {
        let d = 8;
        let si = static_inputs(vec![0; d * d * d], d, G4Version::V10_7);
        assert!(si.validate(d, N_MATERIALS).is_ok());
    }
}
