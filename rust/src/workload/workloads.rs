//! The nine evaluation workloads of the paper's §VI, as voxel geometries +
//! sources + detector ROIs.
//!
//! "electromagnetic (EM) calorimeter arrays, hadron sandwich calorimeters,
//! and specialized water phantom simulations designed for voxel
//! geometries ... neutron measurement ... AmLi, AmBe, and Cf-252 ...
//! a Helium-3 proportional counter ... gamma emissions from various
//! isotopes, including Na-22, K-40, and Co-60, employing High Purity
//! Germanium (HPGe) detectors".

use crate::util::rng::SplitMix64;
use crate::workload::geant4::Material;
use crate::workload::spectra::{Beam, GammaIsotope, NeutronSource};

/// The evaluation-matrix workloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkloadKind {
    /// EM calorimeter array: PbWO4-like crystal block behind an air gap.
    EmCalorimeter,
    /// Hadron sandwich calorimeter: alternating absorber/scintillator.
    HadronSandwich,
    /// Water phantom with voxel dosimetry (medical).
    WaterPhantom,
    /// Neutron source in a polyethylene moderator with a He-3 counter.
    NeutronHe3(NeutronSource),
    /// Gamma isotope viewed by an HPGe crystal.
    GammaHpge(GammaIsotope),
}

impl WorkloadKind {
    pub fn label(&self) -> String {
        match self {
            WorkloadKind::EmCalorimeter => "em-calorimeter".into(),
            WorkloadKind::HadronSandwich => "hadron-sandwich".into(),
            WorkloadKind::WaterPhantom => "water-phantom".into(),
            WorkloadKind::NeutronHe3(s) => format!("neutron-he3-{}", s.label()),
            WorkloadKind::GammaHpge(i) => format!("gamma-hpge-{}", i.label()),
        }
    }

    /// The full §VI evaluation matrix (9 workloads).
    pub fn all() -> Vec<WorkloadKind> {
        let mut v = vec![
            WorkloadKind::EmCalorimeter,
            WorkloadKind::HadronSandwich,
            WorkloadKind::WaterPhantom,
        ];
        v.extend(NeutronSource::all().map(WorkloadKind::NeutronHe3));
        v.extend(GammaIsotope::all().map(WorkloadKind::GammaHpge));
        v
    }
}

/// A fully built workload: geometry + source + detector ROI.
#[derive(Debug, Clone)]
pub struct Workload {
    pub kind: WorkloadKind,
    /// Flattened `D^3` material-index grid.
    pub grid: Vec<i32>,
    /// Detector region-of-interest mask (`D^3`, 0/1).
    pub roi: Vec<f32>,
    /// Source position (world units).
    pub source_origin: [f32; 3],
    /// Source energy sampler.
    pub source: SourceKind,
}

/// Type-erased energy source.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SourceKind {
    Beam(Beam),
    Neutron(NeutronSource),
    Gamma(GammaIsotope),
}

impl SourceKind {
    pub fn sample_energy(&self, rng: &mut SplitMix64) -> f32 {
        match self {
            SourceKind::Beam(b) => b.sample_energy(rng),
            SourceKind::Neutron(s) => s.sample_energy(rng),
            SourceKind::Gamma(g) => g.sample_energy(rng),
        }
    }
}

/// Flat index helper.
fn at(d: usize, x: usize, y: usize, z: usize) -> usize {
    (x * d + y) * d + z
}

impl Workload {
    /// Build a workload's geometry on a `d^3` grid.
    pub fn build(kind: WorkloadKind, d: usize) -> Workload {
        assert!(d >= 8, "grid too small for the geometries");
        let c = d / 2;
        let mut grid = vec![Material::Air as i32; d * d * d];
        let mut roi = vec![0.0f32; d * d * d];
        let source_origin;
        let source;

        match kind {
            WorkloadKind::EmCalorimeter => {
                // Crystal block (lead analog) occupying the downstream 2/3,
                // beam entering from the upstream face. ROI = the block.
                for x in 0..d {
                    for y in 0..d {
                        for z in d / 3..d {
                            grid[at(d, x, y, z)] = Material::Lead as i32;
                            roi[at(d, x, y, z)] = 1.0;
                        }
                    }
                }
                source_origin = [c as f32, c as f32, 1.5];
                source = SourceKind::Beam(Beam { energy_mev: 150.0, spread: 0.02 });
            }
            WorkloadKind::HadronSandwich => {
                // Alternating absorber/scintillator slabs along z; ROI =
                // the active (scintillator) layers.
                for z in d / 4..d {
                    let mat = if (z / 2) % 2 == 0 {
                        Material::Tungsten
                    } else {
                        Material::Scintillator
                    };
                    for x in 0..d {
                        for y in 0..d {
                            grid[at(d, x, y, z)] = mat as i32;
                            if mat == Material::Scintillator {
                                roi[at(d, x, y, z)] = 1.0;
                            }
                        }
                    }
                }
                source_origin = [c as f32, c as f32, 1.5];
                source = SourceKind::Beam(Beam { energy_mev: 300.0, spread: 0.05 });
            }
            WorkloadKind::WaterPhantom => {
                // Uniform water bulk; ROI = a dose voxel column on the beam
                // axis (depth-dose).
                for i in grid.iter_mut() {
                    *i = Material::Water as i32;
                }
                for z in 0..d {
                    roi[at(d, c, c, z)] = 1.0;
                }
                source_origin = [c as f32, c as f32, 0.5];
                source = SourceKind::Beam(Beam { energy_mev: 50.0, spread: 0.01 });
            }
            WorkloadKind::NeutronHe3(src) => {
                // Polyethylene moderator sphere around the source, He-3
                // tube offset in +x; ROI = the tube.
                let r_mod = (d as f32) * 0.30;
                for x in 0..d {
                    for y in 0..d {
                        for z in 0..d {
                            let dx = x as f32 - c as f32;
                            let dy = y as f32 - c as f32;
                            let dz = z as f32 - c as f32;
                            if (dx * dx + dy * dy + dz * dz).sqrt() < r_mod {
                                grid[at(d, x, y, z)] = Material::Polyethylene as i32;
                            }
                        }
                    }
                }
                // Tube embedded at the moderator boundary (thermalized
                // neutrons leak into it), spanning a d/2 column.
                let tube_x = (c as f32 + r_mod) as usize;
                for y in c.saturating_sub(2)..=(c + 2).min(d - 1) {
                    for z in d / 4..(3 * d) / 4 {
                        for x in tube_x.saturating_sub(1)..(tube_x + 2).min(d) {
                            grid[at(d, x, y, z)] = Material::He3 as i32;
                            roi[at(d, x, y, z)] = 1.0;
                        }
                    }
                }
                source_origin = [c as f32, c as f32, c as f32];
                source = SourceKind::Neutron(src);
            }
            WorkloadKind::GammaHpge(iso) => {
                // HPGe crystal block offset from a bare point source.
                let gx0 = (d * 5) / 8;
                let gx1 = (d * 7) / 8;
                for x in gx0..gx1 {
                    for y in d / 3..(2 * d) / 3 {
                        for z in d / 3..(2 * d) / 3 {
                            grid[at(d, x, y, z)] = Material::Germanium as i32;
                            roi[at(d, x, y, z)] = 1.0;
                        }
                    }
                }
                source_origin = [(d / 8) as f32, c as f32, c as f32];
                source = SourceKind::Gamma(iso);
            }
        }

        Workload {
            kind,
            grid,
            roi,
            source_origin,
            source,
        }
    }

    /// Voxels inside the detector ROI.
    pub fn roi_voxels(&self) -> usize {
        self.roi.iter().filter(|&&v| v > 0.5).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_nine_workloads_build() {
        let all = WorkloadKind::all();
        assert_eq!(all.len(), 9);
        for kind in all {
            let w = Workload::build(kind, 16);
            assert_eq!(w.grid.len(), 16 * 16 * 16);
            assert!(w.roi_voxels() > 0, "{kind:?} has an empty ROI");
            // Source must sit inside the world.
            for c in w.source_origin {
                assert!((0.0..16.0).contains(&c), "{kind:?} source outside world");
            }
            // Labels unique.
        }
        let labels: std::collections::HashSet<String> =
            WorkloadKind::all().iter().map(|k| k.label()).collect();
        assert_eq!(labels.len(), 9);
    }

    #[test]
    fn water_phantom_is_all_water() {
        let w = Workload::build(WorkloadKind::WaterPhantom, 16);
        assert!(w.grid.iter().all(|&m| m == Material::Water as i32));
    }

    #[test]
    fn sandwich_alternates() {
        let w = Workload::build(WorkloadKind::HadronSandwich, 16);
        let mats: std::collections::HashSet<i32> = w.grid.iter().copied().collect();
        assert!(mats.contains(&(Material::Tungsten as i32)));
        assert!(mats.contains(&(Material::Scintillator as i32)));
    }

    #[test]
    fn he3_roi_is_he3_material() {
        let w = Workload::build(WorkloadKind::NeutronHe3(NeutronSource::Cf252), 16);
        for (i, &r) in w.roi.iter().enumerate() {
            if r > 0.5 {
                assert_eq!(w.grid[i], Material::He3 as i32, "ROI voxel {i} not He-3");
            }
        }
    }

    #[test]
    fn geometry_deterministic() {
        let a = Workload::build(WorkloadKind::EmCalorimeter, 16);
        let b = Workload::build(WorkloadKind::EmCalorimeter, 16);
        assert_eq!(a.grid, b.grid);
        assert_eq!(a.roi, b.roi);
    }
}
