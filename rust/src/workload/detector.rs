//! Detector readout: turn scoring grids into physical measurements.

use crate::workload::workloads::{SourceKind, Workload};

/// One detector measurement (derived from the edep grid + ROI).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DetectorReading {
    /// Energy deposited inside the ROI (MeV).
    pub roi_edep_mev: f32,
    /// Total energy deposited anywhere (MeV).
    pub total_edep_mev: f32,
    /// Voxels with any deposit.
    pub hit_voxels: u32,
    /// Detector counts (energy / mean energy-per-count for the detector
    /// technology).
    pub counts: u64,
    /// ROI fraction of total deposit (geometry+capture efficiency proxy).
    pub efficiency: f32,
}

/// Mean deposited energy per recorded count (MeV) for each detector
/// technology — He-3 tubes count captures (~0.764 MeV Q-value per capture);
/// HPGe and scintillator readouts are binned at far finer granularity.
pub fn energy_per_count(workload: &Workload) -> f32 {
    match workload.source {
        SourceKind::Neutron(_) => 0.764, // He-3(n,p) Q-value
        SourceKind::Gamma(_) => 0.001,   // HPGe ~keV-scale bins
        SourceKind::Beam(_) => 0.01,     // calorimeter cell threshold
    }
}

/// Build a reading from `score_roi` outputs.
pub fn reading(
    workload: &Workload,
    roi_edep: f32,
    total_edep: f32,
    hit_voxels: f32,
) -> DetectorReading {
    let epc = energy_per_count(workload);
    DetectorReading {
        roi_edep_mev: roi_edep,
        total_edep_mev: total_edep,
        hit_voxels: hit_voxels as u32,
        counts: (roi_edep / epc) as u64,
        efficiency: if total_edep > 0.0 {
            roi_edep / total_edep
        } else {
            0.0
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::spectra::NeutronSource;
    use crate::workload::workloads::WorkloadKind;

    #[test]
    fn reading_derivation() {
        let w = Workload::build(WorkloadKind::NeutronHe3(NeutronSource::AmBe), 16);
        let r = reading(&w, 7.64, 100.0, 42.0);
        assert_eq!(r.counts, 10); // 7.64 / 0.764
        assert!((r.efficiency - 0.0764).abs() < 1e-4);
        assert_eq!(r.hit_voxels, 42);
    }

    #[test]
    fn zero_total_is_safe() {
        let w = Workload::build(WorkloadKind::WaterPhantom, 16);
        let r = reading(&w, 0.0, 0.0, 0.0);
        assert_eq!(r.efficiency, 0.0);
        assert_eq!(r.counts, 0);
    }
}
