//! The Geant4-analog application layer.
//!
//! Everything the paper ran under checkpoint-restart, rebuilt on the
//! transport engine: release-versioned physics tables ([`geant4`]), the
//! nine §VI evaluation workloads ([`workloads`]), calibration-source
//! spectra ([`spectra`]), detector readout ([`detector`]), and the
//! checkpointable state + worker loop that connect the compute to the
//! DMTCP layer ([`state`]).

pub mod cp2k;
pub mod detector;
pub mod geant4;
pub mod spectra;
pub mod state;
pub mod stencil;
pub mod workloads;

pub use cp2k::{cp2k_worker, Cp2kApp, Cp2kScratchPlugin, Cp2kState, CP2K_SCF_LABEL};
pub use stencil::{
    reference_final_states, stencil_worker, Fabric, HaloDrainPlugin, HaloMsg, Side, StencilApp,
    StencilState, STENCIL_LABEL,
};
pub use detector::{reading, DetectorReading};
pub use geant4::{static_inputs, xs_table, G4Version, Material, N_MATERIALS};
pub use spectra::{Beam, GammaIsotope, NeutronSource};
pub use state::{transport_worker, G4App, G4SimState};
pub use workloads::{SourceKind, Workload, WorkloadKind};
