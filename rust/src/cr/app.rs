//! The workload-facing side of the C/R layer: the [`CrApp`] trait.
//!
//! DMTCP's design argument is that checkpoint-restart is *transparent* —
//! it wraps any process, whatever it computes. The session orchestration
//! ([`crate::cr::session::CrSession`]) mirrors that: it drives anything
//! implementing `CrApp`, which is the minimal contract a workload needs to
//! expose — mint a fresh state, mint a shell for restart to restore into,
//! spawn the worker threads that advance it, report progress, and verify a
//! final state against an uninterrupted reference run.
//!
//! Both paper workloads implement it: the Geant4-analog transport
//! ([`G4App`]) and the CP2K-analog SCF driver ([`Cp2kApp`], §VII),
//! including the latter's scratch-path restart fix. Any user state that is
//! [`Checkpointable`] can join them (the integration suite drives a plain
//! LCG chain through the same orchestration).

#![deny(missing_docs)]

use std::fmt::Debug;
use std::sync::{Arc, Mutex};

use crate::dmtcp::mana::ReinitFn;
use crate::dmtcp::process::Checkpointable;
use crate::dmtcp::{LaunchedProcess, PluginRegistry};
use crate::error::{Error, Result};
use crate::runtime::service;
use crate::workload::cp2k::{cp2k_worker, Cp2kApp, Cp2kScratchPlugin, Cp2kState};
use crate::workload::stencil::{
    reference_final_states, stencil_worker, HaloDrainPlugin, StencilApp, StencilState,
    STENCIL_LABEL,
};
use crate::workload::{transport_worker, G4App, G4SimState};

/// A workload the C/R layer can orchestrate.
///
/// Implementors own whatever compute resources they need (the Geant4
/// implementation serves through the shared [`crate::runtime`] service;
/// the CP2K driver is self-contained) so the session stays
/// workload-generic.
pub trait CrApp {
    /// The checkpointable application state this workload advances.
    type State: Checkpointable + Clone + PartialEq + Debug + Send + 'static;

    /// Stable label used in process names, image file names and job ids.
    fn label(&self) -> String;

    /// Mint the state a fresh (incarnation-0) job starts from.
    fn fresh_state(&self, target_steps: u64, seed: u64) -> Result<Self::State>;

    /// Mint an empty shell for `dmtcp_restart` to restore an image into.
    fn restore_state(&self) -> Self::State;

    /// Register workload-specific DMTCP plugins (e.g. the CP2K scratch-path
    /// fix). Called before launch *and* before restart, so `PostRestart`
    /// hooks fire ahead of the state restore.
    fn register_plugins(&self, _state: &Arc<Mutex<Self::State>>, _plugins: &mut PluginRegistry) {}

    /// Spawn the worker threads that advance `state` under `launched`.
    /// `work_per_quantum` is the work quantum between checkpoint
    /// safe-points (scans for transport, sweeps for SCF).
    fn spawn_workers(
        &self,
        launched: &mut LaunchedProcess,
        state: Arc<Mutex<Self::State>>,
        n_threads: u32,
        work_per_quantum: u32,
    ) -> Result<()>;

    /// Whether the workload reached its goal.
    fn done(&self, state: &Self::State) -> bool;

    /// Progress toward the goal in `[0, 1]`.
    fn progress(&self, state: &Self::State) -> f64;

    /// Verify `final_state` bitwise against an uninterrupted reference run
    /// with the same `(target_steps, seed)`. `Err` on any divergence —
    /// this is the paper's robustness claim as a method.
    fn verify_final(
        &self,
        final_state: &Self::State,
        target_steps: u64,
        seed: u64,
    ) -> Result<()>;
}

/// A *distributed* workload the gang C/R layer can orchestrate: N
/// communicating ranks advancing one computation, checkpointed through a
/// single all-or-nothing barrier and restarted as a set.
///
/// The contract extends [`CrApp`]'s shape to the multi-rank case:
///
/// * per-rank states (fresh and restore-shell), worker spawns, and plugin
///   registration — one process per rank;
/// * an incarnation hook ([`GangApp::begin_incarnation`]) where the app
///   rebuilds its incarnation-scoped communication plane (the MANA lower
///   half: endpoints, transports) before any rank launches or restarts;
/// * a MANA `reinit` closure per rank ([`GangApp::reinit_fn`]), run after
///   a rank's upper half is restored, that re-attaches the rank to the
///   *current* incarnation's plane;
/// * gang-level completion and bitwise verification over the full rank
///   vector — a gang is done when every rank is, and correct only if every
///   rank matches the uninterrupted reference.
pub trait GangApp {
    /// The checkpointable per-rank state.
    type RankState: Checkpointable + Clone + PartialEq + Debug + Send + 'static;

    /// Stable label used in process names, image file names and job ids.
    fn label(&self) -> String;

    /// Gang width (fixed for the life of the computation — gang restart
    /// is rank-count-preserving).
    fn n_ranks(&self) -> u32;

    /// Rebuild the incarnation-scoped communication plane for restart
    /// generation `generation`. Called by the session at every boot,
    /// before any rank launches or restores.
    fn begin_incarnation(&self, generation: u32);

    /// Mint rank `rank`'s state for a fresh (generation-0) gang.
    fn fresh_rank_state(&self, rank: u32, target_steps: u64, seed: u64)
        -> Result<Self::RankState>;

    /// Mint rank `rank`'s empty shell for `dmtcp_restart` to restore into.
    fn restore_rank_state(&self, rank: u32) -> Self::RankState;

    /// Register rank-specific DMTCP plugins (e.g. the DRAIN-phase message
    /// drain). Called before launch *and* before restart.
    fn register_rank_plugins(
        &self,
        _rank: u32,
        _state: &Arc<Mutex<Self::RankState>>,
        _plugins: &mut PluginRegistry,
    ) {
    }

    /// The MANA lower-half rebuild hook for rank `rank`: runs right after
    /// the rank's segments are restored, against the *current*
    /// incarnation's plane. Must be `'static` (it is installed into the
    /// rank's [`crate::dmtcp::ManaState`] wrapper), so capture shared
    /// handles, not `&self`.
    fn reinit_fn(&self, rank: u32) -> ReinitFn<Self::RankState>;

    /// Spawn rank `rank`'s worker threads under `launched`.
    fn spawn_rank_workers(
        &self,
        rank: u32,
        launched: &mut LaunchedProcess,
        state: Arc<Mutex<Self::RankState>>,
        work_per_quantum: u32,
    ) -> Result<()>;

    /// Whether one rank reached its goal (the gang is done when all are).
    fn rank_done(&self, state: &Self::RankState) -> bool;

    /// Verify the full rank vector bitwise against an uninterrupted
    /// reference run of the same `(target_steps, seed)`.
    fn verify_final(
        &self,
        finals: &[Self::RankState],
        target_steps: u64,
        seed: u64,
    ) -> Result<()>;
}

/// Gang sessions borrow apps freely too.
impl<A: GangApp + ?Sized> GangApp for &A {
    type RankState = A::RankState;

    fn label(&self) -> String {
        (**self).label()
    }

    fn n_ranks(&self) -> u32 {
        (**self).n_ranks()
    }

    fn begin_incarnation(&self, generation: u32) {
        (**self).begin_incarnation(generation)
    }

    fn fresh_rank_state(
        &self,
        rank: u32,
        target_steps: u64,
        seed: u64,
    ) -> Result<Self::RankState> {
        (**self).fresh_rank_state(rank, target_steps, seed)
    }

    fn restore_rank_state(&self, rank: u32) -> Self::RankState {
        (**self).restore_rank_state(rank)
    }

    fn register_rank_plugins(
        &self,
        rank: u32,
        state: &Arc<Mutex<Self::RankState>>,
        plugins: &mut PluginRegistry,
    ) {
        (**self).register_rank_plugins(rank, state, plugins)
    }

    fn reinit_fn(&self, rank: u32) -> ReinitFn<Self::RankState> {
        (**self).reinit_fn(rank)
    }

    fn spawn_rank_workers(
        &self,
        rank: u32,
        launched: &mut LaunchedProcess,
        state: Arc<Mutex<Self::RankState>>,
        work_per_quantum: u32,
    ) -> Result<()> {
        (**self).spawn_rank_workers(rank, launched, state, work_per_quantum)
    }

    fn rank_done(&self, state: &Self::RankState) -> bool {
        (**self).rank_done(state)
    }

    fn verify_final(
        &self,
        finals: &[Self::RankState],
        target_steps: u64,
        seed: u64,
    ) -> Result<()> {
        (**self).verify_final(finals, target_steps, seed)
    }
}

/// The halo-exchange stencil gang (the distributed workload of DESIGN
/// §10): per-rank slabs, real in-flight halo messages drained at the
/// DRAIN phase, and an incarnation-scoped fabric rebuilt through the MANA
/// reinit hook.
impl GangApp for StencilApp {
    type RankState = StencilState;

    fn label(&self) -> String {
        STENCIL_LABEL.into()
    }

    fn n_ranks(&self) -> u32 {
        self.n_ranks
    }

    fn begin_incarnation(&self, generation: u32) {
        self.rebuild_fabric(generation);
    }

    fn fresh_rank_state(&self, rank: u32, target_steps: u64, seed: u64) -> Result<StencilState> {
        let mut s =
            StencilState::fresh(rank, self.n_ranks, self.cells_per_rank, target_steps, seed);
        s.endpoints = self.fabric().endpoint_blob(rank);
        Ok(s)
    }

    fn restore_rank_state(&self, rank: u32) -> StencilState {
        StencilState::shell(rank, self.n_ranks)
    }

    fn register_rank_plugins(
        &self,
        rank: u32,
        state: &Arc<Mutex<StencilState>>,
        plugins: &mut PluginRegistry,
    ) {
        plugins.register(Box::new(HaloDrainPlugin {
            rank,
            state: Arc::clone(state),
            fabric: self.fabric(),
        }));
    }

    fn reinit_fn(&self, rank: u32) -> ReinitFn<StencilState> {
        let holder = self.fabric_holder();
        Box::new(move |s: &mut StencilState| {
            let fabric = holder
                .lock()
                .expect("fabric holder poisoned")
                .as_ref()
                .cloned()
                .ok_or_else(|| {
                    Error::Workload("stencil reinit before begin_incarnation".into())
                })?;
            s.endpoints = fabric.endpoint_blob(rank);
            Ok(())
        })
    }

    fn spawn_rank_workers(
        &self,
        _rank: u32,
        launched: &mut LaunchedProcess,
        state: Arc<Mutex<StencilState>>,
        work_per_quantum: u32,
    ) -> Result<()> {
        let fabric = self.fabric();
        launched
            .process
            .spawn_user_thread(move |ctx| stencil_worker(ctx, state, fabric, work_per_quantum));
        Ok(())
    }

    fn rank_done(&self, state: &StencilState) -> bool {
        state.done()
    }

    fn verify_final(&self, finals: &[StencilState], target_steps: u64, seed: u64) -> Result<()> {
        if finals.len() != self.n_ranks as usize {
            return Err(Error::Workload(format!(
                "{STENCIL_LABEL}: {} final states for a {}-rank gang",
                finals.len(),
                self.n_ranks
            )));
        }
        let reference =
            reference_final_states(self.n_ranks, self.cells_per_rank, target_steps, seed);
        for (rank, (got, (cells, step))) in finals.iter().zip(&reference).enumerate() {
            if got.step != *step || &got.cells != cells {
                return Err(Error::Workload(format!(
                    "{STENCIL_LABEL}: rank {rank} is not bit-identical to the \
                     uninterrupted reference ({}/{} steps, digest {:016x})",
                    got.step,
                    step,
                    got.science_digest()
                )));
            }
        }
        Ok(())
    }
}

/// Sessions borrow apps freely: a reference to a `CrApp` is a `CrApp`.
impl<A: CrApp + ?Sized> CrApp for &A {
    type State = A::State;

    fn label(&self) -> String {
        (**self).label()
    }

    fn fresh_state(&self, target_steps: u64, seed: u64) -> Result<Self::State> {
        (**self).fresh_state(target_steps, seed)
    }

    fn restore_state(&self) -> Self::State {
        (**self).restore_state()
    }

    fn register_plugins(&self, state: &Arc<Mutex<Self::State>>, plugins: &mut PluginRegistry) {
        (**self).register_plugins(state, plugins)
    }

    fn spawn_workers(
        &self,
        launched: &mut LaunchedProcess,
        state: Arc<Mutex<Self::State>>,
        n_threads: u32,
        work_per_quantum: u32,
    ) -> Result<()> {
        (**self).spawn_workers(launched, state, n_threads, work_per_quantum)
    }

    fn done(&self, state: &Self::State) -> bool {
        (**self).done(state)
    }

    fn progress(&self, state: &Self::State) -> f64 {
        (**self).progress(state)
    }

    fn verify_final(
        &self,
        final_state: &Self::State,
        target_steps: u64,
        seed: u64,
    ) -> Result<()> {
        (**self).verify_final(final_state, target_steps, seed)
    }
}

/// The Geant4-analog transport workload, served through the shared compute
/// service (`runtime::service::shared`). Worker threads run
/// [`transport_worker`]; the batch size comes from the engine manifest.
impl CrApp for G4App {
    type State = G4SimState;

    fn label(&self) -> String {
        format!("g4-{}", self.kind.label())
    }

    fn fresh_state(&self, target_steps: u64, seed: u64) -> Result<G4SimState> {
        let h = service::shared()?;
        let batch = h.manifest().batch;
        Ok(G4App::fresh_state(self, batch, target_steps, seed))
    }

    fn restore_state(&self) -> G4SimState {
        self.shell_state()
    }

    fn spawn_workers(
        &self,
        launched: &mut LaunchedProcess,
        state: Arc<Mutex<G4SimState>>,
        n_threads: u32,
        work_per_quantum: u32,
    ) -> Result<()> {
        let h = service::shared()?;
        for _ in 0..n_threads.max(1) {
            let (st, hh, si) = (Arc::clone(&state), h.clone(), Arc::clone(&self.si));
            launched
                .process
                .spawn_user_thread(move |ctx| transport_worker(ctx, hh, st, si, work_per_quantum));
        }
        Ok(())
    }

    fn done(&self, state: &G4SimState) -> bool {
        state.done()
    }

    fn progress(&self, state: &G4SimState) -> f64 {
        state.progress()
    }

    fn verify_final(&self, final_state: &G4SimState, target_steps: u64, seed: u64) -> Result<()> {
        let h = service::shared()?;
        let m = h.manifest().clone();
        let mut reference = G4App::fresh_state(self, m.batch, target_steps, seed);
        let scans = target_steps.div_ceil(m.scan_steps as u64) as u32;
        reference.particles = h.scan(reference.particles, &self.si, scans)?;
        if final_state.particles != reference.particles {
            return Err(Error::Workload(format!(
                "{}: final state is not bit-identical to the uninterrupted reference",
                CrApp::label(self)
            )));
        }
        Ok(())
    }
}

/// The CP2K-analog SCF workload (§VII), self-contained (no compute
/// service). With [`Cp2kApp::scratch_fix`] on, the scratch-path plugin is
/// registered so restart works; with it off, the paper's known restart
/// defect reproduces through the full C/R stack.
impl CrApp for Cp2kApp {
    type State = Cp2kState;

    fn label(&self) -> String {
        crate::workload::cp2k::CP2K_SCF_LABEL.into()
    }

    fn fresh_state(&self, target_steps: u64, _seed: u64) -> Result<Cp2kState> {
        Ok(Cp2kState::new(self.n, target_steps, Cp2kApp::next_scratch_pid()))
    }

    fn restore_state(&self) -> Cp2kState {
        // Target/field come from the image; a *new* incarnation pid makes
        // the recorded scratch path dangle — the defect the plugin fixes.
        Cp2kState::new(self.n, 0, Cp2kApp::next_scratch_pid())
    }

    fn register_plugins(&self, state: &Arc<Mutex<Cp2kState>>, plugins: &mut PluginRegistry) {
        if self.scratch_fix {
            plugins.register(Box::new(Cp2kScratchPlugin {
                state: Arc::clone(state),
            }));
        }
    }

    fn spawn_workers(
        &self,
        launched: &mut LaunchedProcess,
        state: Arc<Mutex<Cp2kState>>,
        n_threads: u32,
        work_per_quantum: u32,
    ) -> Result<()> {
        let pause = self.sweep_pause;
        for _ in 0..n_threads.max(1) {
            let st = Arc::clone(&state);
            launched
                .process
                .spawn_user_thread(move |ctx| cp2k_worker(ctx, st, work_per_quantum, pause));
        }
        Ok(())
    }

    fn done(&self, state: &Cp2kState) -> bool {
        state.done()
    }

    fn progress(&self, state: &Cp2kState) -> f64 {
        state.iterations as f64 / state.target_iterations.max(1) as f64
    }

    fn verify_final(&self, final_state: &Cp2kState, target_steps: u64, _seed: u64) -> Result<()> {
        // The SCF iteration is deterministic and pid-independent; drive a
        // fresh problem to the same target and compare the field bitwise.
        let mut reference = Cp2kState::new(self.n, target_steps, 0);
        while !reference.done() {
            reference.iterate();
        }
        if final_state.iterations != reference.iterations
            || final_state.digest() != reference.digest()
            || final_state.residuals != reference.residuals
        {
            return Err(Error::Workload(format!(
                "cp2k-scf: final state differs from the uninterrupted reference \
                 ({}/{} iterations, digest {:016x} vs {:016x})",
                final_state.iterations,
                reference.iterations,
                final_state.digest(),
                reference.digest()
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{G4Version, WorkloadKind};

    #[test]
    fn g4_app_trait_surface() {
        let app = G4App::build(WorkloadKind::WaterPhantom, G4Version::V10_7, 16);
        assert_eq!(CrApp::label(&app), "g4-water-phantom");
        let s = CrApp::fresh_state(&app, 64, 3).unwrap();
        assert!(!CrApp::done(&app, &s));
        assert_eq!(CrApp::progress(&app, &s), 0.0);
        // The blanket impl forwards.
        let by_ref: &G4App = &app;
        assert_eq!(CrApp::label(&by_ref), "g4-water-phantom");
    }

    #[test]
    fn cp2k_app_verifies_its_own_reference() {
        let app = Cp2kApp::new(12);
        let mut s = CrApp::fresh_state(&app, 40, 0).unwrap();
        while !s.done() {
            s.iterate();
        }
        CrApp::verify_final(&app, &s, 40, 0).unwrap();
        // A diverged state is rejected.
        s.field[5] += 1.0;
        assert!(CrApp::verify_final(&app, &s, 40, 0).is_err());
    }

    #[test]
    fn stencil_gang_app_trait_surface() {
        let app = StencilApp::new(3, 4).endpoint_bytes(128);
        assert_eq!(GangApp::label(&app), "halo-stencil");
        assert_eq!(GangApp::n_ranks(&app), 3);
        app.begin_incarnation(0);
        let s = GangApp::fresh_rank_state(&app, 1, 10, 7).unwrap();
        assert!(!GangApp::rank_done(&app, &s));
        assert_eq!(s.endpoints.len(), 128, "fresh state carries the lower half");
        // reinit rebuilds endpoints against the *current* incarnation.
        let blob0 = s.endpoints.clone();
        app.begin_incarnation(1);
        let mut shell = GangApp::restore_rank_state(&app, 1);
        (GangApp::reinit_fn(&app, 1))(&mut shell).unwrap();
        assert_eq!(shell.endpoints.len(), 128);
        assert_ne!(shell.endpoints, blob0, "new incarnation, new endpoints");
        // The blanket impl forwards.
        let by_ref: &StencilApp = &app;
        assert_eq!(GangApp::label(&by_ref), "halo-stencil");
    }

    #[test]
    fn stencil_verify_rejects_divergence() {
        let app = StencilApp::new(2, 4);
        let finals: Vec<StencilState> =
            crate::workload::reference_final_states(2, 4, 6, 9)
                .into_iter()
                .enumerate()
                .map(|(r, (cells, step))| {
                    let mut s = StencilState::shell(r as u32, 2);
                    s.cells = cells;
                    s.step = step;
                    s.target_steps = 6;
                    s
                })
                .collect();
        GangApp::verify_final(&app, &finals, 6, 9).unwrap();
        let mut bad = finals.clone();
        bad[1].cells[0] ^= 1;
        assert!(GangApp::verify_final(&app, &bad, 6, 9).is_err());
        assert!(GangApp::verify_final(&app, &finals[..1], 6, 9).is_err());
    }

    #[test]
    fn cp2k_restore_state_gets_fresh_scratch_pid() {
        let app = Cp2kApp::new(8);
        let a = CrApp::restore_state(&app);
        let b = CrApp::restore_state(&app);
        assert_ne!(a.scratch_path, b.scratch_path);
    }
}
