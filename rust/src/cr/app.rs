//! The workload-facing side of the C/R layer: the [`CrApp`] trait.
//!
//! DMTCP's design argument is that checkpoint-restart is *transparent* —
//! it wraps any process, whatever it computes. The session orchestration
//! ([`crate::cr::session::CrSession`]) mirrors that: it drives anything
//! implementing `CrApp`, which is the minimal contract a workload needs to
//! expose — mint a fresh state, mint a shell for restart to restore into,
//! spawn the worker threads that advance it, report progress, and verify a
//! final state against an uninterrupted reference run.
//!
//! Both paper workloads implement it: the Geant4-analog transport
//! ([`G4App`]) and the CP2K-analog SCF driver ([`Cp2kApp`], §VII),
//! including the latter's scratch-path restart fix. Any user state that is
//! [`Checkpointable`] can join them (the integration suite drives a plain
//! LCG chain through the same orchestration).

#![deny(missing_docs)]

use std::fmt::Debug;
use std::sync::{Arc, Mutex};

use crate::dmtcp::process::Checkpointable;
use crate::dmtcp::{LaunchedProcess, PluginRegistry};
use crate::error::{Error, Result};
use crate::runtime::service;
use crate::workload::cp2k::{cp2k_worker, Cp2kApp, Cp2kScratchPlugin, Cp2kState};
use crate::workload::{transport_worker, G4App, G4SimState};

/// A workload the C/R layer can orchestrate.
///
/// Implementors own whatever compute resources they need (the Geant4
/// implementation serves through the shared [`crate::runtime`] service;
/// the CP2K driver is self-contained) so the session stays
/// workload-generic.
pub trait CrApp {
    /// The checkpointable application state this workload advances.
    type State: Checkpointable + Clone + PartialEq + Debug + Send + 'static;

    /// Stable label used in process names, image file names and job ids.
    fn label(&self) -> String;

    /// Mint the state a fresh (incarnation-0) job starts from.
    fn fresh_state(&self, target_steps: u64, seed: u64) -> Result<Self::State>;

    /// Mint an empty shell for `dmtcp_restart` to restore an image into.
    fn restore_state(&self) -> Self::State;

    /// Register workload-specific DMTCP plugins (e.g. the CP2K scratch-path
    /// fix). Called before launch *and* before restart, so `PostRestart`
    /// hooks fire ahead of the state restore.
    fn register_plugins(&self, _state: &Arc<Mutex<Self::State>>, _plugins: &mut PluginRegistry) {}

    /// Spawn the worker threads that advance `state` under `launched`.
    /// `work_per_quantum` is the work quantum between checkpoint
    /// safe-points (scans for transport, sweeps for SCF).
    fn spawn_workers(
        &self,
        launched: &mut LaunchedProcess,
        state: Arc<Mutex<Self::State>>,
        n_threads: u32,
        work_per_quantum: u32,
    ) -> Result<()>;

    /// Whether the workload reached its goal.
    fn done(&self, state: &Self::State) -> bool;

    /// Progress toward the goal in `[0, 1]`.
    fn progress(&self, state: &Self::State) -> f64;

    /// Verify `final_state` bitwise against an uninterrupted reference run
    /// with the same `(target_steps, seed)`. `Err` on any divergence —
    /// this is the paper's robustness claim as a method.
    fn verify_final(
        &self,
        final_state: &Self::State,
        target_steps: u64,
        seed: u64,
    ) -> Result<()>;
}

/// Sessions borrow apps freely: a reference to a `CrApp` is a `CrApp`.
impl<A: CrApp + ?Sized> CrApp for &A {
    type State = A::State;

    fn label(&self) -> String {
        (**self).label()
    }

    fn fresh_state(&self, target_steps: u64, seed: u64) -> Result<Self::State> {
        (**self).fresh_state(target_steps, seed)
    }

    fn restore_state(&self) -> Self::State {
        (**self).restore_state()
    }

    fn register_plugins(&self, state: &Arc<Mutex<Self::State>>, plugins: &mut PluginRegistry) {
        (**self).register_plugins(state, plugins)
    }

    fn spawn_workers(
        &self,
        launched: &mut LaunchedProcess,
        state: Arc<Mutex<Self::State>>,
        n_threads: u32,
        work_per_quantum: u32,
    ) -> Result<()> {
        (**self).spawn_workers(launched, state, n_threads, work_per_quantum)
    }

    fn done(&self, state: &Self::State) -> bool {
        (**self).done(state)
    }

    fn progress(&self, state: &Self::State) -> f64 {
        (**self).progress(state)
    }

    fn verify_final(
        &self,
        final_state: &Self::State,
        target_steps: u64,
        seed: u64,
    ) -> Result<()> {
        (**self).verify_final(final_state, target_steps, seed)
    }
}

/// The Geant4-analog transport workload, served through the shared compute
/// service (`runtime::service::shared`). Worker threads run
/// [`transport_worker`]; the batch size comes from the engine manifest.
impl CrApp for G4App {
    type State = G4SimState;

    fn label(&self) -> String {
        format!("g4-{}", self.kind.label())
    }

    fn fresh_state(&self, target_steps: u64, seed: u64) -> Result<G4SimState> {
        let h = service::shared()?;
        let batch = h.manifest().batch;
        Ok(G4App::fresh_state(self, batch, target_steps, seed))
    }

    fn restore_state(&self) -> G4SimState {
        self.shell_state()
    }

    fn spawn_workers(
        &self,
        launched: &mut LaunchedProcess,
        state: Arc<Mutex<G4SimState>>,
        n_threads: u32,
        work_per_quantum: u32,
    ) -> Result<()> {
        let h = service::shared()?;
        for _ in 0..n_threads.max(1) {
            let (st, hh, si) = (Arc::clone(&state), h.clone(), Arc::clone(&self.si));
            launched
                .process
                .spawn_user_thread(move |ctx| transport_worker(ctx, hh, st, si, work_per_quantum));
        }
        Ok(())
    }

    fn done(&self, state: &G4SimState) -> bool {
        state.done()
    }

    fn progress(&self, state: &G4SimState) -> f64 {
        state.progress()
    }

    fn verify_final(&self, final_state: &G4SimState, target_steps: u64, seed: u64) -> Result<()> {
        let h = service::shared()?;
        let m = h.manifest().clone();
        let mut reference = G4App::fresh_state(self, m.batch, target_steps, seed);
        let scans = target_steps.div_ceil(m.scan_steps as u64) as u32;
        reference.particles = h.scan(reference.particles, &self.si, scans)?;
        if final_state.particles != reference.particles {
            return Err(Error::Workload(format!(
                "{}: final state is not bit-identical to the uninterrupted reference",
                CrApp::label(self)
            )));
        }
        Ok(())
    }
}

/// The CP2K-analog SCF workload (§VII), self-contained (no compute
/// service). With [`Cp2kApp::scratch_fix`] on, the scratch-path plugin is
/// registered so restart works; with it off, the paper's known restart
/// defect reproduces through the full C/R stack.
impl CrApp for Cp2kApp {
    type State = Cp2kState;

    fn label(&self) -> String {
        crate::workload::cp2k::CP2K_SCF_LABEL.into()
    }

    fn fresh_state(&self, target_steps: u64, _seed: u64) -> Result<Cp2kState> {
        Ok(Cp2kState::new(self.n, target_steps, Cp2kApp::next_scratch_pid()))
    }

    fn restore_state(&self) -> Cp2kState {
        // Target/field come from the image; a *new* incarnation pid makes
        // the recorded scratch path dangle — the defect the plugin fixes.
        Cp2kState::new(self.n, 0, Cp2kApp::next_scratch_pid())
    }

    fn register_plugins(&self, state: &Arc<Mutex<Cp2kState>>, plugins: &mut PluginRegistry) {
        if self.scratch_fix {
            plugins.register(Box::new(Cp2kScratchPlugin {
                state: Arc::clone(state),
            }));
        }
    }

    fn spawn_workers(
        &self,
        launched: &mut LaunchedProcess,
        state: Arc<Mutex<Cp2kState>>,
        n_threads: u32,
        work_per_quantum: u32,
    ) -> Result<()> {
        let pause = self.sweep_pause;
        for _ in 0..n_threads.max(1) {
            let st = Arc::clone(&state);
            launched
                .process
                .spawn_user_thread(move |ctx| cp2k_worker(ctx, st, work_per_quantum, pause));
        }
        Ok(())
    }

    fn done(&self, state: &Cp2kState) -> bool {
        state.done()
    }

    fn progress(&self, state: &Cp2kState) -> f64 {
        state.iterations as f64 / state.target_iterations.max(1) as f64
    }

    fn verify_final(&self, final_state: &Cp2kState, target_steps: u64, _seed: u64) -> Result<()> {
        // The SCF iteration is deterministic and pid-independent; drive a
        // fresh problem to the same target and compare the field bitwise.
        let mut reference = Cp2kState::new(self.n, target_steps, 0);
        while !reference.done() {
            reference.iterate();
        }
        if final_state.iterations != reference.iterations
            || final_state.digest() != reference.digest()
            || final_state.residuals != reference.residuals
        {
            return Err(Error::Workload(format!(
                "cp2k-scf: final state differs from the uninterrupted reference \
                 ({}/{} iterations, digest {:016x} vs {:016x})",
                final_state.iterations,
                reference.iterations,
                final_state.digest(),
                reference.digest()
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{G4Version, WorkloadKind};

    #[test]
    fn g4_app_trait_surface() {
        let app = G4App::build(WorkloadKind::WaterPhantom, G4Version::V10_7, 16);
        assert_eq!(CrApp::label(&app), "g4-water-phantom");
        let s = CrApp::fresh_state(&app, 64, 3).unwrap();
        assert!(!CrApp::done(&app, &s));
        assert_eq!(CrApp::progress(&app, &s), 0.0);
        // The blanket impl forwards.
        let by_ref: &G4App = &app;
        assert_eq!(CrApp::label(&by_ref), "g4-water-phantom");
    }

    #[test]
    fn cp2k_app_verifies_its_own_reference() {
        let app = Cp2kApp::new(12);
        let mut s = CrApp::fresh_state(&app, 40, 0).unwrap();
        while !s.done() {
            s.iterate();
        }
        CrApp::verify_final(&app, &s, 40, 0).unwrap();
        // A diverged state is rejected.
        s.field[5] += 1.0;
        assert!(CrApp::verify_final(&app, &s, 40, 0).is_err());
    }

    #[test]
    fn cp2k_restore_state_gets_fresh_scratch_pid() {
        let app = Cp2kApp::new(8);
        let a = CrApp::restore_state(&app);
        let b = CrApp::restore_state(&app);
        assert_ne!(a.scratch_path, b.scratch_path);
    }
}
