//! The CR Module (`nersc_cr`) — the paper's §V.A primitives.
//!
//! "the CR Module (nersc_cr) ... includes a pivotal function,
//! `start_coordinator`, which activates the checkpointing mechanism via the
//! `dmtcp_coordinator` command. It sets the necessary environment variables
//! for the coordinator's communication and manages the
//! `dmtcp_command.<jobid>` file."

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use crate::dmtcp::daemon::CoordinatorDaemon;
use crate::dmtcp::store::ChunkerSpec;
use crate::dmtcp::{Coordinator, CoordinatorConfig};
use crate::error::Result;

/// CR-module configuration for one job.
#[derive(Debug, Clone)]
pub struct CrConfig {
    /// Slurm job id (names the rendezvous file).
    pub jobid: String,
    /// Where checkpoint images are written (must survive the job — on a
    /// shared filesystem or a volume-mapped host dir when containerized).
    pub ckpt_dir: PathBuf,
    /// Working directory for `dmtcp_command.<jobid>`.
    pub workdir: PathBuf,
    /// gzip images (NERSC default on).
    pub gzip: bool,
    /// Write incremental (v2, content-addressed) checkpoint images: only
    /// chunks whose content changed since the previous generation hit the
    /// disk. Off = v1 full images every time (the paper's baseline).
    pub incremental: bool,
    /// With `incremental`, force every Nth checkpoint back to a
    /// self-contained v1 full image (0 = never force).
    pub full_image_every: u32,
    /// With `incremental`, how segments split into chunks (fixed-size or
    /// content-defined; exported as `DMTCP_CHUNKER`).
    pub chunker: ChunkerSpec,
    /// Barrier timeout.
    pub phase_timeout: Duration,
}

impl CrConfig {
    /// Standard configuration for one job: checkpoints under
    /// `<workdir>/ckpt`, gzip on, 30 s barrier timeout.
    pub fn new(jobid: impl Into<String>, workdir: impl Into<PathBuf>) -> Self {
        let workdir: PathBuf = workdir.into();
        Self {
            jobid: jobid.into(),
            ckpt_dir: workdir.join("ckpt"),
            workdir,
            gzip: true,
            incremental: false,
            full_image_every: 0,
            chunker: ChunkerSpec::Fixed,
            phase_timeout: Duration::from_secs(30),
        }
    }
}

/// How a session obtains its coordinator: boot a private daemon (the
/// default, one coordinator per job — the paper's deployment) or attach
/// the job to a long-lived shared [`CoordinatorDaemon`] so whole fleets
/// multiplex over one port with O(1) coordinator threads.
#[derive(Clone, Default)]
pub enum CoordinatorHandle {
    /// Boot a private daemon for this job (the per-session default).
    #[default]
    Private,
    /// Register the job on this shared multi-tenant daemon.
    Shared(Arc<CoordinatorDaemon>),
}

impl CoordinatorHandle {
    /// Start (or attach) the coordinator for `config`'s job and return it
    /// with the environment its processes must inherit.
    pub fn start(&self, config: &CrConfig) -> Result<(Coordinator, BTreeMap<String, String>)> {
        match self {
            CoordinatorHandle::Private => start_coordinator(config),
            CoordinatorHandle::Shared(daemon) => start_coordinator_on(daemon, config),
        }
    }
}

impl std::fmt::Debug for CoordinatorHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CoordinatorHandle::Private => write!(f, "Private"),
            CoordinatorHandle::Shared(d) => write!(f, "Shared({})", d.addr()),
        }
    }
}

fn coordinator_config(config: &CrConfig) -> CoordinatorConfig {
    CoordinatorConfig {
        bind: "127.0.0.1:0".into(),
        ckpt_dir: config.ckpt_dir.clone(),
        gzip: config.gzip,
        jobid: Some(config.jobid.clone()),
        command_file_dir: config.workdir.clone(),
        phase_timeout: config.phase_timeout,
        retry_ephemeral: true,
    }
}

/// `start_coordinator`: boot a coordinator for this job, write the
/// rendezvous file, and return it together with the environment variables
/// the job's processes must inherit (`DMTCP_COORD_HOST`, `DMTCP_COORD_PORT`,
/// `DMTCP_JOB`, `DMTCP_CHECKPOINT_DIR`, `DMTCP_GZIP`, and — when
/// incremental images are on — `DMTCP_INCREMENTAL` / `DMTCP_FULL_EVERY`).
pub fn start_coordinator(config: &CrConfig) -> Result<(Coordinator, BTreeMap<String, String>)> {
    let coord = Coordinator::start(coordinator_config(config))?;
    let env = coordinator_env(config, &coord);
    log::info!(
        "start_coordinator: job {} on {} (ckpt dir {})",
        config.jobid,
        coord.addr(),
        config.ckpt_dir.display()
    );
    Ok((coord, env))
}

/// `start_coordinator` against a *shared* multi-tenant daemon: the job is
/// registered on `daemon` instead of booting a private one, and its
/// processes route to it through the `DMTCP_JOB` tag in their Hello
/// handshake. Everything else — rendezvous file, environment contract,
/// teardown — is identical to the private path.
pub fn start_coordinator_on(
    daemon: &Arc<CoordinatorDaemon>,
    config: &CrConfig,
) -> Result<(Coordinator, BTreeMap<String, String>)> {
    let coord = Coordinator::attach(daemon, coordinator_config(config))?;
    let env = coordinator_env(config, &coord);
    log::info!(
        "start_coordinator: job {} attached to shared daemon {} (ckpt dir {})",
        config.jobid,
        coord.addr(),
        config.ckpt_dir.display()
    );
    Ok((coord, env))
}

fn coordinator_env(config: &CrConfig, coord: &Coordinator) -> BTreeMap<String, String> {
    let mut env = BTreeMap::new();
    env.insert("DMTCP_COORD_HOST".into(), coord.addr().ip().to_string());
    env.insert("DMTCP_COORD_PORT".into(), coord.addr().port().to_string());
    env.insert(
        "DMTCP_CHECKPOINT_DIR".into(),
        config.ckpt_dir.to_string_lossy().into_owned(),
    );
    env.insert("DMTCP_GZIP".into(), if config.gzip { "1" } else { "0" }.into());
    if config.incremental {
        env.insert("DMTCP_INCREMENTAL".into(), "1".into());
        if config.full_image_every > 0 {
            env.insert(
                "DMTCP_FULL_EVERY".into(),
                config.full_image_every.to_string(),
            );
        }
        if config.chunker != ChunkerSpec::Fixed {
            env.insert("DMTCP_CHUNKER".into(), config.chunker.to_string());
        }
    }
    env.insert("SLURM_JOB_ID".into(), config.jobid.clone());
    // The daemon-routing tag: each process's Hello carries it so a shared
    // daemon delivers frames to this job's state machine and no other.
    env.insert("DMTCP_JOB".into(), coord.job().to_string());
    env
}

/// Find the newest checkpoint image set in a directory (restart discovery:
/// the manual flow's "file created during the checkpointing phase").
pub fn latest_images(ckpt_dir: &std::path::Path) -> Result<Vec<PathBuf>> {
    let mut images: Vec<(std::time::SystemTime, PathBuf)> = Vec::new();
    if let Ok(entries) = std::fs::read_dir(ckpt_dir) {
        for e in entries.flatten() {
            let p = e.path();
            if p.extension().map(|x| x == "dmtcp").unwrap_or(false) {
                let mtime = e
                    .metadata()
                    .and_then(|m| m.modified())
                    .unwrap_or(std::time::SystemTime::UNIX_EPOCH);
                images.push((mtime, p));
            }
        }
    }
    images.sort();
    Ok(images.into_iter().map(|(_, p)| p).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ncr_crmod_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn start_coordinator_sets_env_and_file() {
        let wd = dir("start");
        let cfg = CrConfig::new("31415", &wd);
        let (coord, env) = start_coordinator(&cfg).unwrap();
        assert_eq!(
            env.get("DMTCP_COORD_PORT").map(String::as_str),
            Some(coord.addr().port().to_string().as_str())
        );
        assert!(env.contains_key("DMTCP_COORD_HOST"));
        assert_eq!(env.get("DMTCP_GZIP").map(String::as_str), Some("1"));
        let f = wd.join("dmtcp_command.31415");
        assert!(f.exists(), "rendezvous file missing");
        let got = crate::dmtcp::command::read_command_file(&f).unwrap();
        assert_eq!(got, coord.addr());
        std::fs::remove_dir_all(&wd).ok();
    }

    #[test]
    fn start_coordinator_on_shares_one_daemon_across_jobs() {
        let wd = dir("shared");
        let daemon = CoordinatorDaemon::start(Default::default()).unwrap();
        let (a, env_a) = start_coordinator_on(&daemon, &CrConfig::new("900001", &wd)).unwrap();
        let (b, env_b) = start_coordinator_on(&daemon, &CrConfig::new("900002", &wd)).unwrap();
        // One daemon, one port, both jobs' env point at it under their own tag.
        assert_eq!(a.addr(), b.addr());
        assert_eq!(a.addr(), daemon.addr());
        assert_eq!(env_a.get("DMTCP_JOB").map(String::as_str), Some("900001"));
        assert_eq!(env_b.get("DMTCP_JOB").map(String::as_str), Some("900002"));
        assert_eq!(daemon.num_jobs(), 2);
        // Per-job rendezvous files, removed per-job on teardown.
        assert!(wd.join("dmtcp_command.900001").exists());
        assert!(wd.join("dmtcp_command.900002").exists());
        drop(a);
        assert!(!wd.join("dmtcp_command.900001").exists());
        assert!(wd.join("dmtcp_command.900002").exists());
        assert_eq!(daemon.num_jobs(), 1);
        drop(b);
        std::fs::remove_dir_all(&wd).ok();
    }

    #[test]
    fn latest_images_ordering() {
        let d = dir("imgs");
        std::fs::write(d.join("a.dmtcp"), b"x").unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        std::fs::write(d.join("b.dmtcp"), b"y").unwrap();
        std::fs::write(d.join("not_an_image.txt"), b"z").unwrap();
        let imgs = latest_images(&d).unwrap();
        assert_eq!(imgs.len(), 2);
        assert!(imgs[1].ends_with("b.dmtcp"));
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn latest_images_empty_dir() {
        let d = dir("empty");
        assert!(latest_images(&d).unwrap().is_empty());
        assert!(latest_images(std::path::Path::new("/nonexistent-ncr")).unwrap().is_empty());
        std::fs::remove_dir_all(&d).ok();
    }
}
