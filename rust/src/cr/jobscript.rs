//! The consolidated single job script (§V.A): generation + config.
//!
//! "an automated approach employing DMTCP and Slurm is adopted through the
//! deployment of a single job script. This script consolidates both
//! checkpointing and restarting functionalities" — [`consolidated_script`]
//! renders that script (sbatch directives + the func_trap/requeue shell
//! body the paper describes), and [`CrJobConfig`] is its parsed runtime
//! form, bridging the sim-time scheduler and the real-time CR runner.

use crate::simclock::SimTime;
use crate::slurm::{render_script, CrMode, JobSpec, Signal};

/// Runtime C/R configuration carried by a job script.
#[derive(Debug, Clone, PartialEq)]
pub struct CrJobConfig {
    /// The batch-scheduler job specification (directives + C/R mode).
    pub spec: JobSpec,
    /// Total transport steps the workload needs.
    pub target_steps: u64,
    /// Workload label (environment for the containerized app).
    pub workload: String,
    /// Geant4-analog version label.
    pub g4_version: String,
}

impl CrJobConfig {
    /// The standard preemptable C/R job: requeue + USR1@120 + periodic
    /// checkpoints, as the paper's production setup uses.
    pub fn standard(
        workload: &str,
        g4_version: &str,
        work_secs: SimTime,
        ckpt_interval: SimTime,
        ckpt_overhead: SimTime,
    ) -> Self {
        Self {
            spec: JobSpec {
                name: format!("cr-{workload}"),
                partition: "preempt".into(),
                nodes: 1,
                time_limit: 2 * 3_600,
                time_min: Some(1_800),
                signal: Some((Signal::Usr1, 120)),
                requeue: true,
                comment: "nersc_cr".into(),
                work_total: work_secs,
                cr: CrMode::CheckpointRestart {
                    interval: ckpt_interval,
                    overhead: ckpt_overhead,
                },
            },
            target_steps: 0,
            workload: workload.into(),
            g4_version: g4_version.into(),
        }
    }
}

/// Render the paper's consolidated job script: directives + the shell body
/// with `start_coordinator`, the `requeue` function, the SIGTERM/USR1
/// traps, and `dmtcp_launch`/`dmtcp_restart` dispatch.
pub fn consolidated_script(cfg: &CrJobConfig) -> String {
    let body = format!(
        r#"# ---- nersc_cr consolidated C/R job body -------------------------
module load nersc_cr

# Remaining-walltime bookkeeping (updates the job comment; human readable).
update_comment() {{
    left=$(squeue -h -j "$SLURM_JOB_ID" -o %L)
    scontrol update JobId="$SLURM_JOB_ID" Comment="remaining=$left"
}}

# Requeue function: echoed status + scontrol requeue (paper §V.B.1).
requeue() {{
    echo "[nersc_cr] trapping signal: checkpoint + requeue job $SLURM_JOB_ID"
    dmtcp_command --checkpoint
    update_comment
    scontrol requeue "$SLURM_JOB_ID"
}}
trap requeue SIGTERM SIGUSR1

# Coordinator + launch-or-restart dispatch.
export DMTCP_COORD_HOST=$(hostname)
start_coordinator -p 0 --ckptdir "$CKPT_DIR"

restart_job() {{
    if ls "$CKPT_DIR"/ckpt_*.dmtcp >/dev/null 2>&1; then
        echo "[nersc_cr] restarting from newest image"
        dmtcp_restart "$CKPT_DIR"/ckpt_*.dmtcp
    else
        echo "[nersc_cr] first launch"
        dmtcp_launch --gzip $CONTAINER_PREFIX \
            g4app --workload {workload} --g4-version {version} \
                  --steps {steps}
    fi
}}
restart_job
wait
echo "[nersc_cr] job section complete"
"#,
        workload = cfg.workload,
        version = cfg.g4_version,
        steps = cfg.target_steps,
    );
    render_script(&cfg.spec, &body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slurm::parse_script;

    #[test]
    fn standard_config() {
        let cfg = CrJobConfig::standard("water-phantom", "10.7", 7_200, 300, 8);
        assert!(cfg.spec.requeue);
        assert_eq!(cfg.spec.signal, Some((Signal::Usr1, 120)));
        assert!(cfg.spec.cr.restarts_from_ckpt());
        assert_eq!(cfg.spec.partition, "preempt");
    }

    #[test]
    fn script_roundtrips_through_sbatch_parser() {
        let mut cfg = CrJobConfig::standard("em-calorimeter", "11.0", 3_600, 300, 5);
        cfg.target_steps = 640;
        let script = consolidated_script(&cfg);
        let spec = parse_script(&script).unwrap();
        assert_eq!(spec.name, "cr-em-calorimeter");
        assert_eq!(spec.cr, cfg.spec.cr);
        assert_eq!(spec.work_total, 3_600);
        // The paper's moving parts are all present in the body.
        for needle in [
            "start_coordinator",
            "trap requeue SIGTERM",
            "dmtcp_launch",
            "dmtcp_restart",
            "scontrol requeue",
            "DMTCP_COORD_HOST",
            "--open-mode=append",
        ] {
            assert!(script.contains(needle), "script missing {needle:?}");
        }
    }
}
