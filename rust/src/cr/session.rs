//! One session-first entry point for C/R orchestration: [`CrSession`].
//!
//! A session owns everything one checkpointed job needs across its
//! incarnations — coordinator boot, plugin registration, image discovery,
//! launch/restart, worker spawn — behind a builder:
//!
//! ```no_run
//! use nersc_cr::cr::{CrPolicy, CrSession, Substrate};
//! use nersc_cr::workload::{G4App, G4Version, WorkloadKind};
//!
//! let app = G4App::build(WorkloadKind::WaterPhantom, G4Version::V10_7, 16);
//! let report = CrSession::builder(&app)
//!     .substrate(Substrate::bare())
//!     .policy(CrPolicy::default())      // == .strategy(CrStrategy::Auto(..))
//!     .workdir("/tmp/ncr-demo")
//!     .target_steps(640)
//!     .seed(7)
//!     .build()?
//!     .run()?;
//! assert!(report.completed);
//! # Ok::<(), nersc_cr::Error>(())
//! ```
//!
//! The `app` is any [`CrApp`] (Geant4-analog, CP2K-analog, or your own
//! checkpointable state); the [`Substrate`] selects bare vs shifter vs
//! podman-hpc; the [`CrStrategy`] selects the paper's automated Fig 3
//! workflow ([`CrSession::run`]) or the §V.B.2 operator-in-the-loop steps
//! ([`CrSession::submit`] / [`CrSession::monitor`] /
//! [`CrSession::checkpoint_now`] / [`CrSession::kill`] /
//! [`CrSession::resubmit_from_checkpoint`]). Both strategies share one
//! code path for every lifecycle mechanic, so what the automated flow
//! exercises is exactly what the operator flow exercises.
//!
//! Sessions are concurrency-safe at the filesystem level: job ids and
//! image names embed a per-session nonce, so any number of sessions can
//! share one workdir (and its `ckpt/` directory) without colliding — the
//! prerequisite for pooling sessions behind a service.
//!
//! `CrSession` drives *one process*. Multi-rank distributed workloads go
//! through the sibling [`crate::cr::gang::GangSession`], which drives all
//! ranks of one [`crate::cr::app::GangApp`] computation under a single
//! coordinator with all-or-nothing gang checkpoints and gang restarts
//! (DESIGN §10); the two share nonces, workdir layout, and the manual
//! method vocabulary.

#![deny(missing_docs)]

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::cr::app::CrApp;
use crate::cr::auto::{AutoState, CrPolicy, CrReport};
use crate::cr::module::{latest_images, CoordinatorHandle, CrConfig};
use crate::dmtcp::process::Checkpointable;
use crate::dmtcp::store::{ChunkerSpec, ImageStore};
use crate::dmtcp::{Coordinator, ImageInfo, PluginRegistry, TimerPlugin};
use crate::error::{Error, Result};
use crate::metrics::{LdmsSampler, SampledSeries};

use super::substrate::Substrate;

/// How long to wait for the coordinator to assign a virtual pid.
const ATTACH_TIMEOUT: Duration = Duration::from_secs(10);

/// Poll interval for progress checks in the drive loops.
const POLL: Duration = Duration::from_millis(5);

/// Default for [`CrPolicy::gc_grace`] / [`CrSessionBuilder::gc_grace`]:
/// chunks younger than this survive store GC. A concurrent session
/// sharing the workdir may have stored (or mtime-refreshed, for dedup
/// reuse) chunks but not yet published the manifest that references
/// them, so the window must comfortably exceed the longest plausible
/// single checkpoint write — a write slower than the configured grace
/// while another session tears down concurrently is the remaining
/// (documented) exposure. Campaigns that tear many sessions down against
/// one shared chunk store tighten or loosen this through the builder.
pub const GC_GRACE: Duration = Duration::from_secs(600);

/// Process-wide session nonce allocator. Combined with the OS process id
/// so two sessions never mint the same job id or image-name prefix, even
/// across processes sharing a workdir. Shared with the gang sessions
/// ([`crate::cr::gang::GangSession`]) — single-process and gang sessions
/// can interleave in one workdir without colliding.
pub(crate) fn next_nonce() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    ((std::process::id() as u64) << 20) | NEXT.fetch_add(1, Ordering::Relaxed)
}

/// Which orchestration drives the session.
#[derive(Debug, Clone)]
pub enum CrStrategy {
    /// The automated Fig 3 workflow: periodic checkpoints, func_trap
    /// checkpoint-on-signal, requeue, restart — driven to completion by
    /// [`CrSession::run`].
    Auto(CrPolicy),
    /// The §V.B.2 operator-in-the-loop flow, driven step by step through
    /// the session's manual methods.
    Manual,
}

/// What [`CrSession::monitor`] reports (the operator's view of the
/// output/error logs), workload-generic.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SessionStatus {
    /// Steps (scans, sweeps, ...) completed so far.
    pub steps_done: u64,
    /// Steps the workload needs in total.
    pub target_steps: u64,
    /// Whether the workload is finished.
    pub done: bool,
    /// Progress toward the goal in `[0, 1]`.
    pub progress: f64,
}

/// Builder for [`CrSession`] — see the module docs for the canonical
/// chain. `workdir` is required.
pub struct CrSessionBuilder<A: CrApp> {
    app: A,
    substrate: Substrate,
    strategy: CrStrategy,
    workdir: Option<PathBuf>,
    target_steps: u64,
    seed: u64,
    incremental: Option<u32>,
    chunker: Option<ChunkerSpec>,
    gc_grace: Option<Duration>,
    coordinator: CoordinatorHandle,
}

impl<A: CrApp> CrSessionBuilder<A> {
    /// Select the execution environment (default: bare process).
    pub fn substrate(mut self, substrate: Substrate) -> Self {
        self.substrate = substrate;
        self
    }

    /// Select the orchestration strategy (default: [`CrStrategy::Manual`]).
    pub fn strategy(mut self, strategy: CrStrategy) -> Self {
        self.strategy = strategy;
        self
    }

    /// Shorthand for `strategy(CrStrategy::Auto(policy))`.
    pub fn policy(mut self, policy: CrPolicy) -> Self {
        self.strategy = CrStrategy::Auto(policy);
        self
    }

    /// Where the rendezvous file and `ckpt/` images live (required; must
    /// survive the job — a shared filesystem or volume-mapped host dir
    /// when containerized).
    pub fn workdir(mut self, workdir: impl Into<PathBuf>) -> Self {
        self.workdir = Some(workdir.into());
        self
    }

    /// Total steps the workload must complete (0 = trivially done).
    pub fn target_steps(mut self, target_steps: u64) -> Self {
        self.target_steps = target_steps;
        self
    }

    /// Workload seed (also folded into the job id).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Write incremental (content-addressed, chunked) checkpoint images
    /// whatever the strategy — manual sessions have no [`CrPolicy`] to
    /// carry [`CrPolicy::incremental_ckpt`]. `full_image_every` forces
    /// every Nth checkpoint of an incarnation back to a self-contained
    /// full image (0 = never).
    pub fn incremental_images(mut self, full_image_every: u32) -> Self {
        self.incremental = Some(full_image_every);
        self
    }

    /// How incremental images chunk their segments (default
    /// [`ChunkerSpec::Fixed`], or [`CrPolicy::chunker`] for auto
    /// sessions): content-defined chunking keeps dedup effective when
    /// state inserts shift segment bytes. No effect unless incremental
    /// images are on.
    pub fn chunker(mut self, chunker: ChunkerSpec) -> Self {
        self.chunker = Some(chunker);
        self
    }

    /// Override the chunk-store GC grace window for this session's
    /// teardown (default [`GC_GRACE`], or [`CrPolicy::gc_grace`] for auto
    /// sessions). Campaigns with fast session teardown sharing one chunk
    /// store tighten it to reclaim space promptly, or loosen it when
    /// checkpoint writes can outlast the default window.
    pub fn gc_grace(mut self, grace: Duration) -> Self {
        self.gc_grace = Some(grace);
        self
    }

    /// How this session obtains its coordinator (default
    /// [`CoordinatorHandle::Private`]: a private daemon per incarnation).
    /// Pass [`CoordinatorHandle::Shared`] to register each incarnation's
    /// job on a long-lived multi-tenant daemon instead, multiplexing the
    /// session over the daemon's single port.
    pub fn coordinator(mut self, handle: CoordinatorHandle) -> Self {
        self.coordinator = handle;
        self
    }

    /// Validate and assemble the session (creates the workdir).
    pub fn build(self) -> Result<CrSession<A>> {
        let workdir = self.workdir.ok_or_else(|| {
            Error::Workload("CrSession needs a workdir (builder .workdir(..))".into())
        })?;
        std::fs::create_dir_all(&workdir)?;
        // Builder override wins; auto sessions otherwise inherit their
        // policy's window; manual sessions fall back to the default.
        let gc_grace = self.gc_grace.unwrap_or(match &self.strategy {
            CrStrategy::Auto(p) => p.gc_grace,
            CrStrategy::Manual => GC_GRACE,
        });
        if let Some(c) = &self.chunker {
            c.validate()?;
        }
        if let CrStrategy::Auto(p) = &self.strategy {
            p.chunker.validate()?;
        }
        Ok(CrSession {
            app: self.app,
            substrate: self.substrate,
            strategy: self.strategy,
            workdir,
            target_steps: self.target_steps,
            seed: self.seed,
            incremental: self.incremental,
            chunker: self.chunker,
            gc_grace,
            coordinator_handle: self.coordinator,
            nonce: next_nonce(),
            incarnation: 0,
            active: None,
            series_acc: None,
            restore_phases: [0.0; 3],
            image_fallbacks: 0,
        })
    }
}

struct ActiveJob<S: Checkpointable> {
    coordinator: Coordinator,
    launched: crate::dmtcp::LaunchedProcess,
    state: Arc<Mutex<S>>,
    sampler: Option<LdmsSampler>,
}

/// A checkpoint-restart session: one workload, one substrate, any number
/// of incarnations. Built with [`CrSession::builder`].
pub struct CrSession<A: CrApp> {
    app: A,
    substrate: Substrate,
    strategy: CrStrategy,
    workdir: PathBuf,
    target_steps: u64,
    seed: u64,
    incremental: Option<u32>,
    chunker: Option<ChunkerSpec>,
    gc_grace: Duration,
    coordinator_handle: CoordinatorHandle,
    nonce: u64,
    incarnation: u32,
    active: Option<ActiveJob<A::State>>,
    series_acc: Option<SampledSeries>,
    /// Restore-pipeline `[read, decompress, verify]` seconds summed over
    /// this session's restarts (v2 manifest images only).
    restore_phases: [f64; 3],
    /// Restarts that had to skip a corrupt newest image and fall back to
    /// an older restorable one (store-domain fault recovery).
    image_fallbacks: u32,
}

impl<A: CrApp> CrSession<A> {
    /// Start a builder for `app` (anything implementing [`CrApp`], by
    /// value or by reference).
    pub fn builder(app: A) -> CrSessionBuilder<A> {
        CrSessionBuilder {
            app,
            substrate: Substrate::Bare,
            strategy: CrStrategy::Manual,
            workdir: None,
            target_steps: 0,
            seed: 0,
            incremental: None,
            gc_grace: None,
            chunker: None,
            coordinator: CoordinatorHandle::Private,
        }
    }

    /// The Slurm-style job id of the *current* incarnation. Unique across
    /// sessions (nonce) and incarnations, so sessions can share a workdir.
    pub fn jobid(&self) -> String {
        format!(
            "{}s{}i{:02}",
            self.seed % 900_000 + 100_000,
            self.nonce,
            self.incarnation
        )
    }

    /// The incarnation-independent prefix every [`CrSession::jobid`] of
    /// this session starts with. The literal `i` terminator after the
    /// decimal nonce means no other session's job id can share this
    /// prefix — what lets shared-workdir fleets attribute flight dumps
    /// (whose `job` field names one incarnation) to their session.
    pub fn job_prefix(&self) -> String {
        format!("{}s{}i", self.seed % 900_000 + 100_000, self.nonce)
    }

    /// Restarts that skipped a corrupt newest image and fell back to an
    /// older restorable one (store-domain fault recovery).
    pub fn image_fallbacks(&self) -> u32 {
        self.image_fallbacks
    }

    /// The process name this session launches under; checkpoint images
    /// carry it, which is what scopes image discovery per session.
    pub fn process_name(&self) -> String {
        format!("{}-s{}", self.app.label(), self.nonce)
    }

    /// Incarnations used so far (0 = the initial submission).
    pub fn incarnation(&self) -> u32 {
        self.incarnation
    }

    /// The substrate this session launches on.
    pub fn substrate(&self) -> &Substrate {
        &self.substrate
    }

    /// Switch substrate between incarnations (the paper's cross-runtime
    /// compatibility claim: checkpoint under podman-hpc, restart under
    /// shifter). Fails while a job is active.
    pub fn set_substrate(&mut self, substrate: Substrate) -> Result<()> {
        if self.active.is_some() {
            return Err(Error::Workload(
                "kill the active job before switching substrates".into(),
            ));
        }
        self.substrate = substrate;
        Ok(())
    }

    /// The coordinator of the active incarnation (for topology inspection
    /// — `dmtcp::coordinator::client_table` — and direct `dmtcp_command`
    /// control).
    pub fn coordinator(&self) -> Result<&Coordinator> {
        Ok(&self.job()?.coordinator)
    }

    /// This session's checkpoint images, oldest to newest (only images
    /// minted by this session — discovery is nonce-scoped).
    pub fn session_images(&self) -> Result<Vec<PathBuf>> {
        let prefix = format!("ckpt_{}_", self.process_name());
        Ok(latest_images(&self.workdir.join("ckpt"))?
            .into_iter()
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with(&prefix))
            })
            .collect())
    }

    fn job(&self) -> Result<&ActiveJob<A::State>> {
        self.active
            .as_ref()
            .ok_or_else(|| Error::Workload("no active job".into()))
    }

    fn worker_shape(&self) -> (u32, u32) {
        match &self.strategy {
            CrStrategy::Auto(p) => (p.n_threads, p.scans_per_quantum),
            CrStrategy::Manual => (1, 1),
        }
    }

    /// Boot one incarnation: coordinator + plugins + (launch | restart) +
    /// workers + sampler. Returns `Some(steps_at_restart)` when restoring
    /// from an image, `None` on a fresh launch. This is the one code path
    /// both strategies share.
    fn boot(&mut self) -> Result<Option<u64>> {
        if self.active.is_some() {
            return Err(Error::Workload("job already active".into()));
        }
        let name = if self.incarnation == 0 {
            crate::trace::names::SESSION_LAUNCH
        } else {
            crate::trace::names::SESSION_RESTART
        };
        let mut sp = crate::trace::span(name)
            .with("job", || self.jobid())
            .with_u64("incarnation", self.incarnation as u64);
        let res = self.boot_inner();
        match &res {
            Ok(Some(at)) => sp.note_u64("resumed_at", *at),
            Ok(None) => {}
            Err(e) => {
                sp.fail(&e.to_string());
                drop(sp);
                crate::trace::flight::dump_for_job(
                    &self.jobid(),
                    &format!("boot failed: {e}"),
                    &self.workdir.join("ckpt"),
                );
            }
        }
        res
    }

    fn boot_inner(&mut self) -> Result<Option<u64>> {
        let mut cfg = CrConfig::new(self.jobid(), &self.workdir);
        if let CrStrategy::Auto(p) = &self.strategy {
            cfg.incremental = p.incremental_ckpt;
            cfg.full_image_every = p.full_image_every;
            cfg.chunker = p.chunker;
        }
        if let Some(full_every) = self.incremental {
            cfg.incremental = true;
            cfg.full_image_every = full_every;
        }
        if let Some(chunker) = self.chunker {
            cfg.chunker = chunker;
        }
        let (coordinator, env) = self.coordinator_handle.start(&cfg)?;
        let images = self.session_images()?;
        let name = self.process_name();

        let (state, mut launched, resumed_at) = if self.incarnation == 0 {
            if let Some(stale) = images.last() {
                return Err(Error::Workload(format!(
                    "stale checkpoint images in a fresh workdir (e.g. {}): \
                     clean {} or resume through a restart path",
                    stale.display(),
                    self.workdir.display()
                )));
            }
            let state = Arc::new(Mutex::new(
                self.app.fresh_state(self.target_steps, self.seed)?,
            ));
            let mut plugins = PluginRegistry::new();
            plugins.register(Box::new(TimerPlugin::new()));
            self.app.register_plugins(&state, &mut plugins);
            let launched = self.substrate.launch(
                &name,
                coordinator.addr(),
                env,
                Arc::clone(&state),
                plugins,
            )?;
            (state, launched, None)
        } else {
            if images.is_empty() {
                return Err(Error::Workload("requeued but no checkpoint image".into()));
            }
            // Newest image first. A typed `Error::Corrupt` (store damage
            // under the image's manifest) falls back to the previous
            // restorable image instead of sinking the session — losing at
            // most the work since the older cut, which is the store-domain
            // bound of DESIGN §9. Any other error propagates untouched.
            let mut restored = None;
            let mut last_corrupt = None;
            for image in images.iter().rev() {
                let state = Arc::new(Mutex::new(self.app.restore_state()));
                let mut plugins = PluginRegistry::new();
                plugins.register(Box::new(TimerPlugin::new()));
                self.app.register_plugins(&state, &mut plugins);
                // The env overlay re-tags the restarted process with
                // *this* incarnation's coordinator routing (DMTCP_JOB et
                // al.); the image's copy names the previous incarnation's
                // job.
                match self.substrate.restart(
                    image,
                    coordinator.addr(),
                    Arc::clone(&state),
                    plugins,
                    &env,
                ) {
                    Ok(r) => {
                        restored = Some((state, r));
                        break;
                    }
                    Err(e @ Error::Corrupt(_)) => {
                        log::warn!(
                            "session {}: image {} is corrupt, falling back to the \
                             previous one: {e}",
                            self.nonce,
                            image.display()
                        );
                        self.image_fallbacks += 1;
                        crate::trace::flight::dump_for_job_in_domain(
                            &self.jobid(),
                            &format!("corrupt image {}: {e}", image.display()),
                            &self.workdir.join("ckpt"),
                            "store",
                        );
                        last_corrupt = Some(e);
                    }
                    Err(e) => return Err(e),
                }
            }
            let Some((state, restarted)) = restored else {
                return Err(last_corrupt.expect("restart loop saw at least one image"));
            };
            let at = restarted.header.steps_done;
            if let Some(rs) = &restarted.restore {
                self.restore_phases[0] += rs.read_secs;
                self.restore_phases[1] += rs.decompress_secs;
                self.restore_phases[2] += rs.verify_secs;
            }
            (state, restarted.launched, Some(at))
        };
        launched.wait_attached(ATTACH_TIMEOUT)?;
        let (n_threads, per_quantum) = self.worker_shape();
        self.app.spawn_workers(&mut launched, Arc::clone(&state), n_threads, per_quantum)?;
        let sampler = LdmsSampler::start(
            vec![Arc::clone(&launched.process.stats)],
            Duration::from_millis(3),
        );
        self.active = Some(ActiveJob {
            coordinator,
            launched,
            state,
            sampler: Some(sampler),
        });
        Ok(resumed_at)
    }

    /// Kill the active incarnation, join its threads, fold its LDMS series
    /// into the session accumulator, and hand back the state.
    fn teardown(&mut self) -> Result<Arc<Mutex<A::State>>> {
        let ActiveJob {
            coordinator,
            launched,
            state,
            mut sampler,
        } = self
            .active
            .take()
            .ok_or_else(|| Error::Workload("no active job".into()))?;
        coordinator.kill_all();
        let _ = launched.join();
        if let Some(s) = sampler.take() {
            merge_series(&mut self.series_acc, s.stop());
        }
        Ok(state)
    }

    fn checkpoint_images(&self) -> Result<Vec<ImageInfo>> {
        self.job()?.coordinator.checkpoint_all()
    }

    // ----- shared observation methods (both strategies) -----------------

    /// Inspect the running workload (the paper's "monitor the output" step).
    pub fn monitor(&self) -> Result<SessionStatus> {
        let job = self.job()?;
        let s = job.state.lock().expect("state poisoned");
        Ok(SessionStatus {
            steps_done: s.steps_done(),
            target_steps: self.target_steps,
            done: self.app.done(&s),
            progress: self.app.progress(&s),
        })
    }

    /// Run a closure against the live (locked) application state — for
    /// typed observations the generic [`SessionStatus`] doesn't carry.
    pub fn with_state<R>(&self, f: impl FnOnce(&A::State) -> R) -> Result<R> {
        let job = self.job()?;
        let s = job.state.lock().expect("state poisoned");
        Ok(f(&s))
    }

    /// Snapshot of the application state (for final verification).
    pub fn final_state(&self) -> Result<A::State> {
        self.with_state(|s| s.clone())
    }

    /// The LDMS series accumulated across this session's *finished*
    /// incarnations (each incarnation's sampler is folded in at
    /// teardown — an active incarnation's samples appear after the next
    /// `kill`/`finish`). Campaign reports roll these up fleet-wide.
    pub fn series(&self) -> SampledSeries {
        self.series_acc.clone().unwrap_or_default()
    }

    /// Restore-pipeline `[read, decompress, verify]` seconds summed over
    /// this session's restarts so far (all `[0.0; 3]` when every restart
    /// decoded a v1 full image — the phases only exist for v2 manifest
    /// restores). Campaign drivers fold these into the fleet report.
    pub fn restore_phase_secs(&self) -> [f64; 3] {
        self.restore_phases
    }

    /// Verify a final state bitwise against an uninterrupted reference run
    /// of this session's `(target_steps, seed)` — delegates to
    /// [`CrApp::verify_final`].
    pub fn verify_final(&self, final_state: &A::State) -> Result<()> {
        self.app
            .verify_final(final_state, self.target_steps, self.seed)
    }

    /// Take a checkpoint now (`dmtcp_command --checkpoint`); returns the
    /// image paths.
    pub fn checkpoint_now(&self) -> Result<Vec<PathBuf>> {
        let mut sp = crate::trace::span(crate::trace::names::SESSION_CHECKPOINT)
            .with("job", || self.jobid());
        match self.checkpoint_images() {
            Ok(images) => {
                sp.note_u64("images", images.len() as u64);
                Ok(images.into_iter().map(|i| i.path).collect())
            }
            Err(e) => {
                sp.fail(&e.to_string());
                drop(sp);
                crate::trace::flight::dump_for_job(
                    &self.jobid(),
                    &format!("checkpoint failed: {e}"),
                    &self.workdir.join("ckpt"),
                );
                Err(e)
            }
        }
    }

    /// Poll until the workload finishes or `timeout` elapses.
    pub fn wait_done(&self, timeout: Duration) -> Result<SessionStatus> {
        let deadline = Instant::now() + timeout;
        loop {
            let st = self.monitor()?;
            if st.done {
                return Ok(st);
            }
            if Instant::now() > deadline {
                return Err(Error::Workload(format!(
                    "timeout at {}/{} steps",
                    st.steps_done, st.target_steps
                )));
            }
            std::thread::sleep(POLL);
        }
    }

    /// Tear down the active incarnation, if any (idempotent; also runs on
    /// drop), then garbage-collect chunk-store entries no image of this
    /// workdir references anymore.
    pub fn finish(&mut self) {
        if self.active.is_some() {
            let _ = self.teardown();
        }
        self.gc_store();
    }

    /// Reclaim unreferenced chunks from the workdir's content-addressed
    /// store (no-op when no incremental image was ever written). Chunks
    /// younger than the session's configured grace window (builder
    /// [`CrSessionBuilder::gc_grace`] / [`CrPolicy::gc_grace`], default
    /// [`GC_GRACE`]) are spared so concurrent sessions sharing the
    /// workdir cannot lose chunks stored ahead of their manifest.
    fn gc_store(&self) {
        let ckpt_dir = self.workdir.join("ckpt");
        let store = ImageStore::for_images(&ckpt_dir);
        if !store.root().exists() {
            return;
        }
        match store.gc(&ckpt_dir, self.gc_grace) {
            Ok(st) if st.deleted > 0 => log::debug!(
                "session {}: store GC reclaimed {} chunks ({} bytes)",
                self.nonce,
                st.deleted,
                st.deleted_bytes
            ),
            Ok(_) => {}
            Err(e) => log::warn!("session {}: store GC failed: {e}", self.nonce),
        }
    }

    // ----- the manual (§V.B.2) strategy ---------------------------------

    /// Manual step 1: initial submission ("creates a checkpointing
    /// state"). Requires [`CrStrategy::Manual`].
    pub fn submit(&mut self) -> Result<()> {
        self.require_manual("submit")?;
        if self.incarnation != 0 {
            return Err(Error::Workload(
                "session already past its first incarnation; use \
                 resubmit_from_checkpoint"
                    .into(),
            ));
        }
        self.boot().map(|_| ())
    }

    /// Manual step 4: kill the job (failure injection / operator
    /// decision). The session stays usable for resubmission.
    pub fn kill(&mut self) -> Result<()> {
        crate::trace::event(crate::trace::names::SESSION_KILL, |a| {
            a.str("job", self.jobid());
            a.u64("incarnation", self.incarnation as u64);
        });
        self.teardown().map(|_| ())
    }

    /// Manual step 5: resubmit from the newest checkpoint image of this
    /// session. Returns the step count at the restart point.
    pub fn resubmit_from_checkpoint(&mut self) -> Result<u64> {
        self.require_manual("resubmit_from_checkpoint")?;
        if self.active.is_some() {
            return Err(Error::Workload("kill the active job first".into()));
        }
        self.incarnation += 1;
        self.boot()?
            .ok_or_else(|| Error::Workload("restart did not report a resume point".into()))
    }

    fn require_manual(&self, what: &str) -> Result<()> {
        match self.strategy {
            CrStrategy::Manual => Ok(()),
            CrStrategy::Auto(_) => Err(Error::Workload(format!(
                "{what} is a manual-strategy method; CrStrategy::Auto sessions \
                 are driven by CrSession::run"
            ))),
        }
    }

    // ----- the automated (Fig 3) strategy -------------------------------

    /// Drive the automated Fig 3 workflow to completion: periodic
    /// checkpoints, the preemption plan, func_trap checkpoint-on-signal,
    /// requeue, restart from the newest image — until the workload
    /// completes or the incarnation budget is exhausted
    /// ([`Error::IncarnationsExhausted`]). Requires [`CrStrategy::Auto`].
    pub fn run(mut self) -> Result<CrReport<A::State>> {
        let policy = match &self.strategy {
            CrStrategy::Auto(p) => p.clone(),
            CrStrategy::Manual => {
                return Err(Error::Workload(
                    "CrSession::run drives CrStrategy::Auto; manual sessions use \
                     submit/monitor/checkpoint_now/kill/resubmit_from_checkpoint"
                        .into(),
                ))
            }
        };
        let t0 = Instant::now();
        let mut timeline = vec![(0.0, AutoState::Submitted)];
        let auto_tag = self.process_name();
        let mark = |tl: &mut Vec<(f64, AutoState)>, s: AutoState| {
            tl.push((t0.elapsed().as_secs_f64(), s));
            crate::trace::event(crate::trace::names::AUTO_STATE, |a| {
                a.str("job", auto_tag.clone());
                a.str("state", s.label());
            });
        };

        let mut tally = CkptTally::default();
        let mut restart_steps = Vec::new();

        loop {
            if self.incarnation >= policy.max_incarnations {
                mark(&mut timeline, AutoState::Failed);
                self.gc_store();
                return Err(Error::IncarnationsExhausted(policy.max_incarnations));
            }
            mark(&mut timeline, AutoState::Starting);
            if self.incarnation > 0 {
                mark(&mut timeline, AutoState::Restarting);
            }
            if let Some(at) = self.boot()? {
                restart_steps.push(at);
            }
            mark(&mut timeline, AutoState::Running);

            // Drive this incarnation: periodic checkpoints + preemption
            // plan.
            let inc_start = Instant::now();
            let preempt_at = policy.preempt_after.get(self.incarnation as usize).copied();
            let mut next_ckpt = policy.ckpt_interval;
            let completed = loop {
                std::thread::sleep(POLL);
                let done = {
                    let job = self.active.as_ref().expect("active job");
                    let s = job.state.lock().expect("state poisoned");
                    self.app.done(&s)
                };
                if done {
                    break true;
                }
                let ran = inc_start.elapsed();
                if let Some(p) = preempt_at {
                    if ran >= p {
                        break false;
                    }
                }
                if policy.periodic_ckpt && ran >= next_ckpt {
                    mark(&mut timeline, AutoState::Checkpointing);
                    match self.checkpoint_images() {
                        Ok(images) => tally.add(&images),
                        Err(e) => log::warn!("periodic checkpoint failed: {e}"),
                    }
                    mark(&mut timeline, AutoState::Running);
                    next_ckpt += policy.ckpt_interval;
                }
            };

            if completed {
                let state = self.teardown()?;
                mark(&mut timeline, AutoState::Completed);
                self.gc_store();
                let final_state = state.lock().expect("state poisoned").clone();
                return Ok(CrReport {
                    completed: true,
                    incarnations: self.incarnation + 1,
                    checkpoints: tally.checkpoints,
                    total_image_bytes: tally.image_bytes,
                    total_raw_bytes: tally.raw_bytes,
                    wall_secs: t0.elapsed().as_secs_f64(),
                    timeline,
                    final_state,
                    series: self.series_acc.take().unwrap_or_default(),
                    restart_steps,
                    chunks_written: tally.chunks_written,
                    chunks_deduped: tally.chunks_deduped,
                    restore_read_secs: self.restore_phases[0],
                    restore_decompress_secs: self.restore_phases[1],
                    restore_verify_secs: self.restore_phases[2],
                });
            }
            // func_trap: SIGTERM trapped → checkpoint → requeue.
            mark(&mut timeline, AutoState::SignalTrapped);
            if policy.ckpt_on_signal {
                match self.checkpoint_images() {
                    Ok(images) => tally.add(&images),
                    Err(e) => log::warn!("trap checkpoint failed: {e}"),
                }
            }
            let _ = self.teardown()?;
            mark(&mut timeline, AutoState::Requeued);
            std::thread::sleep(policy.requeue_delay);
            self.incarnation += 1;
        }
    }
}

impl<A: CrApp> Drop for CrSession<A> {
    fn drop(&mut self) {
        if let Some(job) = self.active.take() {
            job.coordinator.kill_all();
            let _ = job.launched.join();
        }
    }
}

/// Report accounting folded over checkpoint rounds.
#[derive(Default)]
struct CkptTally {
    checkpoints: u64,
    image_bytes: u64,
    raw_bytes: u64,
    chunks_written: u64,
    chunks_deduped: u64,
}

impl CkptTally {
    fn add(&mut self, images: &[ImageInfo]) {
        self.checkpoints += 1;
        self.image_bytes += images.iter().map(|i| i.stored_bytes).sum::<u64>();
        self.raw_bytes += images.iter().map(|i| i.raw_bytes).sum::<u64>();
        self.chunks_written += images.iter().map(|i| i.chunks_written).sum::<u64>();
        self.chunks_deduped += images.iter().map(|i| i.chunks_deduped).sum::<u64>();
    }
}

/// Concatenate sampler outputs across incarnations (time axes are
/// per-incarnation; offset each segment by the accumulated end time).
/// `ckpt_stored` is a per-process *cumulative* counter that restarts at 0
/// each incarnation, so its values are additionally offset by the
/// accumulated total — the merged series stays monotone. Shared with the
/// gang sessions, whose per-incarnation samplers cover all ranks at once.
pub(crate) fn merge_series(acc: &mut Option<SampledSeries>, next: SampledSeries) {
    match acc {
        None => *acc = Some(next),
        Some(a) => {
            let offset = a.memory.t.last().copied().unwrap_or(0.0);
            for (dst, src) in [
                (&mut a.memory, &next.memory),
                (&mut a.cpu, &next.cpu),
                (&mut a.steps, &next.steps),
            ] {
                for (&t, &v) in src.t.iter().zip(&src.v) {
                    dst.push(offset + t, v);
                }
            }
            let stored_base = a.ckpt_stored.v.last().copied().unwrap_or(0.0);
            for (&t, &v) in next.ckpt_stored.t.iter().zip(&next.ckpt_stored.v) {
                a.ckpt_stored.push(offset + t, stored_base + v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{G4App, G4Version, WorkloadKind};

    fn app() -> G4App {
        G4App::build(WorkloadKind::WaterPhantom, G4Version::V10_7, 16)
    }

    fn workdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ncr_sess_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn builder_requires_workdir() {
        let a = app();
        assert!(CrSession::builder(&a).target_steps(8).build().is_err());
        // A zero target is a degenerate but valid already-done workload
        // (the legacy entry points allowed it, so the builder must too).
        assert!(CrSession::builder(&a)
            .workdir(workdir("req"))
            .build()
            .is_ok());
    }

    #[test]
    fn nonces_make_jobids_and_names_unique() {
        let a = app();
        let wd = workdir("nonce");
        let s1 = CrSession::builder(&a)
            .workdir(&wd)
            .target_steps(8)
            .seed(7)
            .build()
            .unwrap();
        let s2 = CrSession::builder(&a)
            .workdir(&wd)
            .target_steps(8)
            .seed(7)
            .build()
            .unwrap();
        assert_ne!(s1.jobid(), s2.jobid());
        assert_ne!(s1.process_name(), s2.process_name());
        // Same seed still contributes the Slurm-looking prefix.
        assert!(s1.jobid().starts_with("100007"));
    }

    #[test]
    fn manual_methods_rejected_under_auto() {
        let a = app();
        let mut s = CrSession::builder(&a)
            .policy(CrPolicy::default())
            .workdir(workdir("gate"))
            .target_steps(8)
            .build()
            .unwrap();
        assert!(s.submit().is_err());
        assert!(s.monitor().is_err(), "no active job yet");
    }

    #[test]
    fn run_rejected_under_manual() {
        let a = app();
        let s = CrSession::builder(&a)
            .workdir(workdir("runman"))
            .target_steps(8)
            .build()
            .unwrap();
        let err = s.run().unwrap_err();
        assert!(err.to_string().contains("CrStrategy::Auto"), "{err}");
    }

    #[test]
    fn gc_grace_resolves_builder_then_policy_then_default() {
        let a = app();
        let s = CrSession::builder(&a)
            .workdir(workdir("gcg_default"))
            .build()
            .unwrap();
        assert_eq!(s.gc_grace, GC_GRACE);
        let s = CrSession::builder(&a)
            .workdir(workdir("gcg_builder"))
            .gc_grace(Duration::from_millis(5))
            .build()
            .unwrap();
        assert_eq!(s.gc_grace, Duration::from_millis(5));
        let s = CrSession::builder(&a)
            .policy(CrPolicy {
                gc_grace: Duration::from_secs(1),
                ..Default::default()
            })
            .workdir(workdir("gcg_policy"))
            .build()
            .unwrap();
        assert_eq!(s.gc_grace, Duration::from_secs(1));
        // The builder override beats the policy.
        let s = CrSession::builder(&a)
            .policy(CrPolicy {
                gc_grace: Duration::from_secs(1),
                ..Default::default()
            })
            .gc_grace(Duration::from_millis(7))
            .workdir(workdir("gcg_both"))
            .build()
            .unwrap();
        assert_eq!(s.gc_grace, Duration::from_millis(7));
    }

    #[test]
    fn merge_series_offsets_time() {
        let mut a = SampledSeries::default();
        a.memory.push(0.0, 1.0);
        a.memory.push(1.0, 2.0);
        let mut b = SampledSeries::default();
        b.memory.push(0.0, 3.0);
        b.memory.push(0.5, 4.0);
        let mut acc = Some(a);
        merge_series(&mut acc, b);
        let m = &acc.unwrap().memory;
        assert_eq!(m.t, vec![0.0, 1.0, 1.0, 1.5]);
        assert_eq!(m.v, vec![1.0, 2.0, 3.0, 4.0]);
    }
}
