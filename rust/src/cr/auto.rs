//! Automated C/R strategy — the paper's Fig 3 workflow, executable.
//!
//! "Users initiate their computational tasks with batch scripts that
//! include DMTCP within the container ... a `restart_job` function that
//! integrates a `start_coordinator` to launch the checkpointing mechanism,
//! followed by the execution command `dmtcp_launch` ... handling
//! termination signals such as SIGTERM ... thereby triggering a requeue
//! function".
//!
//! [`run_auto`] drives the full lifecycle in real time against the real
//! subsystems: coordinator per incarnation (a fresh batch job lands on a
//! fresh node), periodic `dmtcp_command --checkpoint`, a preemption plan
//! (when the "scheduler" SIGTERMs each incarnation), func_trap-style
//! checkpoint-on-signal, requeue delay, restart from the newest image —
//! until the workload completes or the incarnation budget is exhausted.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::cr::module::{latest_images, start_coordinator, CrConfig};
use crate::dmtcp::{
    dmtcp_launch, dmtcp_restart, LaunchSpec, PluginRegistry, TimerPlugin,
};
use crate::error::{Error, Result};
use crate::metrics::{LdmsSampler, SampledSeries};
use crate::runtime::ComputeHandle;
use crate::workload::{transport_worker, G4App, G4SimState};

/// Fig 3 states (the workflow diagram, as data).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AutoState {
    /// Job handed to the batch system.
    Submitted,
    /// Coordinator boot + launch/restart in progress.
    Starting,
    /// Transport workers advancing the state.
    Running,
    /// A coordinator-wide checkpoint barrier is in flight.
    Checkpointing,
    /// A preemption signal was trapped (func_trap).
    SignalTrapped,
    /// Waiting in the queue after a requeue.
    Requeued,
    /// Restoring state from the newest image.
    Restarting,
    /// Workload reached its target step count.
    Completed,
    /// Incarnation budget exhausted (or unrecoverable error).
    Failed,
}

/// Policy knobs for one automated C/R run.
#[derive(Debug, Clone)]
pub struct CrPolicy {
    /// Periodic checkpoint interval (wall clock).
    pub ckpt_interval: Duration,
    /// Preemption plan: incarnation `i` is SIGTERM'd after
    /// `preempt_after[i]` of runtime (absent = runs to completion/limit).
    pub preempt_after: Vec<Duration>,
    /// Queue wait between requeue and restart (the Fig 4 gap).
    pub requeue_delay: Duration,
    /// Give up after this many incarnations.
    pub max_incarnations: u32,
    /// Take a func_trap checkpoint on the preemption signal (the paper's
    /// automated flow) — disable to model plain preemption.
    pub ckpt_on_signal: bool,
    /// Checkpoint periodically at all (false = the paper's "without C/R").
    pub periodic_ckpt: bool,
    /// Worker threads per process.
    pub n_threads: u32,
    /// Scans between checkpoint safe-points.
    pub scans_per_quantum: u32,
}

impl Default for CrPolicy {
    fn default() -> Self {
        Self {
            ckpt_interval: Duration::from_millis(300),
            preempt_after: Vec::new(),
            requeue_delay: Duration::from_millis(100),
            max_incarnations: 8,
            ckpt_on_signal: true,
            periodic_ckpt: true,
            n_threads: 1,
            scans_per_quantum: 1,
        }
    }
}

/// Outcome of an automated run.
#[derive(Debug)]
pub struct CrReport {
    /// Whether the workload reached its target step count.
    pub completed: bool,
    /// Batch-job incarnations used (1 = never preempted).
    pub incarnations: u32,
    /// Checkpoints taken across all incarnations.
    pub checkpoints: u64,
    /// Stored (possibly compressed) checkpoint bytes written.
    pub total_image_bytes: u64,
    /// Raw (uncompressed) checkpoint bytes serialized.
    pub total_raw_bytes: u64,
    /// `(elapsed_secs, state)` transitions.
    pub timeline: Vec<(f64, AutoState)>,
    /// Wall time, start to terminal state.
    pub wall_secs: f64,
    /// The final simulation state (for bitwise verification).
    pub final_state: G4SimState,
    /// LDMS series across the whole run (all incarnations).
    pub series: SampledSeries,
    /// Steps at each restart (monotone; proves no lost progress).
    pub restart_steps: Vec<u64>,
}

/// Run the automated Fig 3 workflow to completion.
pub fn run_auto(
    app: &G4App,
    handle: &ComputeHandle,
    target_steps: u64,
    seed: u64,
    policy: &CrPolicy,
    workdir: &std::path::Path,
) -> Result<CrReport> {
    let t0 = Instant::now();
    let mut timeline = vec![(0.0, AutoState::Submitted)];
    let mark = |tl: &mut Vec<(f64, AutoState)>, s: AutoState| {
        tl.push((t0.elapsed().as_secs_f64(), s));
    };

    let batch = handle.manifest().batch;
    let mut checkpoints = 0u64;
    let mut total_image_bytes = 0u64;
    let mut total_raw_bytes = 0u64;
    let mut restart_steps = Vec::new();
    let mut sampler: Option<LdmsSampler> = None;
    let mut series_acc: Option<SampledSeries> = None;

    let mut incarnation = 0u32;
    loop {
        if incarnation >= policy.max_incarnations {
            mark(&mut timeline, AutoState::Failed);
            return Err(Error::Workload(format!(
                "incarnation budget ({}) exhausted",
                policy.max_incarnations
            )));
        }
        let jobid = format!("{}{:02}", seed % 900_000 + 100_000, incarnation);
        let cfg = CrConfig::new(jobid, workdir);
        mark(&mut timeline, AutoState::Starting);
        let (coord, env) = start_coordinator(&cfg)?;

        // Launch fresh or restart from the newest image.
        let images = latest_images(&cfg.ckpt_dir)?;
        let state: Arc<Mutex<G4SimState>>;
        let mut launched;
        let mut plugins = PluginRegistry::new();
        plugins.register(Box::new(TimerPlugin::new()));
        if incarnation == 0 {
            assert!(images.is_empty(), "stale images in a fresh workdir");
            state = Arc::new(Mutex::new(app.fresh_state(batch, target_steps, seed)));
            let mut spec = LaunchSpec::new(format!("g4-{}", app.kind.label()), coord.addr());
            spec.env = env.clone();
            launched = dmtcp_launch(spec, Arc::clone(&state), plugins);
        } else {
            mark(&mut timeline, AutoState::Restarting);
            let image = images
                .last()
                .ok_or_else(|| Error::Workload("requeued but no checkpoint image".into()))?;
            state = Arc::new(Mutex::new(app.shell_state()));
            let restarted = dmtcp_restart(image, coord.addr(), Arc::clone(&state), plugins)?;
            restart_steps.push(restarted.header.steps_done);
            launched = restarted.launched;
        }
        launched.wait_attached(Duration::from_secs(10))?;

        // Spawn the transport workers.
        for _ in 0..policy.n_threads {
            let ctx_state = Arc::clone(&state);
            let h = handle.clone();
            let si = Arc::clone(&app.si);
            let spq = policy.scans_per_quantum;
            launched
                .process
                .spawn_user_thread(move |ctx| transport_worker(ctx, h, ctx_state, si, spq));
        }
        // (Re)start the LDMS sampler over this incarnation's process.
        if let Some(s) = sampler.take() {
            merge_series(&mut series_acc, s.stop());
        }
        sampler = Some(LdmsSampler::start(
            vec![Arc::clone(&launched.process.stats)],
            Duration::from_millis(3),
        ));
        mark(&mut timeline, AutoState::Running);

        // Drive this incarnation: periodic checkpoints + preemption plan.
        let inc_start = Instant::now();
        let preempt_at = policy.preempt_after.get(incarnation as usize).copied();
        let mut next_ckpt = policy.ckpt_interval;
        let outcome = loop {
            std::thread::sleep(Duration::from_millis(5));
            let done = state.lock().expect("state poisoned").done();
            if done {
                break IncOutcome::Completed;
            }
            let ran = inc_start.elapsed();
            if let Some(p) = preempt_at {
                if ran >= p {
                    break IncOutcome::Preempted;
                }
            }
            if policy.periodic_ckpt && ran >= next_ckpt {
                mark(&mut timeline, AutoState::Checkpointing);
                match coord.checkpoint_all() {
                    Ok(images) => {
                        checkpoints += 1;
                        total_image_bytes +=
                            images.iter().map(|i| i.stored_bytes).sum::<u64>();
                        total_raw_bytes += images.iter().map(|i| i.raw_bytes).sum::<u64>();
                    }
                    Err(e) => log::warn!("periodic checkpoint failed: {e}"),
                }
                mark(&mut timeline, AutoState::Running);
                next_ckpt += policy.ckpt_interval;
            }
        };

        match outcome {
            IncOutcome::Completed => {
                coord.kill_all();
                let process = launched.join();
                if let Some(s) = sampler.take() {
                    merge_series(&mut series_acc, s.stop());
                }
                drop(process);
                mark(&mut timeline, AutoState::Completed);
                let final_state = state.lock().expect("state poisoned").clone();
                return Ok(CrReport {
                    completed: true,
                    incarnations: incarnation + 1,
                    checkpoints,
                    total_image_bytes,
                    total_raw_bytes,
                    wall_secs: t0.elapsed().as_secs_f64(),
                    timeline,
                    final_state,
                    series: series_acc.unwrap_or_default(),
                    restart_steps,
                });
            }
            IncOutcome::Preempted => {
                // func_trap: SIGTERM trapped → checkpoint → requeue.
                mark(&mut timeline, AutoState::SignalTrapped);
                if policy.ckpt_on_signal {
                    match coord.checkpoint_all() {
                        Ok(images) => {
                            checkpoints += 1;
                            total_image_bytes +=
                                images.iter().map(|i| i.stored_bytes).sum::<u64>();
                            total_raw_bytes += images.iter().map(|i| i.raw_bytes).sum::<u64>();
                        }
                        Err(e) => log::warn!("trap checkpoint failed: {e}"),
                    }
                }
                coord.kill_all();
                let _ = launched.join();
                if let Some(s) = sampler.take() {
                    merge_series(&mut series_acc, s.stop());
                }
                mark(&mut timeline, AutoState::Requeued);
                std::thread::sleep(policy.requeue_delay);
                incarnation += 1;
            }
        }
        drop(coord); // fresh coordinator next incarnation
    }
}

enum IncOutcome {
    Completed,
    Preempted,
}

/// Concatenate sampler outputs across incarnations (time axes are
/// per-incarnation; offset each segment by the accumulated end time).
fn merge_series(acc: &mut Option<SampledSeries>, next: SampledSeries) {
    match acc {
        None => *acc = Some(next),
        Some(a) => {
            let offset = a.memory.t.last().copied().unwrap_or(0.0);
            for (dst, src) in [
                (&mut a.memory, &next.memory),
                (&mut a.cpu, &next.cpu),
                (&mut a.steps, &next.steps),
            ] {
                for (&t, &v) in src.t.iter().zip(&src.v) {
                    dst.push(offset + t, v);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_default_sane() {
        let p = CrPolicy::default();
        assert!(p.periodic_ckpt && p.ckpt_on_signal);
        assert!(p.max_incarnations > 1);
    }

    #[test]
    fn merge_series_offsets_time() {
        let mut a = SampledSeries::default();
        a.memory.push(0.0, 1.0);
        a.memory.push(1.0, 2.0);
        let mut b = SampledSeries::default();
        b.memory.push(0.0, 3.0);
        b.memory.push(0.5, 4.0);
        let mut acc = Some(a);
        merge_series(&mut acc, b);
        let m = &acc.unwrap().memory;
        assert_eq!(m.t, vec![0.0, 1.0, 1.0, 1.5]);
        assert_eq!(m.v, vec![1.0, 2.0, 3.0, 4.0]);
    }
}
