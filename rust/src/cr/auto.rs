//! Automated C/R strategy types — the paper's Fig 3 workflow, as data.
//!
//! "Users initiate their computational tasks with batch scripts that
//! include DMTCP within the container ... a `restart_job` function that
//! integrates a `start_coordinator` to launch the checkpointing mechanism,
//! followed by the execution command `dmtcp_launch` ... handling
//! termination signals such as SIGTERM ... thereby triggering a requeue
//! function".
//!
//! The orchestration itself lives in [`crate::cr::session::CrSession`]:
//! build a session with `CrStrategy::Auto(CrPolicy)` and call
//! [`crate::cr::session::CrSession::run`], which drives the full lifecycle
//! — coordinator per incarnation, periodic `dmtcp_command --checkpoint`,
//! the preemption plan, func_trap checkpoint-on-signal, requeue delay,
//! restart from the newest image — until the workload completes or the
//! incarnation budget is exhausted. This module keeps the policy/report
//! types.

use std::time::Duration;

use crate::dmtcp::store::ChunkerSpec;
use crate::metrics::SampledSeries;
use crate::workload::G4SimState;

/// Fig 3 states (the workflow diagram, as data).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AutoState {
    /// Job handed to the batch system.
    Submitted,
    /// Coordinator boot + launch/restart in progress.
    Starting,
    /// Transport workers advancing the state.
    Running,
    /// A coordinator-wide checkpoint barrier is in flight.
    Checkpointing,
    /// A preemption signal was trapped (func_trap).
    SignalTrapped,
    /// Waiting in the queue after a requeue.
    Requeued,
    /// Restoring state from the newest image.
    Restarting,
    /// Workload reached its target step count.
    Completed,
    /// Incarnation budget exhausted (or unrecoverable error).
    Failed,
}

impl AutoState {
    /// Stable lowercase label for trace attributes and reports.
    pub fn label(&self) -> &'static str {
        match self {
            AutoState::Submitted => "submitted",
            AutoState::Starting => "starting",
            AutoState::Running => "running",
            AutoState::Checkpointing => "checkpointing",
            AutoState::SignalTrapped => "signal_trapped",
            AutoState::Requeued => "requeued",
            AutoState::Restarting => "restarting",
            AutoState::Completed => "completed",
            AutoState::Failed => "failed",
        }
    }
}

/// Policy knobs for one automated C/R run.
#[derive(Debug, Clone)]
pub struct CrPolicy {
    /// Periodic checkpoint interval (wall clock).
    pub ckpt_interval: Duration,
    /// Preemption plan: incarnation `i` is SIGTERM'd after
    /// `preempt_after[i]` of runtime (absent = runs to completion/limit).
    pub preempt_after: Vec<Duration>,
    /// Queue wait between requeue and restart (the Fig 4 gap).
    pub requeue_delay: Duration,
    /// Give up after this many incarnations.
    pub max_incarnations: u32,
    /// Take a func_trap checkpoint on the preemption signal (the paper's
    /// automated flow) — disable to model plain preemption.
    pub ckpt_on_signal: bool,
    /// Checkpoint periodically at all (false = the paper's "without C/R").
    pub periodic_ckpt: bool,
    /// Worker threads per process.
    pub n_threads: u32,
    /// Work quanta (scans/sweeps) between checkpoint safe-points.
    pub scans_per_quantum: u32,
    /// Write incremental (content-addressed, chunked) checkpoint images:
    /// after a small state delta only the changed chunks are compressed
    /// and stored. Off reproduces the paper's whole-image-gzip baseline.
    pub incremental_ckpt: bool,
    /// With `incremental_ckpt`, force every Nth checkpoint of an
    /// incarnation back to a self-contained full image (0 = never) — a
    /// periodic anchor that restores independently of the chunk store and
    /// bounds how many generations a damaged store entry can poison.
    /// Defaults to 16 so flipping `incremental_ckpt` on inherits a sane
    /// anchor cadence.
    pub full_image_every: u32,
    /// Chunk-store GC grace window applied at session teardown: chunks
    /// younger than this are never reclaimed, protecting a concurrent
    /// session (sharing the workdir's store) that stored chunks but has
    /// not yet published the manifest referencing them. Campaigns with
    /// fast session churn over one shared store tune this; the default
    /// ([`crate::cr::session::GC_GRACE`], 10 min) comfortably exceeds any
    /// plausible single checkpoint write.
    pub gc_grace: Duration,
    /// How incremental images split segments into chunks
    /// ([`ChunkerSpec::Fixed`] offsets, or content-defined `Cdc` so
    /// insert-shifted state keeps deduping). Ignored unless
    /// `incremental_ckpt` is on. Spec key `chunker =`, CLI `--chunker`.
    pub chunker: ChunkerSpec,
}

impl Default for CrPolicy {
    fn default() -> Self {
        Self {
            ckpt_interval: Duration::from_millis(300),
            preempt_after: Vec::new(),
            requeue_delay: Duration::from_millis(100),
            max_incarnations: 8,
            ckpt_on_signal: true,
            periodic_ckpt: true,
            n_threads: 1,
            scans_per_quantum: 1,
            incremental_ckpt: false,
            full_image_every: 16,
            gc_grace: crate::cr::session::GC_GRACE,
            chunker: ChunkerSpec::Fixed,
        }
    }
}

/// Outcome of an automated run, generic over the application state (the
/// default keeps the historical Geant4-analog shape).
#[derive(Debug)]
pub struct CrReport<S = G4SimState> {
    /// Whether the workload reached its target step count.
    pub completed: bool,
    /// Batch-job incarnations used (1 = never preempted).
    pub incarnations: u32,
    /// Checkpoints taken across all incarnations.
    pub checkpoints: u64,
    /// Stored (possibly compressed) checkpoint bytes written.
    pub total_image_bytes: u64,
    /// Raw (uncompressed) checkpoint bytes serialized.
    pub total_raw_bytes: u64,
    /// `(elapsed_secs, state)` transitions.
    pub timeline: Vec<(f64, AutoState)>,
    /// Wall time, start to terminal state.
    pub wall_secs: f64,
    /// The final application state (for bitwise verification).
    pub final_state: S,
    /// LDMS series across the whole run (all incarnations).
    pub series: SampledSeries,
    /// Steps at each restart (monotone; proves no lost progress).
    pub restart_steps: Vec<u64>,
    /// Chunks newly written to the content-addressed store (0 when
    /// `incremental_ckpt` is off).
    pub chunks_written: u64,
    /// Chunks reused instead of rewritten — the incremental pipeline's
    /// savings, in chunk counts.
    pub chunks_deduped: u64,
    /// Restore-pipeline seconds spent reading chunk files, summed across
    /// all restarts (0.0 when every restart decoded a v1 full image).
    pub restore_read_secs: f64,
    /// Restore-pipeline seconds spent decompressing chunk payloads,
    /// summed across all restarts.
    pub restore_decompress_secs: f64,
    /// Restore-pipeline seconds spent CRC-verifying restored bytes,
    /// summed across all restarts.
    pub restore_verify_secs: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_labels_distinct_and_lowercase() {
        let all = [
            AutoState::Submitted,
            AutoState::Starting,
            AutoState::Running,
            AutoState::Checkpointing,
            AutoState::SignalTrapped,
            AutoState::Requeued,
            AutoState::Restarting,
            AutoState::Completed,
            AutoState::Failed,
        ];
        let mut labels: Vec<&str> = all.iter().map(|s| s.label()).collect();
        assert!(labels
            .iter()
            .all(|l| l.chars().all(|c| c.is_ascii_lowercase() || c == '_')));
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), all.len());
    }

    #[test]
    fn policy_default_sane() {
        let p = CrPolicy::default();
        assert!(p.periodic_ckpt && p.ckpt_on_signal);
        assert!(p.max_incarnations > 1);
    }
}
