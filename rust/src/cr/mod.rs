//! The paper's contribution: the NERSC checkpoint-restart job-management
//! layer, entered through one session-first API.
//!
//! * [`session`] — [`CrSession`]: builder-style orchestration over any
//!   [`CrApp`] workload, on any [`Substrate`] (bare / shifter /
//!   podman-hpc), driven automatically ([`CrStrategy::Auto`], the Fig 3
//!   workflow) or by an operator ([`CrStrategy::Manual`], §V.B.2).
//! * [`app`] — the [`CrApp`] trait both paper workloads implement
//!   (Geant4-analog transport and the CP2K-analog SCF driver), plus the
//!   multi-rank [`GangApp`] contract for distributed computations.
//! * [`gang`] — [`GangSession`]: gang checkpoint-restart of N
//!   communicating ranks through one all-or-nothing barrier, committed by
//!   an atomically published consistent-cut manifest (DESIGN §10).
//! * [`substrate`] — the [`Substrate`] execution environments, enforcing
//!   the paper's containerized-C/R constraints.
//! * [`module`] — the CR Module primitives (`start_coordinator`, image
//!   discovery, environment wiring, the incremental-image knobs).
//! * [`auto`] — the Fig 3 policy/report types ([`CrPolicy`],
//!   [`CrReport`]).
//! * [`jobscript`] — the consolidated single job script.
//!
//! The pre-0.3 entry points (`run_auto`, `ManualCr`,
//! `Container::launch_checkpointed`) were deprecated in 0.3 and are now
//! removed; see the migration table in `CHANGES.md`.

pub mod app;
pub mod auto;
pub mod gang;
pub mod jobscript;
pub mod module;
pub mod session;
pub mod substrate;

pub use app::{CrApp, GangApp};
pub use auto::{AutoState, CrPolicy, CrReport};
pub use gang::{GangCheckpoint, GangSession, GangSessionBuilder, GangStatus};
pub use jobscript::{consolidated_script, CrJobConfig};
pub use module::{
    latest_images, start_coordinator, start_coordinator_on, CoordinatorHandle, CrConfig,
};
pub use session::{CrSession, CrSessionBuilder, CrStrategy, SessionStatus, GC_GRACE};
pub use substrate::Substrate;
