//! The paper's contribution: the NERSC checkpoint-restart job-management
//! layer.
//!
//! * [`module`] — the CR Module primitives (`start_coordinator`, image
//!   discovery, environment wiring).
//! * [`auto`] — the automated Fig 3 workflow: periodic checkpoints,
//!   func_trap on preemption signals, requeue, restart-from-image.
//! * [`manual`] — the operator-in-the-loop flow (§V.B.2).
//! * [`jobscript`] — the consolidated single job script.

pub mod auto;
pub mod jobscript;
pub mod manual;
pub mod module;

pub use auto::{run_auto, AutoState, CrPolicy, CrReport};
pub use jobscript::{consolidated_script, CrJobConfig};
pub use manual::{ManualCr, MonitorReport};
pub use module::{latest_images, start_coordinator, CrConfig};
