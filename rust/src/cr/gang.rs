//! Gang checkpoint-restart: one session driving *all ranks* of a
//! distributed computation — [`GangSession`].
//!
//! The paper's subject is **Distributed** MultiThreaded CheckPointing: an
//! all-or-nothing coordinated checkpoint of a cluster computation with
//! in-flight data drained, followed by a consistent gang restart. This
//! module is that layer. One coordinator manages every rank of one
//! [`GangApp`] computation; [`GangSession::checkpoint_now`] drives them
//! through a single five-phase barrier
//! ([`crate::dmtcp::Coordinator::checkpoint_gang`]) and commits the round
//! by atomically publishing a [`GangManifest`] — the generation-stamped
//! consistent cut tying the per-rank images together. Rank images are
//! round-stamped (`DMTCP_IMAGE_PER_ROUND`), so a published manifest's
//! image set is immutable; an aborted round leaves at most unreferenced
//! debris, never a torn set (invariant 7, DESIGN §10).
//!
//! Restart is symmetric: [`GangSession::resubmit_from_checkpoint`] reads
//! the newest manifest and restarts *every* rank from its image — onto
//! the same substrate or a different one ([`GangSession::set_substrate`]),
//! always rank-count-preserving. Each rank's state is wrapped in a
//! [`ManaState`]: with exclusion on (the default), `lib:` lower-half
//! segments never enter the images and the app's per-rank `reinit` hook
//! rebuilds channels against the new incarnation's fabric; with exclusion
//! off, the whole-process baseline of the MANA ablation runs through the
//! very same path.
//!
//! The operator vocabulary mirrors [`crate::cr::CrSession`]'s §V.B.2
//! methods:
//! `submit` / `monitor` / `checkpoint_now` / `kill` (+ the gang-specific
//! [`GangSession::kill_rank`] fault injection — losing *any* rank aborts
//! the generation) / `resubmit_from_checkpoint` / `wait_done` / `finish`.

#![deny(missing_docs)]

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::cr::app::GangApp;
use crate::cr::module::{CoordinatorHandle, CrConfig};
use crate::cr::session::{merge_series, next_nonce, GC_GRACE};
use crate::dmtcp::process::Checkpointable;
use crate::dmtcp::store::{
    gang_manifests, latest_gang_manifest, ChunkerSpec, GangManifest, GangRankEntry, ImageStore,
};
use crate::dmtcp::{
    inspect_image, Coordinator, LaunchedProcess, ManaState, PluginRegistry, TimerPlugin,
};
use crate::error::{Error, Result};
use crate::metrics::{LdmsSampler, SampledSeries};

use super::substrate::Substrate;

/// How long to wait for the coordinator to assign each rank's virtual pid.
const ATTACH_TIMEOUT: Duration = Duration::from_secs(10);

/// Poll interval of [`GangSession::wait_done`].
const POLL: Duration = Duration::from_millis(5);

/// What [`GangSession::monitor`] reports: the gang moves at the pace of
/// its slowest rank.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GangStatus {
    /// The slowest rank's completed steps.
    pub steps_done: u64,
    /// Steps every rank must complete.
    pub target_steps: u64,
    /// Whether *every* rank reached the target.
    pub done: bool,
    /// Slowest-rank progress in `[0, 1]`.
    pub progress: f64,
    /// Ranks in the gang.
    pub ranks: u32,
    /// Ranks whose process is still alive (a dead rank means the
    /// generation is lost — kill and gang-restart).
    pub alive_ranks: u32,
}

/// One committed gang checkpoint: the manifest and where it was published.
#[derive(Debug, Clone)]
pub struct GangCheckpoint {
    /// Path of the atomically published gang manifest.
    pub manifest_path: PathBuf,
    /// The consistent-cut record itself.
    pub manifest: GangManifest,
}

/// Builder for [`GangSession`] — `workdir` is required, everything else
/// has gang-sensible defaults (MANA exclusion on).
pub struct GangSessionBuilder<A: GangApp> {
    app: A,
    substrate: Substrate,
    workdir: Option<PathBuf>,
    target_steps: u64,
    seed: u64,
    mana_exclusion: bool,
    incremental: Option<u32>,
    chunker: ChunkerSpec,
    work_per_quantum: u32,
    gc_grace: Duration,
    coordinator: CoordinatorHandle,
}

impl<A: GangApp> GangSessionBuilder<A> {
    /// Select the execution environment (default: bare processes).
    pub fn substrate(mut self, substrate: Substrate) -> Self {
        self.substrate = substrate;
        self
    }

    /// Where the rendezvous file, `ckpt/` images and gang manifests live
    /// (required; must survive the job).
    pub fn workdir(mut self, workdir: impl Into<PathBuf>) -> Self {
        self.workdir = Some(workdir.into());
        self
    }

    /// Steps every rank must complete (0 = trivially done).
    pub fn target_steps(mut self, target_steps: u64) -> Self {
        self.target_steps = target_steps;
        self
    }

    /// Workload seed (also folded into the job id).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// MANA lower-half exclusion (default **on**): `lib:` segments are
    /// omitted from rank images and rebuilt by the app's `reinit` hook on
    /// restart. Off = the whole-process DMTCP baseline of the ablation.
    pub fn mana_exclusion(mut self, on: bool) -> Self {
        self.mana_exclusion = on;
        self
    }

    /// Write incremental (content-addressed, chunked) rank images,
    /// forcing every Nth checkpoint back to a self-contained full image
    /// (0 = never).
    pub fn incremental_images(mut self, full_image_every: u32) -> Self {
        self.incremental = Some(full_image_every);
        self
    }

    /// How incremental rank images split segments into chunks
    /// ([`ChunkerSpec::Fixed`] offsets, or content-defined `Cdc` so
    /// insert-shifted rank state keeps deduping). Validated at
    /// [`GangSessionBuilder::build`]; ignored without
    /// [`GangSessionBuilder::incremental_images`].
    pub fn chunker(mut self, spec: ChunkerSpec) -> Self {
        self.chunker = spec;
        self
    }

    /// Work quanta between checkpoint safe-points in each rank worker.
    pub fn work_per_quantum(mut self, quanta: u32) -> Self {
        self.work_per_quantum = quanta.max(1);
        self
    }

    /// Override the chunk-store GC grace window applied at teardown.
    pub fn gc_grace(mut self, grace: Duration) -> Self {
        self.gc_grace = grace;
        self
    }

    /// How this gang obtains its coordinator (default
    /// [`CoordinatorHandle::Private`]). With [`CoordinatorHandle::Shared`]
    /// every incarnation registers its job on the given multi-tenant
    /// daemon, and all ranks' barriers multiplex over its single port.
    pub fn coordinator(mut self, handle: CoordinatorHandle) -> Self {
        self.coordinator = handle;
        self
    }

    /// Validate and assemble the session (creates the workdir).
    pub fn build(self) -> Result<GangSession<A>> {
        let workdir = self.workdir.ok_or_else(|| {
            Error::Workload("GangSession needs a workdir (builder .workdir(..))".into())
        })?;
        if self.app.n_ranks() == 0 {
            return Err(Error::Workload("a gang needs at least one rank".into()));
        }
        self.chunker.validate()?;
        std::fs::create_dir_all(&workdir)?;
        Ok(GangSession {
            app: self.app,
            substrate: self.substrate,
            workdir,
            target_steps: self.target_steps,
            seed: self.seed,
            mana_exclusion: self.mana_exclusion,
            incremental: self.incremental,
            chunker: self.chunker,
            work_per_quantum: self.work_per_quantum,
            gc_grace: self.gc_grace,
            coordinator_handle: self.coordinator,
            nonce: next_nonce(),
            generation: 0,
            submitted: false,
            active: None,
            series_acc: None,
            restore_phases: [0.0; 3],
            manifest_fallbacks: 0,
        })
    }
}

/// One launched rank of the active incarnation.
struct RankSlot<S: Checkpointable> {
    state: Arc<Mutex<S>>,
    launched: LaunchedProcess,
}

struct ActiveGang<S: Checkpointable> {
    coordinator: Coordinator,
    slots: Vec<RankSlot<S>>,
    sampler: Option<LdmsSampler>,
}

/// A gang checkpoint-restart session: one distributed computation, one
/// substrate, any number of incarnations. Built with
/// [`GangSession::builder`].
pub struct GangSession<A: GangApp> {
    app: A,
    substrate: Substrate,
    workdir: PathBuf,
    target_steps: u64,
    seed: u64,
    mana_exclusion: bool,
    incremental: Option<u32>,
    chunker: ChunkerSpec,
    work_per_quantum: u32,
    gc_grace: Duration,
    coordinator_handle: CoordinatorHandle,
    nonce: u64,
    generation: u32,
    submitted: bool,
    active: Option<ActiveGang<A::RankState>>,
    series_acc: Option<SampledSeries>,
    /// Restore-pipeline `[read, decompress, verify]` seconds summed over
    /// every rank restart of every incarnation (v2 manifest images only).
    restore_phases: [f64; 3],
    /// Gang restarts that had to skip a corrupt newest cut and fall back
    /// to an older committed manifest (store-domain recoveries).
    manifest_fallbacks: u32,
}

impl<A: GangApp> GangSession<A> {
    /// Start a builder for `app` (anything implementing [`GangApp`], by
    /// value or by reference).
    pub fn builder(app: A) -> GangSessionBuilder<A> {
        GangSessionBuilder {
            app,
            substrate: Substrate::Bare,
            workdir: None,
            target_steps: 0,
            seed: 0,
            mana_exclusion: true,
            incremental: None,
            chunker: ChunkerSpec::Fixed,
            work_per_quantum: 1,
            gc_grace: GC_GRACE,
            coordinator: CoordinatorHandle::Private,
        }
    }

    /// The Slurm-style job id of the current incarnation (nonce-scoped,
    /// like [`crate::cr::CrSession::jobid`]).
    pub fn jobid(&self) -> String {
        format!(
            "{}g{}i{:02}",
            self.seed % 900_000 + 100_000,
            self.nonce,
            self.generation
        )
    }

    /// The incarnation-independent prefix every [`GangSession::jobid`] of
    /// this session starts with (`{base}g{nonce}i`). The literal `i`
    /// terminator keeps a nonce from prefix-matching a longer nonce, so
    /// flight-dump attribution in a shared workdir can filter scans by
    /// `job.starts_with(prefix)`.
    pub fn job_prefix(&self) -> String {
        format!("{}g{}i", self.seed % 900_000 + 100_000, self.nonce)
    }

    /// Store-domain recoveries so far: gang restarts that skipped a
    /// corrupt newest cut and restored an older committed manifest.
    pub fn manifest_fallbacks(&self) -> u32 {
        self.manifest_fallbacks
    }

    /// The gang's process-name base; rank processes are
    /// `<base>-r<rank>`, and image/manifest discovery is scoped by it.
    pub fn gang_name(&self) -> String {
        format!("{}-s{}", self.app.label(), self.nonce)
    }

    fn rank_name(&self, rank: u32) -> String {
        format!("{}-r{rank:03}", self.gang_name())
    }

    fn ckpt_dir(&self) -> PathBuf {
        self.workdir.join("ckpt")
    }

    /// Incarnations used so far (0 = the initial submission).
    pub fn generation(&self) -> u32 {
        self.generation
    }

    /// The substrate the gang launches on.
    pub fn substrate(&self) -> &Substrate {
        &self.substrate
    }

    /// Switch substrate between incarnations (checkpoint under one
    /// runtime, gang-restart under another). Fails while a gang is live.
    pub fn set_substrate(&mut self, substrate: Substrate) -> Result<()> {
        if self.active.is_some() {
            return Err(Error::Workload(
                "kill the active gang before switching substrates".into(),
            ));
        }
        self.substrate = substrate;
        Ok(())
    }

    /// The coordinator of the active incarnation.
    pub fn coordinator(&self) -> Result<&Coordinator> {
        Ok(&self.gang()?.coordinator)
    }

    /// The newest committed gang checkpoint of this session, if any.
    pub fn latest_checkpoint(&self) -> Result<Option<GangCheckpoint>> {
        Ok(
            latest_gang_manifest(&self.ckpt_dir(), &self.gang_name())?.map(
                |(manifest_path, manifest)| GangCheckpoint {
                    manifest_path,
                    manifest,
                },
            ),
        )
    }

    fn gang(&self) -> Result<&ActiveGang<A::RankState>> {
        self.active
            .as_ref()
            .ok_or_else(|| Error::Workload("no active gang".into()))
    }

    /// Restore-pipeline `[read, decompress, verify]` seconds summed over
    /// every rank restart so far (all `[0.0; 3]` when every restart decoded
    /// a v1 full image — the phases only exist for v2 manifest restores).
    pub fn restore_phase_secs(&self) -> [f64; 3] {
        self.restore_phases
    }

    /// Boot one incarnation: coordinator, fabric rebuild, then every rank
    /// launched (generation 0) or restored from the newest gang manifest
    /// (later generations), workers spawned, sampler started. Returns
    /// `Some(cut steps)` when restoring.
    fn boot(&mut self) -> Result<Option<u64>> {
        if self.active.is_some() {
            return Err(Error::Workload("gang already active".into()));
        }
        let name = if self.generation == 0 {
            crate::trace::names::GANG_LAUNCH
        } else {
            crate::trace::names::GANG_RESTART
        };
        let mut sp = crate::trace::span(name)
            .with("job", || self.jobid())
            .with_u64("generation", self.generation as u64);
        let res = self.boot_inner();
        match &res {
            Ok(Some(at)) => sp.note_u64("resumed_at", *at),
            Ok(None) => {}
            Err(e) => {
                sp.fail(&e.to_string());
                drop(sp);
                crate::trace::flight::dump_for_job(
                    &self.jobid(),
                    &format!("gang boot failed: {e}"),
                    &self.ckpt_dir(),
                );
            }
        }
        res
    }

    fn boot_inner(&mut self) -> Result<Option<u64>> {
        let mut cfg = CrConfig::new(self.jobid(), &self.workdir);
        if let Some(full_every) = self.incremental {
            cfg.incremental = true;
            cfg.full_image_every = full_every;
            cfg.chunker = self.chunker;
        }
        let (coordinator, base_env) = self.coordinator_handle.start(&cfg)?;
        self.app.begin_incarnation(self.generation);
        let n = self.app.n_ranks();

        let (mut slots, resumed_at) = if self.generation == 0 {
            let mut slots: Vec<RankSlot<A::RankState>> = Vec::with_capacity(n as usize);
            for rank in 0..n {
                let mut plugins = PluginRegistry::new();
                plugins.register(Box::new(TimerPlugin::new()));
                let name = self.rank_name(rank);
                let state = Arc::new(Mutex::new(self.app.fresh_rank_state(
                    rank,
                    self.target_steps,
                    self.seed,
                )?));
                self.app.register_rank_plugins(rank, &state, &mut plugins);
                let wrapped = Arc::new(Mutex::new(ManaState::with_exclusion(
                    Arc::clone(&state),
                    self.app.reinit_fn(rank),
                    self.mana_exclusion,
                )));
                let mut env = base_env.clone();
                env.insert("DMTCP_RANK".into(), rank.to_string());
                env.insert("DMTCP_IMAGE_PER_ROUND".into(), "1".into());
                let launched = self.substrate.launch(
                    &name,
                    coordinator.addr(),
                    env,
                    wrapped,
                    plugins,
                )?;
                slots.push(RankSlot { state, launched });
            }
            (slots, None)
        } else {
            let candidates = gang_manifests(&self.ckpt_dir(), &self.gang_name())?;
            let newest_id = candidates
                .first()
                .map(|(_, m)| m.ckpt_id)
                .ok_or_else(|| Error::Workload("requeued but no gang manifest".into()))?;
            // Round ids must stay unique across incarnations: a fresh
            // coordinator would reuse a committed cut's round id and
            // overwrite the very files its manifest references. Seed
            // above the NEWEST cut even when a store-corruption fallback
            // restores an older one, so new rounds cannot collide with
            // the retained newer manifest's file names.
            coordinator.bump_ckpt_id_to(newest_id + 1);
            self.restore_gang(&coordinator, &base_env, n, candidates)?
        };
        for slot in &slots {
            slot.launched.wait_attached(ATTACH_TIMEOUT)?;
        }
        for (rank, slot) in slots.iter_mut().enumerate() {
            self.app.spawn_rank_workers(
                rank as u32,
                &mut slot.launched,
                Arc::clone(&slot.state),
                self.work_per_quantum,
            )?;
        }
        let sampler = LdmsSampler::start(
            slots
                .iter()
                .map(|s| Arc::clone(&s.launched.process.stats))
                .collect(),
            Duration::from_millis(3),
        );
        self.active = Some(ActiveGang {
            coordinator,
            slots,
            sampler: Some(sampler),
        });
        Ok(resumed_at)
    }

    /// Restore every rank from the newest *restorable* committed cut:
    /// candidates are tried newest-first, and a typed [`Error::Corrupt`]
    /// from any rank restore (fleet-scale chunk-store damage under that
    /// cut) tears the partial attempt down and falls back to the next
    /// older manifest — losing at most the work between the two cuts,
    /// the store-domain bound of DESIGN §9. Any other error propagates
    /// unchanged, and a gang whose every candidate is corrupt surfaces
    /// the last typed error rather than panicking.
    fn restore_gang(
        &mut self,
        coordinator: &Coordinator,
        base_env: &BTreeMap<String, String>,
        n: u32,
        candidates: Vec<(PathBuf, GangManifest)>,
    ) -> Result<(Vec<RankSlot<A::RankState>>, Option<u64>)> {
        let mut last_corrupt = None;
        for (path, manifest) in &candidates {
            if manifest.n_ranks() != n {
                return Err(Error::Workload(format!(
                    "gang manifest covers {} ranks, app wants {n} \
                     (gang restart is rank-count-preserving)",
                    manifest.n_ranks()
                )));
            }
            let mut slots: Vec<RankSlot<A::RankState>> = Vec::with_capacity(n as usize);
            let mut corrupt = None;
            for rank in 0..n {
                let mut plugins = PluginRegistry::new();
                plugins.register(Box::new(TimerPlugin::new()));
                let entry = &manifest.ranks[rank as usize];
                let image = self.ckpt_dir().join(&entry.image);
                let state = Arc::new(Mutex::new(self.app.restore_rank_state(rank)));
                self.app.register_rank_plugins(rank, &state, &mut plugins);
                let wrapped = Arc::new(Mutex::new(ManaState::with_exclusion(
                    Arc::clone(&state),
                    self.app.reinit_fn(rank),
                    self.mana_exclusion,
                )));
                // Re-tag the rank with this incarnation's coordinator
                // routing (DMTCP_JOB names the previous incarnation's
                // job inside the image); the rank's position itself is
                // preserved by the image's DMTCP_RANK.
                match self
                    .substrate
                    .restart(&image, coordinator.addr(), wrapped, plugins, base_env)
                {
                    Ok(restarted) => {
                        if let Some(rs) = &restarted.restore {
                            self.restore_phases[0] += rs.read_secs;
                            self.restore_phases[1] += rs.decompress_secs;
                            self.restore_phases[2] += rs.verify_secs;
                        }
                        slots.push(RankSlot {
                            state,
                            launched: restarted.launched,
                        });
                    }
                    Err(e @ Error::Corrupt(_)) => {
                        corrupt = Some((rank, e));
                        break;
                    }
                    Err(e) => {
                        Self::abandon_slots(slots);
                        return Err(e);
                    }
                }
            }
            let Some((rank, e)) = corrupt else {
                // The gang resumes from the cut: the slowest rank's step
                // at the checkpoint (each rank still restores at its own
                // recorded step — cut consistency covers the skew).
                return Ok((slots, Some(manifest.cut_steps())));
            };
            Self::abandon_slots(slots);
            self.manifest_fallbacks += 1;
            log::warn!(
                "gang {}: cut {} is corrupt at rank {rank} ({e}), falling back to an \
                 older committed manifest",
                self.nonce,
                path.display()
            );
            crate::trace::flight::dump_for_job_in_domain(
                &self.jobid(),
                &format!("corrupt gang cut {}: rank {rank}: {e}", path.display()),
                &self.ckpt_dir(),
                "store",
            );
            last_corrupt = Some(e);
        }
        Err(last_corrupt.expect("restore loop saw at least one candidate"))
    }

    /// Kill and reap the rank processes of an abandoned restore attempt.
    fn abandon_slots(slots: Vec<RankSlot<A::RankState>>) {
        for slot in slots {
            slot.launched.process.gate.kill();
            let _ = slot.launched.join();
        }
    }

    fn teardown(&mut self) -> Result<Vec<Arc<Mutex<A::RankState>>>> {
        let ActiveGang {
            coordinator,
            slots,
            mut sampler,
        } = self
            .active
            .take()
            .ok_or_else(|| Error::Workload("no active gang".into()))?;
        coordinator.kill_all();
        let mut states = Vec::with_capacity(slots.len());
        for slot in slots {
            let _ = slot.launched.join();
            states.push(slot.state);
        }
        if let Some(s) = sampler.take() {
            merge_series(&mut self.series_acc, s.stop());
        }
        Ok(states)
    }

    // ----- observation ---------------------------------------------------

    /// Inspect the running gang. The gang moves at its slowest rank.
    pub fn monitor(&self) -> Result<GangStatus> {
        let gang = self.gang()?;
        let mut min_steps = u64::MAX;
        let mut all_done = true;
        let mut alive = 0u32;
        for slot in &gang.slots {
            let s = slot.state.lock().expect("rank state poisoned");
            min_steps = min_steps.min(s.steps_done());
            if !self.app.rank_done(&s) {
                all_done = false;
            }
            // A rank is lost once its gate was killed (fault injection,
            // coordinator Kill, or a dead coordinator link) — normal
            // completion leaves the gate alone.
            if !slot.launched.process.gate.killed() {
                alive += 1;
            }
        }
        let steps_done = if min_steps == u64::MAX { 0 } else { min_steps };
        Ok(GangStatus {
            steps_done,
            target_steps: self.target_steps,
            done: all_done,
            progress: steps_done as f64 / self.target_steps.max(1) as f64,
            ranks: self.app.n_ranks(),
            alive_ranks: alive,
        })
    }

    /// Run a closure against one rank's live (locked) state.
    pub fn with_rank_state<R>(&self, rank: u32, f: impl FnOnce(&A::RankState) -> R) -> Result<R> {
        let gang = self.gang()?;
        let slot = gang
            .slots
            .get(rank as usize)
            .ok_or_else(|| Error::Workload(format!("no rank {rank} in this gang")))?;
        let s = slot.state.lock().expect("rank state poisoned");
        Ok(f(&s))
    }

    /// Snapshot every rank's state, rank order (for final verification).
    pub fn final_states(&self) -> Result<Vec<A::RankState>> {
        let gang = self.gang()?;
        Ok(gang
            .slots
            .iter()
            .map(|s| s.state.lock().expect("rank state poisoned").clone())
            .collect())
    }

    /// Verify a final rank vector bitwise against an uninterrupted
    /// reference run of this session's `(target_steps, seed)`.
    pub fn verify_final(&self, finals: &[A::RankState]) -> Result<()> {
        self.app.verify_final(finals, self.target_steps, self.seed)
    }

    /// The LDMS series accumulated across finished incarnations — one
    /// series covering all ranks (the per-gang rollup campaigns consume).
    pub fn series(&self) -> SampledSeries {
        self.series_acc.clone().unwrap_or_default()
    }

    /// Poll until every rank finishes or `timeout` elapses.
    pub fn wait_done(&self, timeout: Duration) -> Result<GangStatus> {
        let deadline = Instant::now() + timeout;
        loop {
            let st = self.monitor()?;
            if st.done {
                return Ok(st);
            }
            if st.alive_ranks < st.ranks {
                return Err(Error::Workload(format!(
                    "gang lost {} rank(s) at {}/{} steps: kill and gang-restart",
                    st.ranks - st.alive_ranks,
                    st.steps_done,
                    st.target_steps
                )));
            }
            if Instant::now() > deadline {
                return Err(Error::Workload(format!(
                    "gang timeout at {}/{} steps",
                    st.steps_done, st.target_steps
                )));
            }
            std::thread::sleep(POLL);
        }
    }

    // ----- lifecycle ------------------------------------------------------

    /// Initial submission: boot generation 0 (all ranks fresh).
    pub fn submit(&mut self) -> Result<()> {
        if self.submitted {
            return Err(Error::Workload(
                "gang already submitted; use resubmit_from_checkpoint".into(),
            ));
        }
        self.boot()?;
        self.submitted = true;
        Ok(())
    }

    /// Take an all-or-nothing gang checkpoint now: drive every rank
    /// through one barrier, then — only if *every* rank image of the
    /// round is durably published — commit the round by atomically
    /// writing the gang manifest. On any failure (a rank died
    /// mid-barrier, a phase timed out) nothing is committed and the
    /// previous manifest remains the newest restartable cut.
    pub fn checkpoint_now(&self) -> Result<GangCheckpoint> {
        let mut sp = crate::trace::span(crate::trace::names::GANG_CHECKPOINT)
            .with("job", || self.jobid())
            .with_u64("ranks", self.app.n_ranks() as u64);
        match self.checkpoint_now_inner() {
            Ok(ck) => {
                sp.note_u64("round", ck.manifest.ckpt_id);
                Ok(ck)
            }
            Err(e) => {
                sp.fail(&e.to_string());
                drop(sp);
                // The uncommitted round's daemon-side PHASE_FAIL pin (if
                // any) is already in the ring; persist it next to the
                // surviving manifests so the failure is explainable even
                // after the gang restarts.
                crate::trace::flight::dump_for_job(
                    &self.jobid(),
                    &format!("gang checkpoint failed: {e}"),
                    &self.ckpt_dir(),
                );
                Err(e)
            }
        }
    }

    fn checkpoint_now_inner(&self) -> Result<GangCheckpoint> {
        let gang = self.gang()?;
        let images = gang.coordinator.checkpoint_gang(self.app.n_ranks())?;
        let ckpt_dir = self.ckpt_dir();
        let ckpt_id = images.first().map(|(_, i)| i.ckpt_id).unwrap_or(0);
        let mut ranks = Vec::with_capacity(images.len());
        for (rank, info) in &images {
            // Header-only read: also proves each image file is present and
            // frame-valid before the manifest commits to it.
            let header = inspect_image(&info.path)?;
            let image = info
                .path
                .file_name()
                .map(|n| n.to_string_lossy().into_owned())
                .ok_or_else(|| {
                    Error::Image(format!("rank image path {:?} has no file name", info.path))
                })?;
            ranks.push(GangRankEntry {
                rank: *rank,
                vpid: info.vpid,
                image,
                steps_done: header.steps_done,
                stored_bytes: info.stored_bytes,
                raw_bytes: info.raw_bytes,
            });
        }
        let manifest = GangManifest {
            gang: self.gang_name(),
            generation: self.generation,
            ckpt_id,
            ranks,
        };
        let manifest_path = manifest.write_file(&ckpt_dir)?;
        self.prune_superseded_rounds(&manifest);
        Ok(GangCheckpoint {
            manifest_path,
            manifest,
        })
    }

    /// Best-effort cleanup of superseded rounds, retaining the newest
    /// committed round *and its immediate predecessor*: the predecessor
    /// is the store-domain fallback — if fleet-scale chunk corruption
    /// lands on the newest cut's unique chunks, the next gang restart
    /// falls back to it instead of losing the session (DESIGN §9).
    /// Everything older loses its manifest and round-stamped rank
    /// images; chunk-store entries are reclaimed by the regular GC once
    /// the old `.dmtcp` manifests are gone. Never touches the new round.
    fn prune_superseded_rounds(&self, newest: &GangManifest) {
        let ckpt_dir = self.ckpt_dir();
        let Ok(all) = gang_manifests(&ckpt_dir, &self.gang_name()) else {
            return;
        };
        // `all` is newest-first and includes the just-committed round:
        // index 0 is `newest`, index 1 the retained fallback.
        for (p, m) in all.into_iter().skip(2) {
            if (m.generation, m.ckpt_id) < (newest.generation, newest.ckpt_id) {
                for r in &m.ranks {
                    let _ = std::fs::remove_file(ckpt_dir.join(&r.image));
                }
                let _ = std::fs::remove_file(&p);
            }
        }
    }

    /// Arm a one-shot fabric partition (fault injection): when the next
    /// gang barrier reaches `phase`, the coordinator severs the given
    /// ranks mid-round as if the fabric to their node dropped. The round
    /// fails typed, surviving ranks are resumed by the daemon's abort
    /// broadcast, and the previous committed manifest remains the newest
    /// restartable cut — follow with [`GangSession::kill`] and
    /// [`GangSession::resubmit_from_checkpoint`] as for any lost rank.
    pub fn inject_partition(
        &self,
        phase: crate::dmtcp::protocol::Phase,
        ranks: &[u32],
    ) -> Result<()> {
        self.gang()?.coordinator.inject_partition(phase, ranks)
    }

    /// Kill a single rank (fault injection). Losing any rank aborts the
    /// generation: in-flight and future gang checkpoints fail their
    /// barrier, and the computation cannot finish — follow with
    /// [`GangSession::kill`] and [`GangSession::resubmit_from_checkpoint`]
    /// to gang-restart every rank from the last committed cut.
    pub fn kill_rank(&self, rank: u32) -> Result<()> {
        let gang = self.gang()?;
        let slot = gang
            .slots
            .get(rank as usize)
            .ok_or_else(|| Error::Workload(format!("no rank {rank} in this gang")))?;
        crate::trace::event(crate::trace::names::GANG_KILL, |a| {
            a.str("job", self.jobid());
            a.u64("rank", rank as u64);
        });
        slot.launched.process.gate.kill();
        Ok(())
    }

    /// Kill the whole gang (teardown; the session stays resubmittable).
    pub fn kill(&mut self) -> Result<()> {
        self.teardown().map(|_| ())
    }

    /// Gang-restart every rank from the newest committed manifest.
    /// Returns the cut's step count (the slowest rank's progress at the
    /// checkpoint — where the whole gang resumes from).
    pub fn resubmit_from_checkpoint(&mut self) -> Result<u64> {
        if self.active.is_some() {
            return Err(Error::Workload("kill the active gang first".into()));
        }
        if !self.submitted {
            return Err(Error::Workload("gang was never submitted".into()));
        }
        self.generation += 1;
        self.boot()?
            .ok_or_else(|| Error::Workload("gang restart did not report a resume point".into()))
    }

    /// Tear down the active gang, if any, then garbage-collect
    /// chunk-store entries nothing references anymore.
    pub fn finish(&mut self) {
        if self.active.is_some() {
            let _ = self.teardown();
        }
        let ckpt_dir = self.ckpt_dir();
        let store = ImageStore::for_images(&ckpt_dir);
        if !store.root().exists() {
            return;
        }
        match store.gc(&ckpt_dir, self.gc_grace) {
            Ok(st) if st.deleted > 0 => log::debug!(
                "gang {}: store GC reclaimed {} chunks ({} bytes)",
                self.nonce,
                st.deleted,
                st.deleted_bytes
            ),
            Ok(_) => {}
            Err(e) => log::warn!("gang {}: store GC failed: {e}", self.nonce),
        }
    }
}

impl<A: GangApp> Drop for GangSession<A> {
    fn drop(&mut self) {
        if let Some(gang) = self.active.take() {
            gang.coordinator.kill_all();
            for slot in gang.slots {
                let _ = slot.launched.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::StencilApp;

    fn workdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ncr_gang_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn builder_requires_workdir() {
        let app = StencilApp::new(2, 4);
        assert!(GangSession::builder(&app).target_steps(8).build().is_err());
        assert!(GangSession::builder(&app)
            .workdir(workdir("req"))
            .build()
            .is_ok());
    }

    #[test]
    fn names_are_nonce_scoped() {
        let app = StencilApp::new(2, 4);
        let a = GangSession::builder(&app)
            .workdir(workdir("nonce"))
            .build()
            .unwrap();
        let b = GangSession::builder(&app)
            .workdir(workdir("nonce"))
            .build()
            .unwrap();
        assert_ne!(a.gang_name(), b.gang_name());
        assert_ne!(a.jobid(), b.jobid());
        assert!(a.rank_name(3).starts_with(&a.gang_name()));
    }

    #[test]
    fn lifecycle_gates() {
        let app = StencilApp::new(2, 4);
        let mut s = GangSession::builder(&app)
            .workdir(workdir("gates"))
            .target_steps(8)
            .build()
            .unwrap();
        assert!(s.monitor().is_err(), "no active gang yet");
        assert!(s.checkpoint_now().is_err());
        assert!(s.kill().is_err());
        assert!(
            s.resubmit_from_checkpoint().is_err(),
            "never-submitted gang cannot resubmit"
        );
    }

    #[test]
    fn tiny_gang_runs_checkpoints_and_completes() {
        let app = StencilApp::new(2, 8).endpoint_bytes(512);
        let wd = workdir("tiny");
        let mut s = GangSession::builder(&app)
            .workdir(&wd)
            .target_steps(40)
            .seed(11)
            .build()
            .unwrap();
        s.submit().unwrap();
        // A mid-run gang checkpoint commits a manifest covering each rank.
        let ck = loop {
            match s.checkpoint_now() {
                Ok(ck) => break ck,
                Err(_) => std::thread::sleep(Duration::from_millis(2)),
            }
        };
        assert_eq!(ck.manifest.n_ranks(), 2);
        assert!(ck.manifest_path.exists());
        let st = s.wait_done(Duration::from_secs(60)).unwrap();
        assert!(st.done);
        let finals = s.final_states().unwrap();
        s.verify_final(&finals).unwrap();
        s.finish();
        std::fs::remove_dir_all(&wd).ok();
    }
}
