//! Manual C/R strategy — the paper's §V.B.2 operator-in-the-loop flow.
//!
//! "the user actively monitors its output ... Based on this analysis, the
//! user can decide whether to resubmit or restart the job ... utilizing a
//! file created during the checkpointing phase". Each paper step is one
//! method here: [`ManualCr::submit`], [`ManualCr::monitor`],
//! [`ManualCr::checkpoint_now`], [`ManualCr::kill`],
//! [`ManualCr::resubmit_from_checkpoint`], iterated until
//! [`MonitorReport::done`].

use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::cr::module::{latest_images, start_coordinator, CrConfig};
use crate::dmtcp::{
    dmtcp_launch, dmtcp_restart, Coordinator, LaunchSpec, LaunchedProcess, PluginRegistry,
};
use crate::error::{Error, Result};
use crate::runtime::ComputeHandle;
use crate::workload::{transport_worker, G4App, G4SimState};

/// What `monitor` reports (the user's view of the output/error logs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MonitorReport {
    /// Transport steps completed so far.
    pub steps_done: u64,
    /// Steps the workload needs in total.
    pub target_steps: u64,
    /// Particles still alive in the batch.
    pub alive_particles: usize,
    /// Whether the workload is finished.
    pub done: bool,
    /// `steps_done / target_steps` in `[0, 1]`.
    pub progress: f64,
}

/// An operator-driven C/R session for one job.
pub struct ManualCr<'a> {
    app: &'a G4App,
    handle: ComputeHandle,
    workdir: PathBuf,
    target_steps: u64,
    seed: u64,
    incarnation: u32,
    active: Option<ActiveJob>,
}

struct ActiveJob {
    coordinator: Coordinator,
    launched: LaunchedProcess,
    state: Arc<Mutex<G4SimState>>,
}

impl<'a> ManualCr<'a> {
    /// Set up a session (no job submitted yet; call [`Self::submit`]).
    pub fn new(
        app: &'a G4App,
        handle: ComputeHandle,
        workdir: PathBuf,
        target_steps: u64,
        seed: u64,
    ) -> Self {
        Self {
            app,
            handle,
            workdir,
            target_steps,
            seed,
            incarnation: 0,
            active: None,
        }
    }

    fn spawn_workers(&self, launched: &mut LaunchedProcess, state: &Arc<Mutex<G4SimState>>) {
        let h = self.handle.clone();
        let si = Arc::clone(&self.app.si);
        let st = Arc::clone(state);
        launched
            .process
            .spawn_user_thread(move |ctx| transport_worker(ctx, h, st, si, 1));
    }

    /// Step 1: initial submission ("creates a checkpointing state").
    pub fn submit(&mut self) -> Result<()> {
        if self.active.is_some() {
            return Err(Error::Workload("job already active".into()));
        }
        let cfg = CrConfig::new(format!("M{}0", self.seed % 100_000), &self.workdir);
        let (coordinator, env) = start_coordinator(&cfg)?;
        let state = Arc::new(Mutex::new(self.app.fresh_state(
            self.handle.manifest().batch,
            self.target_steps,
            self.seed,
        )));
        let mut spec =
            LaunchSpec::new(format!("manual-{}", self.app.kind.label()), coordinator.addr());
        spec.env = env;
        let mut launched = dmtcp_launch(spec, Arc::clone(&state), PluginRegistry::new());
        launched.wait_attached(Duration::from_secs(10))?;
        self.spawn_workers(&mut launched, &state);
        self.active = Some(ActiveJob {
            coordinator,
            launched,
            state,
        });
        Ok(())
    }

    /// Step 2: monitor the job (output/error log inspection analog).
    pub fn monitor(&self) -> Result<MonitorReport> {
        let job = self
            .active
            .as_ref()
            .ok_or_else(|| Error::Workload("no active job".into()))?;
        let s = job.state.lock().expect("state poisoned");
        Ok(MonitorReport {
            steps_done: s.particles.steps_done,
            target_steps: s.target_steps,
            alive_particles: s.particles.alive_count(),
            done: s.done(),
            progress: s.progress(),
        })
    }

    /// Step 3: take a checkpoint on demand (`dmtcp_command --checkpoint`).
    /// Returns the image paths.
    pub fn checkpoint_now(&self) -> Result<Vec<PathBuf>> {
        let job = self
            .active
            .as_ref()
            .ok_or_else(|| Error::Workload("no active job".into()))?;
        let images = job.coordinator.checkpoint_all()?;
        Ok(images.into_iter().map(|i| i.path).collect())
    }

    /// Step 4: kill the job (failure injection / operator decision).
    pub fn kill(&mut self) -> Result<()> {
        let job = self
            .active
            .take()
            .ok_or_else(|| Error::Workload("no active job".into()))?;
        job.coordinator.kill_all();
        let _ = job.launched.join();
        Ok(())
    }

    /// Step 5: resubmit from the newest checkpoint file.
    pub fn resubmit_from_checkpoint(&mut self) -> Result<u64> {
        if self.active.is_some() {
            return Err(Error::Workload("kill the active job first".into()));
        }
        self.incarnation += 1;
        let cfg = CrConfig::new(
            format!("M{}{}", self.seed % 100_000, self.incarnation),
            &self.workdir,
        );
        // All incarnations share the ckpt dir (first config created it).
        let ckpt_dir = CrConfig::new("x", &self.workdir).ckpt_dir;
        let image = latest_images(&ckpt_dir)?
            .into_iter()
            .last()
            .ok_or_else(|| Error::Workload("no checkpoint image to restart from".into()))?;
        let (coordinator, _env) = start_coordinator(&cfg)?;
        let state = Arc::new(Mutex::new(self.app.shell_state()));
        let restarted = dmtcp_restart(
            &image,
            coordinator.addr(),
            Arc::clone(&state),
            PluginRegistry::new(),
        )?;
        let steps_at_restart = restarted.header.steps_done;
        let mut launched = restarted.launched;
        launched.wait_attached(Duration::from_secs(10))?;
        self.spawn_workers(&mut launched, &state);
        self.active = Some(ActiveJob {
            coordinator,
            launched,
            state,
        });
        Ok(steps_at_restart)
    }

    /// Wait (polling) until done or `timeout`; returns the final report.
    pub fn wait_done(&self, timeout: Duration) -> Result<MonitorReport> {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            let r = self.monitor()?;
            if r.done {
                return Ok(r);
            }
            if std::time::Instant::now() > deadline {
                return Err(Error::Workload(format!(
                    "timeout at {}/{} steps",
                    r.steps_done, r.target_steps
                )));
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    /// Final state snapshot (verification).
    pub fn final_state(&self) -> Result<G4SimState> {
        let job = self
            .active
            .as_ref()
            .ok_or_else(|| Error::Workload("no active job".into()))?;
        Ok(job.state.lock().expect("state poisoned").clone())
    }

    /// Tear down.
    pub fn finish(&mut self) {
        if let Some(job) = self.active.take() {
            job.coordinator.kill_all();
            let _ = job.launched.join();
        }
    }
}

impl Drop for ManualCr<'_> {
    fn drop(&mut self) {
        self.finish();
    }
}
