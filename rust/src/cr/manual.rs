//! Manual C/R strategy — the paper's §V.B.2 operator-in-the-loop flow
//! (legacy shim).
//!
//! "the user actively monitors its output ... Based on this analysis, the
//! user can decide whether to resubmit or restart the job ... utilizing a
//! file created during the checkpointing phase". The five paper steps are
//! now methods on [`crate::cr::session::CrSession`] built with
//! `CrStrategy::Manual` (`submit` / `monitor` / `checkpoint_now` / `kill`
//! / `resubmit_from_checkpoint`); [`ManualCr`] remains for one release as
//! a thin wrapper preserving the old Geant4-analog-specific API.

use std::path::PathBuf;
use std::time::Duration;

use crate::cr::session::{CrSession, CrStrategy};
use crate::error::Result;
use crate::runtime::ComputeHandle;
use crate::workload::{G4App, G4SimState};

/// What [`ManualCr::monitor`] reports (the user's view of the output/error
/// logs), with the Geant4-analog-specific fields the generic
/// [`crate::cr::session::SessionStatus`] does not carry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MonitorReport {
    /// Transport steps completed so far.
    pub steps_done: u64,
    /// Steps the workload needs in total.
    pub target_steps: u64,
    /// Particles still alive in the batch.
    pub alive_particles: usize,
    /// Whether the workload is finished.
    pub done: bool,
    /// `steps_done / target_steps` in `[0, 1]`.
    pub progress: f64,
}

/// An operator-driven C/R session for one Geant4-analog job (legacy).
#[deprecated(
    since = "0.3.0",
    note = "build a cr::CrSession with CrStrategy::Manual instead"
)]
pub struct ManualCr<'a> {
    session: CrSession<&'a G4App>,
}

#[allow(deprecated)]
impl<'a> ManualCr<'a> {
    /// Set up a session (no job submitted yet; call [`Self::submit`]).
    ///
    /// `handle` is unused: the Geant4-analog `CrApp` implementation serves
    /// compute through the shared service handle, which is the same handle
    /// every historical caller passed here. Panics only if `workdir`
    /// cannot be created (the historical constructor deferred that failure
    /// to `submit`).
    pub fn new(
        app: &'a G4App,
        handle: ComputeHandle,
        workdir: PathBuf,
        target_steps: u64,
        seed: u64,
    ) -> Self {
        let _ = handle;
        let session = CrSession::builder(app)
            .strategy(CrStrategy::Manual)
            .workdir(workdir)
            .target_steps(target_steps)
            .seed(seed)
            .build()
            .expect("manual C/R session");
        Self { session }
    }

    /// Step 1: initial submission ("creates a checkpointing state").
    pub fn submit(&mut self) -> Result<()> {
        self.session.submit()
    }

    /// Step 2: monitor the job (output/error log inspection analog).
    pub fn monitor(&self) -> Result<MonitorReport> {
        self.session.with_state(|s| MonitorReport {
            steps_done: s.particles.steps_done,
            target_steps: s.target_steps,
            alive_particles: s.particles.alive_count(),
            done: s.done(),
            progress: s.progress(),
        })
    }

    /// Step 3: take a checkpoint on demand (`dmtcp_command --checkpoint`).
    /// Returns the image paths.
    pub fn checkpoint_now(&self) -> Result<Vec<PathBuf>> {
        self.session.checkpoint_now()
    }

    /// Step 4: kill the job (failure injection / operator decision).
    pub fn kill(&mut self) -> Result<()> {
        self.session.kill()
    }

    /// Step 5: resubmit from the newest checkpoint file.
    pub fn resubmit_from_checkpoint(&mut self) -> Result<u64> {
        self.session.resubmit_from_checkpoint()
    }

    /// Wait (polling) until done or `timeout`; returns the final report.
    pub fn wait_done(&self, timeout: Duration) -> Result<MonitorReport> {
        self.session.wait_done(timeout)?;
        self.monitor()
    }

    /// Final state snapshot (verification).
    pub fn final_state(&self) -> Result<G4SimState> {
        self.session.final_state()
    }

    /// Tear down.
    pub fn finish(&mut self) {
        self.session.finish();
    }
}
