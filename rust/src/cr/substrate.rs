//! Where a checkpointed process runs: bare on the host, or inside a
//! shifter / podman-hpc container.
//!
//! The paper's central container constraint lives here (absorbed from the
//! old `Container::launch_checkpointed`): **checkpointing inside a
//! container requires DMTCP inside the image** — a runtime cannot
//! checkpoint a container from outside — and checkpoint images must land
//! on a volume that outlives the container instance. A [`Substrate`] makes
//! the choice of execution environment a one-line builder argument on
//! [`crate::cr::session::CrSession`], so the same workflow runs bare,
//! under shifter, or under podman-hpc (the paper's §V claim).

#![deny(missing_docs)]

use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::container::runtime::Container;
use crate::dmtcp::process::Checkpointable;
use crate::dmtcp::{
    dmtcp_launch, dmtcp_restart_with_env, LaunchSpec, LaunchedProcess, PluginRegistry,
    RestartedProcess,
};
use crate::error::{Error, Result};

/// The execution environment a C/R session launches its processes in.
pub enum Substrate {
    /// A plain host process (no container runtime).
    Bare,
    /// Inside a container execution context (shifter or podman-hpc —
    /// build one with `Shifter::run` / `PodmanHpc::run`).
    Container(Container),
}

impl Substrate {
    /// The bare-process substrate.
    pub fn bare() -> Self {
        Substrate::Bare
    }

    /// A containerized substrate from an execution context.
    pub fn container(container: Container) -> Self {
        Substrate::Container(container)
    }

    /// Substrate name for logs and reports (`bare` / `shifter` /
    /// `podman-hpc`).
    pub fn name(&self) -> &'static str {
        match self {
            Substrate::Bare => "bare",
            Substrate::Container(c) => c.runtime_name,
        }
    }

    /// Launch a fresh process on this substrate under checkpoint control.
    /// `env` is the CR-module environment (coordinator address, checkpoint
    /// dir, job id); containerized launches overlay the image environment
    /// on top of it.
    pub(crate) fn launch<S: Checkpointable + 'static>(
        &self,
        name: &str,
        coordinator: SocketAddr,
        env: BTreeMap<String, String>,
        state: Arc<Mutex<S>>,
        plugins: PluginRegistry,
    ) -> Result<LaunchedProcess> {
        match self {
            Substrate::Bare => {
                let mut spec = LaunchSpec::new(name, coordinator);
                spec.env = env;
                Ok(dmtcp_launch(spec, state, plugins))
            }
            Substrate::Container(c) => {
                launch_in_container(c, name, coordinator, env, state, plugins)
            }
        }
    }

    /// Restart a process from a checkpoint image on this substrate. The
    /// container constraints are re-validated: the restarting image set
    /// must also run where DMTCP is embedded and checkpoints persist.
    /// `env_overrides` is layered over the image environment — the session
    /// layers use it to stamp the new incarnation's coordinator routing
    /// (`DMTCP_JOB`) over the image's stale tag.
    pub(crate) fn restart<S: Checkpointable + 'static>(
        &self,
        image: &Path,
        coordinator: SocketAddr,
        state: Arc<Mutex<S>>,
        plugins: PluginRegistry,
        env_overrides: &BTreeMap<String, String>,
    ) -> Result<RestartedProcess> {
        if let Substrate::Container(c) = self {
            validate_container(c)?;
        }
        dmtcp_restart_with_env(image, coordinator, state, plugins, env_overrides)
    }
}

/// Enforce the paper's containerized-C/R preconditions: DMTCP embedded in
/// the image, and the checkpoint directory volume-mapped to the host.
pub(crate) fn validate_container(container: &Container) -> Result<()> {
    if !container.image.has_dmtcp {
        return Err(Error::Container(format!(
            "image {} does not embed DMTCP: checkpointing from outside \
             the container is not possible — rebuild the image with \
             DMTCP installed (see container::image::EMBED_DMTCP_SNIPPET)",
            container.image.reference()
        )));
    }
    // Checkpoint images must land on a volume that outlives the
    // container instance.
    let ckpt_container_dir = container
        .effective_env()
        .get("DMTCP_CHECKPOINT_DIR")
        .cloned()
        .unwrap_or_else(|| "/ckpt".to_string());
    if container.spec.host_path(&ckpt_container_dir).is_none() {
        return Err(Error::Container(format!(
            "checkpoint dir {ckpt_container_dir} is not volume-mapped; \
             images written there would not survive the container"
        )));
    }
    Ok(())
}

/// Validate, then launch inside the container with the image environment
/// overlaid on the session environment (the container view wins for keys
/// both define, matching what the runtime would present to the process).
pub(crate) fn launch_in_container<S: Checkpointable + 'static>(
    container: &Container,
    name: &str,
    coordinator: SocketAddr,
    extra_env: BTreeMap<String, String>,
    state: Arc<Mutex<S>>,
    plugins: PluginRegistry,
) -> Result<LaunchedProcess> {
    validate_container(container)?;
    let mut spec = LaunchSpec::new(name, coordinator);
    spec.env = extra_env;
    spec.env.extend(container.effective_env());
    Ok(dmtcp_launch(spec, state, plugins))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::image::Image;
    use crate::container::runtime::RunSpec;

    fn container(has_dmtcp: bool, volume: bool) -> Container {
        let mut image = Image::base("app", "v1", 1);
        image.has_dmtcp = has_dmtcp;
        let mut spec = RunSpec::default().env("DMTCP_CHECKPOINT_DIR", "/ckpt");
        if volume {
            spec = spec.volume("/host/ckpt", "/ckpt");
        }
        Container {
            runtime_name: "podman-hpc",
            image,
            spec,
        }
    }

    #[test]
    fn names() {
        assert_eq!(Substrate::bare().name(), "bare");
        assert_eq!(
            Substrate::container(container(true, true)).name(),
            "podman-hpc"
        );
    }

    #[test]
    fn validation_enforces_paper_constraints() {
        assert!(validate_container(&container(true, true)).is_ok());
        let err = validate_container(&container(false, true)).unwrap_err();
        assert!(err.to_string().contains("does not embed DMTCP"), "{err}");
        let err = validate_container(&container(true, false)).unwrap_err();
        assert!(err.to_string().contains("volume"), "{err}");
    }
}
