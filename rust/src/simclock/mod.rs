//! Discrete-event simulation core.
//!
//! The batch-scheduler experiments (Fig 2 sweeps, utilization studies,
//! preemption campaigns) run thousands of simulated jobs; they use this
//! event queue in *sim-time* (integer seconds) so hours of cluster activity
//! replay in milliseconds. Real-time components (the DMTCP coordinator, the
//! PJRT engine) don't use this — see DESIGN.md §3 on the two modes.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Simulated time in seconds since sim start.
pub type SimTime = u64;

/// A scheduled event: fires at `at`; FIFO among equal times (`seq`).
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
struct Scheduled<E> {
    at: SimTime,
    seq: u64,
    event: E,
}

/// Priority queue of timed events with stable FIFO tie-breaking.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<Scheduled<E>>>,
    seq: u64,
}

impl<E: Ord> EventQueue<E> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    /// Schedule `event` at absolute time `at`.
    pub fn schedule(&mut self, at: SimTime, event: E) {
        self.seq += 1;
        self.heap.push(Reverse(Scheduled {
            at,
            seq: self.seq,
            event,
        }));
    }

    /// Pop the earliest event `(time, event)`.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|Reverse(s)| (s.at, s.event))
    }

    /// Time of the next event without removing it.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|Reverse(s)| s.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

impl<E: Ord> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_ordering() {
        let mut q = EventQueue::new();
        q.schedule(30, "c");
        q.schedule(10, "a");
        q.schedule(20, "b");
        assert_eq!(q.peek_time(), Some(10));
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn fifo_among_equal_times() {
        let mut q = EventQueue::new();
        q.schedule(5, "first");
        q.schedule(5, "second");
        q.schedule(5, "third");
        assert_eq!(q.pop().unwrap().1, "first");
        assert_eq!(q.pop().unwrap().1, "second");
        assert_eq!(q.pop().unwrap().1, "third");
    }

    #[test]
    fn interleaved_schedule_pop() {
        let mut q = EventQueue::new();
        q.schedule(10, 1);
        assert_eq!(q.pop(), Some((10, 1)));
        q.schedule(5, 2);
        q.schedule(15, 3);
        assert_eq!(q.pop(), Some((5, 2)));
        q.schedule(1, 4);
        assert_eq!(q.pop(), Some((1, 4)));
        assert_eq!(q.pop(), Some((15, 3)));
        assert!(q.is_empty());
    }
}
