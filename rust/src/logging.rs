//! Tiny `log` facade backend (env_logger is not in the offline closure).
//!
//! Level comes from `NERSC_CR_LOG` (error|warn|info|debug|trace), default
//! `info`. Messages go to stderr with a monotonic timestamp, mirroring the
//! `dmtcp_coordinator --daemon` log style.

use std::io::Write;
use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

static START: OnceLock<Instant> = OnceLock::new();
static LOGGER: Logger = Logger;

struct Logger;

impl log::Log for Logger {
    fn enabled(&self, _metadata: &Metadata) -> bool {
        true
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.get_or_init(Instant::now).elapsed();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let mut err = std::io::stderr().lock();
        let _ = writeln!(
            err,
            "[{:>9.3}s {} {}] {}",
            t.as_secs_f64(),
            lvl,
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent).
pub fn init() {
    let level = match std::env::var("NERSC_CR_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    START.get_or_init(Instant::now);
    if log::set_logger(&LOGGER).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke");
    }
}
