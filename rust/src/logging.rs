//! Tiny `log` facade backend (env_logger is not in the offline closure).
//!
//! Level comes from `NERSC_CR_LOG` (error|warn|info|debug|trace), default
//! `info`. Messages go to stderr with a monotonic timestamp, mirroring the
//! `dmtcp_coordinator --daemon` log style. When a [`crate::trace`] sink is
//! recording, every emitted record is also forwarded into it as an
//! instant event (`log.event` with level/target/msg attributes), so a
//! flight-recorder dump interleaves log lines with the spans around them.

use std::io::Write;
use std::sync::OnceLock;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

static START: OnceLock<Instant> = OnceLock::new();
static LOGGER: Logger = Logger;

struct Logger;

impl log::Log for Logger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        // Honor the `NERSC_CR_LOG` filter `init` installed: a `debug`
        // record is only enabled when the max level admits it. (This used
        // to return `true` unconditionally, so `log_enabled!` and direct
        // `enabled()` probes lied about what would actually print.)
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.get_or_init(Instant::now).elapsed();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        if crate::trace::enabled() {
            crate::trace::log_event(lvl.trim_end(), record.target(), &record.args().to_string());
        }
        let mut err = std::io::stderr().lock();
        let _ = writeln!(
            err,
            "[{:>9.3}s {} {}] {}",
            t.as_secs_f64(),
            lvl,
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent).
pub fn init() {
    let level = match std::env::var("NERSC_CR_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    START.get_or_init(Instant::now);
    if log::set_logger(&LOGGER).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    use log::Log;

    #[test]
    fn init_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke");
    }

    #[test]
    fn enabled_honors_max_level() {
        super::init();
        let meta = |l: log::Level| log::Metadata::builder().level(l).target("t").build();
        let max = log::max_level();
        // Whatever the filter is, a level past it must be disabled and a
        // level within it enabled — `enabled()` can no longer say yes to
        // everything.
        if max < log::LevelFilter::Trace {
            assert!(!super::LOGGER.enabled(&meta(log::Level::Trace)));
        }
        if max >= log::LevelFilter::Error {
            assert!(super::LOGGER.enabled(&meta(log::Level::Error)));
        }
    }
}
