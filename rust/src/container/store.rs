//! Image stores and the external registry (DockerHub analog).

use std::collections::BTreeMap;

use crate::container::image::Image;
use crate::error::{Error, Result};

/// A remote registry ("uploaded to DockerHub ... pushed to an external
/// registry like Docker Hub and pulled later as needed").
#[derive(Debug, Default)]
pub struct Registry {
    images: BTreeMap<String, Image>,
    /// Private repositories require a login before pull.
    private: BTreeMap<String, String>, // repo -> required user
    logged_in: Option<String>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Publish an image.
    pub fn push(&mut self, image: Image) {
        self.images.insert(image.reference(), image);
    }

    /// Mark a repository private (pull requires `login(user)`).
    pub fn set_private(&mut self, name: &str, owner: &str) {
        self.private.insert(name.to_string(), owner.to_string());
    }

    /// `podman-hpc login` analog.
    pub fn login(&mut self, user: &str) {
        self.logged_in = Some(user.to_string());
    }

    /// Pull an image by `name:tag`.
    pub fn pull(&self, reference: &str) -> Result<Image> {
        let img = self
            .images
            .get(reference)
            .ok_or_else(|| Error::Container(format!("registry: {reference:?} not found")))?;
        if let Some(owner) = self.private.get(&img.name) {
            match &self.logged_in {
                Some(u) if u == owner => {}
                _ => {
                    return Err(Error::Container(format!(
                        "registry: {reference:?} is private; login required"
                    )))
                }
            }
        }
        Ok(img.clone())
    }

    pub fn len(&self) -> usize {
        self.images.len()
    }

    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }
}

/// A node/center-local image store (per container runtime).
#[derive(Debug, Default)]
pub struct ImageStore {
    images: BTreeMap<String, Image>,
    /// References that have been converted to the runtime's squash format
    /// and are therefore usable inside batch jobs.
    squashed: BTreeMap<String, u64>, // reference -> squash size
}

impl ImageStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, image: Image) {
        self.images.insert(image.reference(), image);
    }

    pub fn get(&self, reference: &str) -> Option<&Image> {
        self.images.get(reference)
    }

    pub fn contains(&self, reference: &str) -> bool {
        self.images.contains_key(reference)
    }

    /// Record a squash conversion (see [`crate::container::squash`]).
    pub fn mark_squashed(&mut self, reference: &str, squash_bytes: u64) -> Result<()> {
        if !self.images.contains_key(reference) {
            return Err(Error::Container(format!(
                "cannot squash unknown image {reference:?}"
            )));
        }
        self.squashed.insert(reference.to_string(), squash_bytes);
        Ok(())
    }

    /// Is the image ready for batch-job use?
    pub fn is_squashed(&self, reference: &str) -> bool {
        self.squashed.contains_key(reference)
    }

    pub fn squash_size(&self, reference: &str) -> Option<u64> {
        self.squashed.get(reference).copied()
    }

    pub fn references(&self) -> impl Iterator<Item = &str> {
        self.images.keys().map(String::as_str)
    }

    pub fn len(&self) -> usize {
        self.images.len()
    }

    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn img(name: &str, tag: &str) -> Image {
        Image::base(name, tag, 1024)
    }

    #[test]
    fn registry_push_pull() {
        let mut r = Registry::new();
        r.push(img("app", "v1"));
        assert_eq!(r.pull("app:v1").unwrap().reference(), "app:v1");
        assert!(r.pull("app:v2").is_err());
    }

    #[test]
    fn private_repo_requires_login() {
        let mut r = Registry::new();
        r.push(img("secret", "v1"));
        r.set_private("secret", "elvis");
        assert!(r.pull("secret:v1").is_err());
        r.login("someone_else");
        assert!(r.pull("secret:v1").is_err());
        r.login("elvis");
        assert!(r.pull("secret:v1").is_ok());
    }

    #[test]
    fn store_squash_tracking() {
        let mut s = ImageStore::new();
        s.insert(img("app", "v1"));
        assert!(!s.is_squashed("app:v1"));
        s.mark_squashed("app:v1", 512).unwrap();
        assert!(s.is_squashed("app:v1"));
        assert_eq!(s.squash_size("app:v1"), Some(512));
        assert!(s.mark_squashed("ghost:v0", 1).is_err());
    }
}
