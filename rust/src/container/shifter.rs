//! The shifter runtime model.
//!
//! shifter bridges Docker images onto HPC: users push to a registry, then
//! `shifterimg pull` converts the image to shifter's squash format at the
//! image gateway — there is no local build path, and container contents are
//! immutable at runtime. Its image cache has had "the benefit of years of
//! performance optimization" (Fig 2: fastest startup at scale).

use crate::container::image::Image;
use crate::container::runtime::{Container, ContainerRuntime, RunSpec};
use crate::container::squash::squash;
use crate::container::store::{ImageStore, Registry};
use crate::error::{Error, Result};
use crate::fsmodel::Environment;

/// The shifter runtime + its image gateway store.
#[derive(Debug, Default)]
pub struct Shifter {
    store: ImageStore,
}

impl Shifter {
    pub fn new() -> Self {
        Self::default()
    }

    /// `shifterimg pull <ref>`: fetch from the registry and convert to the
    /// shifter squash format in one step.
    pub fn pull(&mut self, registry: &Registry, reference: &str) -> Result<()> {
        let image = registry.pull(reference)?;
        let sq = squash(&image);
        self.store.insert(image);
        self.store.mark_squashed(reference, sq.squash_bytes)?;
        log::debug!(
            "shifterimg pull {reference}: squashed to {} bytes",
            sq.squash_bytes
        );
        Ok(())
    }

    /// `shifter --image=<ref> ...`: create an execution context.
    pub fn run(&self, reference: &str, spec: RunSpec) -> Result<Container> {
        let image = self.runnable_image(reference)?;
        Ok(Container {
            runtime_name: "shifter",
            image,
            spec,
        })
    }

    pub fn store(&self) -> &ImageStore {
        &self.store
    }
}

impl ContainerRuntime for Shifter {
    fn name(&self) -> &'static str {
        "shifter"
    }

    fn environment(&self) -> Environment {
        Environment::Shifter
    }

    fn runnable_image(&self, reference: &str) -> Result<Image> {
        let img = self
            .store
            .get(reference)
            .ok_or_else(|| {
                Error::Container(format!(
                    "shifter: image {reference:?} not pulled (use shifterimg pull)"
                ))
            })?
            .clone();
        if !self.store.is_squashed(reference) {
            return Err(Error::Container(format!(
                "shifter: image {reference:?} not converted"
            )));
        }
        Ok(img)
    }

    fn supports_local_build(&self) -> bool {
        false
    }

    fn supports_runtime_modification(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pull_then_run() {
        let mut reg = Registry::new();
        reg.push(Image::base("app", "v1", 1024 * 1024));
        let mut sh = Shifter::new();
        assert!(sh.run("app:v1", RunSpec::default()).is_err());
        sh.pull(&reg, "app:v1").unwrap();
        let c = sh.run("app:v1", RunSpec::default()).unwrap();
        assert_eq!(c.runtime_name, "shifter");
        assert!(sh.store().is_squashed("app:v1"));
    }

    #[test]
    fn capabilities() {
        let sh = Shifter::new();
        assert!(!sh.supports_local_build());
        assert!(!sh.supports_runtime_modification());
        assert_eq!(sh.environment(), Environment::Shifter);
        // Fig 2: startup grows slowly with ranks.
        assert!(sh.startup_time(512) < 4.0 * sh.startup_time(1));
    }
}
