//! The podman-hpc runtime model.
//!
//! podman-hpc is Red Hat Podman (daemonless, rootless) plus NERSC's HPC
//! add-on: users build images *directly on the system*
//! (`podman-hpc build`), convert them with `podman-hpc migrate` into a
//! squashfile usable inside batch jobs, or pull from registries (pulls
//! auto-migrate). Unlike shifter it permits runtime modification of
//! container contents. Being newer, its image cache is less tuned
//! (Fig 2: comparable to optimized shared filesystems, behind shifter).

use crate::container::image::{build_image, parse_containerfile, Image};
use crate::container::runtime::{Container, ContainerRuntime, RunSpec};
use crate::container::squash::squash;
use crate::container::store::{ImageStore, Registry};
use crate::error::{Error, Result};
use crate::fsmodel::Environment;

/// The podman-hpc runtime + its local store.
#[derive(Debug, Default)]
pub struct PodmanHpc {
    store: ImageStore,
    /// Rootless mode (the default; kept for capability reporting).
    pub rootless: bool,
}

impl PodmanHpc {
    pub fn new() -> Self {
        Self {
            store: ImageStore::new(),
            rootless: true,
        }
    }

    /// `podman-hpc build -t name:tag .` — build from a Containerfile,
    /// resolving FROM references against the local store then `bases`.
    pub fn build(
        &mut self,
        name: &str,
        tag: &str,
        containerfile: &str,
        bases: &Registry,
    ) -> Result<Image> {
        let instructions = parse_containerfile(containerfile)?;
        let image = build_image(name, tag, &instructions, |r| {
            self.store.get(r).cloned().or_else(|| bases.pull(r).ok())
        })?;
        self.store.insert(image.clone());
        log::debug!("podman-hpc build {name}:{tag}: {} layers", image.layers.len());
        Ok(image)
    }

    /// `podman-hpc migrate name:tag` — convert to the squashfile format
    /// required for job execution.
    pub fn migrate(&mut self, reference: &str) -> Result<()> {
        let image = self
            .store
            .get(reference)
            .ok_or_else(|| Error::Container(format!("migrate: unknown image {reference:?}")))?;
        let sq = squash(image);
        self.store.mark_squashed(reference, sq.squash_bytes)?;
        log::debug!("podman-hpc migrate {reference}: {} bytes", sq.squash_bytes);
        Ok(())
    }

    /// `podman-hpc pull <ref>` — "images pulled from a registry are
    /// automatically converted into a suitable squashfile format".
    pub fn pull(&mut self, registry: &Registry, reference: &str) -> Result<()> {
        let image = registry.pull(reference)?;
        let sq = squash(&image);
        self.store.insert(image);
        self.store.mark_squashed(reference, sq.squash_bytes)
    }

    /// `podman-hpc push <ref>` — publish a locally built image.
    pub fn push(&self, registry: &mut Registry, reference: &str) -> Result<()> {
        let image = self
            .store
            .get(reference)
            .ok_or_else(|| Error::Container(format!("push: unknown image {reference:?}")))?;
        registry.push(image.clone());
        Ok(())
    }

    /// `podman-hpc run --volume ... <ref>` — create an execution context.
    pub fn run(&self, reference: &str, spec: RunSpec) -> Result<Container> {
        let image = self.runnable_image(reference)?;
        Ok(Container {
            runtime_name: "podman-hpc",
            image,
            spec,
        })
    }

    pub fn store(&self) -> &ImageStore {
        &self.store
    }
}

impl ContainerRuntime for PodmanHpc {
    fn name(&self) -> &'static str {
        "podman-hpc"
    }

    fn environment(&self) -> Environment {
        Environment::PodmanHpc
    }

    fn runnable_image(&self, reference: &str) -> Result<Image> {
        let img = self
            .store
            .get(reference)
            .ok_or_else(|| Error::Container(format!("podman-hpc: unknown image {reference:?}")))?
            .clone();
        if !self.store.is_squashed(reference) {
            return Err(Error::Container(format!(
                "podman-hpc: image {reference:?} not migrated — run \
                 `podman-hpc migrate {reference}` before using it in a job"
            )));
        }
        Ok(img)
    }

    fn supports_local_build(&self) -> bool {
        true
    }

    fn supports_runtime_modification(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::image::EMBED_DMTCP_SNIPPET;

    fn base_registry() -> Registry {
        let mut r = Registry::new();
        r.push(Image::base("my_application_container", "latest", 500 * 1024 * 1024));
        r
    }

    #[test]
    fn build_migrate_run() {
        let reg = base_registry();
        let mut pm = PodmanHpc::new();
        let img = pm.build("elvis", "test", EMBED_DMTCP_SNIPPET, &reg).unwrap();
        assert!(img.has_dmtcp);
        // Unmigrated images are not job-runnable.
        assert!(pm.run("elvis:test", RunSpec::default()).is_err());
        pm.migrate("elvis:test").unwrap();
        let c = pm.run("elvis:test", RunSpec::default()).unwrap();
        assert_eq!(c.runtime_name, "podman-hpc");
    }

    #[test]
    fn pull_auto_migrates() {
        let mut reg = base_registry();
        reg.push(Image::base("pub", "v2", 1024));
        let mut pm = PodmanHpc::new();
        pm.pull(&reg, "pub:v2").unwrap();
        assert!(pm.store().is_squashed("pub:v2"));
        assert!(pm.run("pub:v2", RunSpec::default()).is_ok());
    }

    #[test]
    fn push_roundtrip() {
        let mut reg = base_registry();
        let mut pm = PodmanHpc::new();
        pm.build("elvis", "test", EMBED_DMTCP_SNIPPET, &reg).unwrap();
        pm.push(&mut reg, "elvis:test").unwrap();
        // Another runtime can now pull it.
        let mut pm2 = PodmanHpc::new();
        pm2.pull(&reg, "elvis:test").unwrap();
        assert!(pm2.runnable_image("elvis:test").unwrap().has_dmtcp);
    }

    #[test]
    fn capabilities() {
        let pm = PodmanHpc::new();
        assert!(pm.supports_local_build());
        assert!(pm.supports_runtime_modification());
        assert!(pm.rootless);
    }
}
