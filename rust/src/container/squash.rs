//! Squashfile conversion (`podman-hpc migrate` / shifter gateway format).
//!
//! Both NERSC runtimes execute images from a single squashfs file on
//! node-local storage rather than from overlay layer stacks — that is the
//! architectural root of their startup-performance win in Fig 2 (one
//! loopback mount + page cache instead of per-file metadata round-trips).

use crate::container::image::Image;

/// Result of converting an image to squash format.
#[derive(Debug, Clone, PartialEq)]
pub struct SquashImage {
    pub reference: String,
    /// Squashed size (layer dedup + compression).
    pub squash_bytes: u64,
    /// Layers folded in.
    pub layers: usize,
    /// Conversion wall-time estimate (seconds) — proportional to input
    /// size; migrate happens once per image on the login node.
    pub convert_secs: f64,
}

/// Compression+dedup ratio of squashfs over raw layers for typical HPC
/// images (conda envs and simulation toolkits compress well).
const SQUASH_RATIO: f64 = 0.42;

/// Convert an image (both runtimes share the mechanics; they differ in
/// where/when conversion happens — see `shifter.rs` / `podman_hpc.rs`).
pub fn squash(image: &Image) -> SquashImage {
    let raw = image.size_bytes();
    let squash_bytes = ((raw as f64) * SQUASH_RATIO) as u64;
    SquashImage {
        reference: image.reference(),
        squash_bytes,
        layers: image.layers.len(),
        // ~150 MB/s single-stream mksquashfs
        convert_secs: raw as f64 / (150.0 * 1024.0 * 1024.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::image::{Image, Layer};

    #[test]
    fn squash_compresses() {
        let mut img = Image::base("app", "v1", 400 * 1024 * 1024);
        img.layers.push(Layer {
            instruction: "RUN build".into(),
            size_bytes: 100 * 1024 * 1024,
        });
        let sq = squash(&img);
        assert_eq!(sq.layers, 2);
        assert!(sq.squash_bytes < img.size_bytes());
        assert!(sq.convert_secs > 0.0);
    }
}
