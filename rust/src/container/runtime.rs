//! The common container-runtime interface.
//!
//! A [`ContainerRuntime`] can make images runnable in batch jobs; a
//! [`Container`] is the resulting execution context. Launching
//! DMTCP-managed processes inside one goes through
//! [`crate::cr::substrate::Substrate::container`], which enforces the
//! paper's central container constraint: **checkpointing inside a
//! container requires DMTCP inside the image** — a runtime cannot
//! checkpoint a container from outside.

use std::collections::BTreeMap;

use crate::container::image::Image;
use crate::error::Result;
use crate::fsmodel::Environment;

/// Container run parameters (volume mappings, env overrides, entrypoint).
#[derive(Debug, Clone, Default)]
pub struct RunSpec {
    /// `(host_path, container_path)` volume mappings. Checkpoint images
    /// must be written to a mapped volume or they die with the container.
    pub volumes: Vec<(String, String)>,
    /// Environment overrides on top of the image's env.
    pub env: BTreeMap<String, String>,
    /// Override the image entrypoint.
    pub command: Option<String>,
}

impl RunSpec {
    pub fn volume(mut self, host: impl Into<String>, container: impl Into<String>) -> Self {
        self.volumes.push((host.into(), container.into()));
        self
    }

    pub fn env(mut self, k: impl Into<String>, v: impl Into<String>) -> Self {
        self.env.insert(k.into(), v.into());
        self
    }

    /// Translate a container path to the host path through the volume map.
    pub fn host_path(&self, container_path: &str) -> Option<String> {
        self.volumes.iter().find_map(|(h, c)| {
            container_path
                .strip_prefix(c.as_str())
                .map(|rest| format!("{h}{rest}"))
        })
    }
}

/// What both NERSC runtimes provide.
pub trait ContainerRuntime {
    /// Runtime name (`shifter` / `podman-hpc`).
    fn name(&self) -> &'static str;

    /// The startup-performance environment of this runtime (Fig 2 curve).
    fn environment(&self) -> Environment;

    /// Look up an image ready for batch-job execution.
    fn runnable_image(&self, reference: &str) -> Result<Image>;

    /// Whether images can be built directly on the system (podman-hpc can;
    /// shifter images come through the gateway).
    fn supports_local_build(&self) -> bool;

    /// Whether container contents can be modified at runtime ("shifter ...
    /// does not allow for dynamic modification of container contents at
    /// runtime", podman-hpc does).
    fn supports_runtime_modification(&self) -> bool;

    /// Mean startup time for `ranks` ranks using this runtime's image
    /// cache (drives Fig 2).
    fn startup_time(&self, ranks: u32) -> f64 {
        self.environment().import_time(ranks)
    }
}

/// A container execution context: image + run parameters, ready to host
/// DMTCP-managed processes.
pub struct Container {
    pub runtime_name: &'static str,
    pub image: Image,
    pub spec: RunSpec,
}

impl Container {
    /// Effective environment: image env overlaid with run overrides.
    pub fn effective_env(&self) -> BTreeMap<String, String> {
        let mut env = self.image.env.clone();
        env.extend(self.spec.env.clone());
        env.insert("CONTAINER_RUNTIME".into(), self.runtime_name.to_string());
        env.insert("CONTAINER_IMAGE".into(), self.image.reference());
        env
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn volume_path_translation() {
        let rs = RunSpec::default()
            .volume("/global/scratch/u/ckpt", "/ckpt")
            .volume("/global/homes/u", "/home/u");
        assert_eq!(
            rs.host_path("/ckpt/img.dmtcp").as_deref(),
            Some("/global/scratch/u/ckpt/img.dmtcp")
        );
        assert_eq!(
            rs.host_path("/home/u/x").as_deref(),
            Some("/global/homes/u/x")
        );
        assert_eq!(rs.host_path("/etc/passwd"), None);
    }

    #[test]
    fn effective_env_overlay() {
        let mut image = Image::base("app", "v1", 1);
        image.env.insert("A".into(), "from-image".into());
        image.env.insert("B".into(), "keep".into());
        let c = Container {
            runtime_name: "shifter",
            image,
            spec: RunSpec::default().env("A", "override"),
        };
        let env = c.effective_env();
        assert_eq!(env.get("A").map(String::as_str), Some("override"));
        assert_eq!(env.get("B").map(String::as_str), Some("keep"));
        assert_eq!(env.get("CONTAINER_RUNTIME").map(String::as_str), Some("shifter"));
    }
}
