//! NERSC's HPC container runtimes: shifter and podman-hpc.
//!
//! Models the two container stacks the paper runs DMTCP inside, at the
//! fidelity its findings need: Containerfile builds with DMTCP-embedding
//! detection ([`image`]), registries and local stores ([`store`]),
//! squashfile conversion ([`squash`]), the runtime capability differences
//! (build-on-system, runtime modification — [`shifter`] vs
//! [`podman_hpc`]), startup-performance models (Fig 2, via
//! [`crate::fsmodel`]), and container execution contexts ([`Container`])
//! that plug into the C/R layer as `cr::Substrate::container(..)`, which
//! enforces the DMTCP-must-be-in-the-image constraint on launch and
//! restart.

pub mod image;
pub mod podman_hpc;
pub mod runtime;
pub mod shifter;
pub mod squash;
pub mod store;

pub use image::{build_image, parse_containerfile, Image, Instruction, Layer, EMBED_DMTCP_SNIPPET};
pub use podman_hpc::PodmanHpc;
pub use runtime::{Container, ContainerRuntime, RunSpec};
pub use shifter::Shifter;
pub use squash::{squash, SquashImage};
pub use store::{ImageStore, Registry};
