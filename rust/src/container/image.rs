//! Container images and Containerfile builds.
//!
//! "A Containerfile is a more general form of a Dockerfile—they follow the
//! same syntax" — this module parses that syntax (the subset the paper's
//! workflows use: FROM/RUN/COPY/ENV/WORKDIR/LABEL/ENTRYPOINT) and models
//! builds as layer stacks. The detail that matters most to the paper is
//! tracked explicitly: **whether DMTCP was installed inside the image**
//! ("DMTCP can not perform a checkpoint from outside the container; it has
//! to be included within the container at the time of its creation").

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// One image layer (one build instruction's effect).
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    /// The instruction that produced the layer (for `history`).
    pub instruction: String,
    /// Bytes this layer adds.
    pub size_bytes: u64,
}

/// A container image.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Image {
    pub name: String,
    pub tag: String,
    pub layers: Vec<Layer>,
    pub env: BTreeMap<String, String>,
    pub entrypoint: Option<String>,
    pub labels: BTreeMap<String, String>,
    /// DMTCP is installed inside this image (checkpointing prerequisite).
    pub has_dmtcp: bool,
}

impl Image {
    /// `name:tag` reference.
    pub fn reference(&self) -> String {
        format!("{}:{}", self.name, self.tag)
    }

    /// Total image size.
    pub fn size_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.size_bytes).sum()
    }

    /// A minimal base image (think `docker.io/library/ubuntu`).
    pub fn base(name: &str, tag: &str, size_bytes: u64) -> Self {
        Self {
            name: name.into(),
            tag: tag.into(),
            layers: vec![Layer {
                instruction: format!("FROM scratch ({name}:{tag})"),
                size_bytes,
            }],
            ..Default::default()
        }
    }
}

/// A parsed Containerfile instruction.
#[derive(Debug, Clone, PartialEq)]
pub enum Instruction {
    From(String),
    Run(String),
    Copy { src: String, dst: String },
    Env { key: String, val: String },
    Workdir(String),
    Label { key: String, val: String },
    Entrypoint(String),
}

/// Parse a Containerfile/Dockerfile (line continuations supported).
pub fn parse_containerfile(text: &str) -> Result<Vec<Instruction>> {
    // Join continuation lines first.
    let mut joined: Vec<String> = Vec::new();
    let mut acc = String::new();
    for raw in text.lines() {
        let line = raw.trim_end();
        let trimmed = line.trim_start();
        if trimmed.is_empty() || (trimmed.starts_with('#') && acc.is_empty()) {
            continue;
        }
        if let Some(head) = line.strip_suffix('\\') {
            acc.push_str(head);
            acc.push(' ');
        } else {
            acc.push_str(line);
            joined.push(std::mem::take(&mut acc));
        }
    }
    if !acc.is_empty() {
        return Err(Error::Container("dangling line continuation".into()));
    }

    let mut out = Vec::new();
    for (i, line) in joined.iter().enumerate() {
        let line = line.trim();
        let (op, rest) = match line.split_once(char::is_whitespace) {
            Some((op, rest)) => (op.to_ascii_uppercase(), rest.trim()),
            None => (line.to_ascii_uppercase(), ""),
        };
        let bad = |m: &str| Error::Container(format!("instruction {}: {m}", i + 1));
        match op.as_str() {
            "FROM" => {
                if rest.is_empty() {
                    return Err(bad("FROM needs an image reference"));
                }
                out.push(Instruction::From(rest.to_string()));
            }
            "RUN" => out.push(Instruction::Run(rest.to_string())),
            "COPY" | "ADD" => {
                let mut parts = rest.split_whitespace();
                let src = parts.next().ok_or_else(|| bad("COPY needs src dst"))?;
                let dst = parts.next().ok_or_else(|| bad("COPY needs src dst"))?;
                out.push(Instruction::Copy {
                    src: src.into(),
                    dst: dst.into(),
                });
            }
            "ENV" => {
                let (k, v) = rest
                    .split_once('=')
                    .or_else(|| rest.split_once(char::is_whitespace))
                    .ok_or_else(|| bad("ENV needs key=value"))?;
                out.push(Instruction::Env {
                    key: k.trim().into(),
                    val: v.trim().into(),
                });
            }
            "WORKDIR" => out.push(Instruction::Workdir(rest.into())),
            "LABEL" => {
                let (k, v) = rest.split_once('=').ok_or_else(|| bad("LABEL needs key=value"))?;
                out.push(Instruction::Label {
                    key: k.trim().into(),
                    val: v.trim().trim_matches('"').into(),
                });
            }
            "ENTRYPOINT" | "CMD" => out.push(Instruction::Entrypoint(rest.into())),
            other => return Err(bad(&format!("unsupported instruction {other}"))),
        }
    }
    if !matches!(out.first(), Some(Instruction::From(_))) {
        return Err(Error::Container("Containerfile must start with FROM".into()));
    }
    Ok(out)
}

/// Does a RUN command install DMTCP? (The paper's embedding snippet clones
/// and `make install`s it; package-manager installs count too.)
fn run_installs_dmtcp(cmd: &str) -> bool {
    let c = cmd.to_ascii_lowercase();
    c.contains("dmtcp")
        && (c.contains("make install")
            || c.contains("apt") && c.contains("install")
            || c.contains("yum install")
            || c.contains("conda install")
            || c.contains("pip install"))
}

/// Estimated layer size of a RUN command (deterministic, content-derived —
/// enough for store/squash accounting).
fn run_layer_size(cmd: &str) -> u64 {
    let base = 2 * 1024 * 1024u64;
    let c = cmd.to_ascii_lowercase();
    let mut size = base + cmd.len() as u64 * 1024;
    if c.contains("dmtcp") {
        size += 18 * 1024 * 1024; // DMTCP build artifacts
    }
    if c.contains("geant4") || c.contains("cvmfs") {
        size += 350 * 1024 * 1024; // toolkit + data files
    }
    if c.contains("install") {
        size += 40 * 1024 * 1024;
    }
    size
}

/// Build an image from instructions, resolving `FROM` through `resolve`.
pub fn build_image(
    name: &str,
    tag: &str,
    instructions: &[Instruction],
    resolve: impl Fn(&str) -> Option<Image>,
) -> Result<Image> {
    let mut image = match instructions.first() {
        Some(Instruction::From(base_ref)) => {
            let mut base = resolve(base_ref).ok_or_else(|| {
                Error::Container(format!("base image {base_ref:?} not found"))
            })?;
            base.name = name.into();
            base.tag = tag.into();
            base
        }
        _ => return Err(Error::Container("first instruction must be FROM".into())),
    };

    for ins in &instructions[1..] {
        match ins {
            Instruction::From(_) => {
                return Err(Error::Container("multi-stage builds not supported".into()))
            }
            Instruction::Run(cmd) => {
                if run_installs_dmtcp(cmd) {
                    image.has_dmtcp = true;
                }
                image.layers.push(Layer {
                    instruction: format!("RUN {cmd}"),
                    size_bytes: run_layer_size(cmd),
                });
            }
            Instruction::Copy { src, dst } => {
                image.layers.push(Layer {
                    instruction: format!("COPY {src} {dst}"),
                    size_bytes: 1024 * 1024,
                });
            }
            Instruction::Env { key, val } => {
                image.env.insert(key.clone(), val.clone());
            }
            Instruction::Workdir(d) => {
                image.env.insert("PWD".into(), d.clone());
            }
            Instruction::Label { key, val } => {
                image.labels.insert(key.clone(), val.clone());
            }
            Instruction::Entrypoint(e) => image.entrypoint = Some(e.clone()),
        }
    }
    Ok(image)
}

/// The paper's own snippet: extend an existing application container with
/// DMTCP in one RUN.
pub const EMBED_DMTCP_SNIPPET: &str = r#"FROM my_application_container:latest
RUN git clone https://github.com/dmtcp/dmtcp.git \
 && cd dmtcp \
 && ./configure && make \
 && make install
"#;

#[cfg(test)]
mod tests {
    use super::*;

    fn resolver(base: Image) -> impl Fn(&str) -> Option<Image> {
        move |r: &str| {
            if r == base.reference() || r == "my_application_container:latest" {
                Some(base.clone())
            } else {
                None
            }
        }
    }

    #[test]
    fn parse_papers_snippet() {
        let ins = parse_containerfile(EMBED_DMTCP_SNIPPET).unwrap();
        assert_eq!(ins.len(), 2);
        assert!(matches!(&ins[0], Instruction::From(f) if f == "my_application_container:latest"));
        assert!(matches!(&ins[1], Instruction::Run(c) if c.contains("make install")));
    }

    #[test]
    fn build_embeds_dmtcp() {
        let base = Image::base("my_application_container", "latest", 500 * 1024 * 1024);
        let ins = parse_containerfile(EMBED_DMTCP_SNIPPET).unwrap();
        let img = build_image("elvis", "test", &ins, resolver(base)).unwrap();
        assert!(img.has_dmtcp, "DMTCP install not detected");
        assert_eq!(img.reference(), "elvis:test");
        assert!(img.size_bytes() > 500 * 1024 * 1024);
    }

    #[test]
    fn build_without_dmtcp_flags_false() {
        let base = Image::base("ubuntu", "22.04", 80 * 1024 * 1024);
        let ins = parse_containerfile("FROM ubuntu:22.04\nRUN pip install numpy\n").unwrap();
        let img = build_image("app", "v1", &ins, resolver(base)).unwrap();
        assert!(!img.has_dmtcp);
    }

    #[test]
    fn env_label_entrypoint() {
        let base = Image::base("ubuntu", "22.04", 1024);
        let file = "FROM ubuntu:22.04\nENV G4VERSION=10.7\nLABEL maintainer=\"nersc\"\nENTRYPOINT ./run.sh\nWORKDIR /work\n";
        let ins = parse_containerfile(file).unwrap();
        let img = build_image("g4", "10.7", &ins, resolver(base)).unwrap();
        assert_eq!(img.env.get("G4VERSION").map(String::as_str), Some("10.7"));
        assert_eq!(img.labels.get("maintainer").map(String::as_str), Some("nersc"));
        assert_eq!(img.entrypoint.as_deref(), Some("./run.sh"));
        assert_eq!(img.env.get("PWD").map(String::as_str), Some("/work"));
    }

    #[test]
    fn parse_errors() {
        assert!(parse_containerfile("RUN echo hi\n").is_err()); // no FROM
        assert!(parse_containerfile("FROM a:b\nFLY now\n").is_err());
        assert!(parse_containerfile("FROM a:b\nRUN echo \\").is_err()); // dangling
        assert!(parse_containerfile("FROM a:b\nCOPY onlyone\n").is_err());
    }

    #[test]
    fn unknown_base_rejected() {
        let ins = parse_containerfile("FROM nowhere:latest\n").unwrap();
        let err = build_image("x", "y", &ins, |_| None).unwrap_err();
        assert!(err.to_string().contains("not found"));
    }
}
