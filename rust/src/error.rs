//! Crate-wide error type.

use thiserror::Error;

/// Unified error type for all `nersc_cr` subsystems.
#[derive(Error, Debug)]
pub enum Error {
    /// PJRT / XLA runtime failures (compile, execute, literal conversion).
    #[error("xla: {0}")]
    Xla(String),

    /// I/O failures (checkpoint files, artifact loading, sockets).
    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    /// Malformed or corrupt checkpoint image.
    #[error("checkpoint image: {0}")]
    Image(String),

    /// DMTCP coordinator protocol violations.
    #[error("coordinator protocol: {0}")]
    Protocol(String),

    /// Batch-scheduler errors (unknown job, invalid directive, ...).
    #[error("slurm: {0}")]
    Slurm(String),

    /// Container build/run errors.
    #[error("container: {0}")]
    Container(String),

    /// Artifact manifest problems.
    #[error("manifest: {0}")]
    Manifest(String),

    /// Workload configuration errors.
    #[error("workload: {0}")]
    Workload(String),

    /// CLI usage errors.
    #[error("usage: {0}")]
    Usage(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
