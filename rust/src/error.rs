//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls: `thiserror` is not in the offline
//! dependency closure (see `vendor/README.md`).

use std::fmt;

/// Unified error type for all `nersc_cr` subsystems.
#[derive(Debug)]
pub enum Error {
    /// Compute-backend failures (engine startup, compile, execute,
    /// service-channel breakdowns).
    Backend(String),

    /// I/O failures (checkpoint files, artifact loading, sockets).
    Io(std::io::Error),

    /// Malformed or corrupt checkpoint image.
    Image(String),

    /// Detected corruption in checkpoint storage: a chunk referenced by an
    /// image manifest is missing from the content-addressed store, or its
    /// bytes fail CRC/length verification. Restart paths surface this
    /// instead of panicking or silently zero-filling state.
    Corrupt(String),

    /// DMTCP coordinator protocol violations.
    Protocol(String),

    /// Batch-scheduler errors (unknown job, invalid directive, ...).
    Slurm(String),

    /// Container build/run errors.
    Container(String),

    /// Artifact manifest problems.
    Manifest(String),

    /// Workload configuration errors.
    Workload(String),

    /// An automated C/R session used up its incarnation budget without
    /// completing (the contained value is the budget that was exhausted).
    IncarnationsExhausted(u32),

    /// Campaign-executor failures scoped to one session of a fleet (a
    /// worker panic, a poisoned slot): the affected session is reported
    /// failed while the rest of the campaign keeps running.
    Campaign(String),

    /// CLI usage errors.
    Usage(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Backend(msg) => write!(f, "backend: {msg}"),
            Error::Io(err) => write!(f, "io: {err}"),
            Error::Image(msg) => write!(f, "checkpoint image: {msg}"),
            Error::Corrupt(msg) => write!(f, "corrupt checkpoint storage: {msg}"),
            Error::Protocol(msg) => write!(f, "coordinator protocol: {msg}"),
            Error::Slurm(msg) => write!(f, "slurm: {msg}"),
            Error::Container(msg) => write!(f, "container: {msg}"),
            Error::Manifest(msg) => write!(f, "manifest: {msg}"),
            Error::Workload(msg) => write!(f, "workload: {msg}"),
            Error::IncarnationsExhausted(budget) => {
                write!(f, "incarnation budget ({budget}) exhausted")
            }
            Error::Campaign(msg) => write!(f, "campaign: {msg}"),
            Error::Usage(msg) => write!(f, "usage: {msg}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(err) => Some(err),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Backend(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes() {
        assert_eq!(Error::Slurm("x".into()).to_string(), "slurm: x");
        assert_eq!(
            Error::Image("bad".into()).to_string(),
            "checkpoint image: bad"
        );
        assert_eq!(
            Error::Campaign("worker panicked".into()).to_string(),
            "campaign: worker panicked"
        );
    }

    #[test]
    fn corrupt_displays_prefix() {
        assert_eq!(
            Error::Corrupt("chunk gone".into()).to_string(),
            "corrupt checkpoint storage: chunk gone"
        );
    }

    #[test]
    fn incarnations_exhausted_displays_budget() {
        assert_eq!(
            Error::IncarnationsExhausted(8).to_string(),
            "incarnation budget (8) exhausted"
        );
    }

    #[test]
    fn io_conversion_preserves_source() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let err: Error = io.into();
        assert!(err.to_string().contains("gone"));
        assert!(std::error::Error::source(&err).is_some());
    }
}
