//! LDMS-analog: the Lightweight Distributed Metric Service sampler.
//!
//! The paper's Fig 4 data "were acquired using the Lightweight Distributed
//! Metric Service (LDMS)": a daemon sampling memory and CPU of the job's
//! processes on a fixed interval. This sampler does the same for simulated
//! processes — it polls their [`ProcessStats`] counters from a background
//! thread and accumulates [`TimeSeries`] for memory and CPU utilization.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::dmtcp::process::ProcessStats;
use crate::metrics::series::TimeSeries;

/// Fixed per-process overhead added to the memory proxy (interpreter,
/// libraries, DMTCP runtime — the paper's ~0.8% "loading of DMTCP and
/// associated files").
pub const BASE_PROCESS_OVERHEAD: u64 = 64 * 1024 * 1024;

/// A running sampler; dropping it stops the thread.
pub struct LdmsSampler {
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
    out: Arc<Mutex<SampledSeries>>,
}

/// The collected series.
#[derive(Debug, Clone, Default)]
pub struct SampledSeries {
    /// Aggregate memory across processes (bytes).
    pub memory: TimeSeries,
    /// Aggregate CPU utilization fraction `[0, n_procs]`.
    pub cpu: TimeSeries,
    /// Total steps done across processes.
    pub steps: TimeSeries,
    /// Cumulative checkpoint bytes stored across processes (for full
    /// images: file sizes; for incremental images: manifest + new chunks —
    /// the flat-vs-steep contrast between the two pipelines).
    pub ckpt_stored: TimeSeries,
}

impl LdmsSampler {
    /// Start sampling `procs` every `interval`.
    pub fn start(procs: Vec<Arc<ProcessStats>>, interval: Duration) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let out = Arc::new(Mutex::new(SampledSeries {
            memory: TimeSeries::new("memory_bytes"),
            cpu: TimeSeries::new("cpu_util"),
            steps: TimeSeries::new("steps_done"),
            ckpt_stored: TimeSeries::new("ckpt_stored_bytes"),
        }));
        let stop2 = Arc::clone(&stop);
        let out2 = Arc::clone(&out);
        let join = std::thread::Builder::new()
            .name("ldms-sampler".into())
            .spawn(move || {
                let t0 = Instant::now();
                while !stop2.load(Ordering::Relaxed) {
                    let t = t0.elapsed().as_secs_f64();
                    let mut mem = 0u64;
                    let mut cpu = 0.0f64;
                    let mut steps = 0u64;
                    let mut stored = 0u64;
                    for p in &procs {
                        mem += p.memory_bytes(BASE_PROCESS_OVERHEAD);
                        cpu += p.cpu_fraction();
                        steps += p.steps_done.load(Ordering::Relaxed);
                        stored += p.ckpt_stored_bytes.load(Ordering::Relaxed);
                    }
                    {
                        let mut o = out2.lock().expect("ldms series poisoned");
                        o.memory.push(t, mem as f64);
                        o.cpu.push(t, cpu);
                        o.steps.push(t, steps as f64);
                        o.ckpt_stored.push(t, stored as f64);
                    }
                    std::thread::sleep(interval);
                }
            })
            .expect("spawn ldms sampler");
        Self {
            stop,
            join: Some(join),
            out,
        }
    }

    /// Stop sampling and return the collected series.
    pub fn stop(mut self) -> SampledSeries {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        let out = self.out.lock().expect("ldms series poisoned").clone();
        out
    }

    /// Snapshot without stopping.
    pub fn snapshot(&self) -> SampledSeries {
        self.out.lock().expect("ldms series poisoned").clone()
    }
}

impl Drop for LdmsSampler {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_running_process() {
        let stats = Arc::new(ProcessStats::default());
        stats.alive.store(true, Ordering::Relaxed);
        stats.n_threads.store(2, Ordering::Relaxed);
        stats.state_bytes.store(1_000_000, Ordering::Relaxed);

        let sampler = LdmsSampler::start(vec![Arc::clone(&stats)], Duration::from_millis(5));
        std::thread::sleep(Duration::from_millis(40));
        // Mid-run: park one thread (checkpoint) and add transient memory.
        stats.parked.store(1, Ordering::Relaxed);
        stats.transient_bytes.store(5_000_000, Ordering::Relaxed);
        std::thread::sleep(Duration::from_millis(40));
        let series = sampler.stop();

        assert!(series.memory.len() >= 8, "too few samples");
        assert!(series.memory.max() >= (BASE_PROCESS_OVERHEAD + 5_500_000) as f64);
        assert!(series.cpu.max() > 0.9, "cpu should be ~1.0 while unparked");
        assert!(series.cpu.min() < 0.6, "cpu should dip when parked");
    }

    #[test]
    fn dead_process_reads_zero() {
        let stats = Arc::new(ProcessStats::default());
        // alive=false by default
        let sampler = LdmsSampler::start(vec![stats], Duration::from_millis(5));
        std::thread::sleep(Duration::from_millis(25));
        let series = sampler.stop();
        assert_eq!(series.memory.max(), 0.0);
        assert_eq!(series.cpu.max(), 0.0);
    }
}
