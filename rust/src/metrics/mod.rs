//! Metrics: the LDMS-analog sampler and time-series tooling (Fig 4
//! substrate).

pub mod ldms;
pub mod series;

pub use ldms::{LdmsSampler, SampledSeries, BASE_PROCESS_OVERHEAD};
pub use series::{ascii_chart, to_csv, TimeSeries};
