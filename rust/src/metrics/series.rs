//! Time series: storage, aggregation, CSV and terminal rendering.
//!
//! The Fig 4 reproduction renders memory/CPU series as CSV (for external
//! plotting) and as ASCII charts (so `cargo bench` output shows the shape
//! directly, like the paper's figure does).

/// A named `(t, value)` series.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TimeSeries {
    pub name: String,
    pub t: Vec<f64>,
    pub v: Vec<f64>,
}

impl TimeSeries {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            t: Vec::new(),
            v: Vec::new(),
        }
    }

    /// Build a series from bare values (`t` = sample index): how the
    /// campaign report lifts latency/wait samples into series form for
    /// [`TimeSeries::percentile`] and windowed SLO rollups.
    pub fn from_values(name: impl Into<String>, vals: &[f64]) -> Self {
        Self {
            name: name.into(),
            t: (0..vals.len()).map(|i| i as f64).collect(),
            v: vals.to_vec(),
        }
    }

    pub fn push(&mut self, t: f64, v: f64) {
        self.t.push(t);
        self.v.push(v);
    }

    pub fn len(&self) -> usize {
        self.t.len()
    }

    pub fn is_empty(&self) -> bool {
        self.t.is_empty()
    }

    pub fn max(&self) -> f64 {
        self.v.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn min(&self) -> f64 {
        self.v.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn mean(&self) -> f64 {
        if self.v.is_empty() {
            return 0.0;
        }
        self.v.iter().sum::<f64>() / self.v.len() as f64
    }

    /// Mean over the subrange `t ∈ [t0, t1)`.
    pub fn mean_between(&self, t0: f64, t1: f64) -> f64 {
        let vals: Vec<f64> = self
            .t
            .iter()
            .zip(&self.v)
            .filter(|(&t, _)| t >= t0 && t < t1)
            .map(|(_, &v)| v)
            .collect();
        if vals.is_empty() {
            0.0
        } else {
            vals.iter().sum::<f64>() / vals.len() as f64
        }
    }

    /// Nearest-rank percentile of the values, NaN-safe: NaN samples are
    /// ignored, and an all-NaN or empty series returns NaN (callers that
    /// want `0.0`-for-empty decide that themselves). `p` is in percent
    /// and is clamped to `[0, 100]`; `percentile(50.0)` is the median.
    ///
    /// This is the one percentile implementation in the crate — the
    /// campaign report's p50/p99 SLOs all route through it.
    pub fn percentile(&self, p: f64) -> f64 {
        let mut vals: Vec<f64> = self.v.iter().copied().filter(|x| !x.is_nan()).collect();
        if vals.is_empty() {
            return f64::NAN;
        }
        vals.sort_by(|a, b| a.partial_cmp(b).expect("NaN filtered"));
        let p = p.clamp(0.0, 100.0);
        let rank = (p / 100.0 * vals.len() as f64).ceil() as usize;
        vals[rank.clamp(1, vals.len()) - 1]
    }

    /// Local maxima above `threshold` (the Fig 4 checkpoint spikes).
    pub fn peaks_above(&self, threshold: f64) -> Vec<f64> {
        let mut peaks = Vec::new();
        for i in 1..self.v.len().saturating_sub(1) {
            if self.v[i] > threshold && self.v[i] >= self.v[i - 1] && self.v[i] >= self.v[i + 1] {
                peaks.push(self.t[i]);
            }
        }
        peaks
    }
}

/// Render several aligned series to CSV (`t,name1,name2,...`). Series are
/// sampled on the union time grid with last-observation carry-forward.
pub fn to_csv(series: &[&TimeSeries]) -> String {
    let mut grid: Vec<f64> = series.iter().flat_map(|s| s.t.iter().copied()).collect();
    grid.sort_by(|a, b| a.partial_cmp(b).unwrap());
    grid.dedup();
    let mut out = String::from("t");
    for s in series {
        out.push(',');
        out.push_str(&s.name);
    }
    out.push('\n');
    let mut idx = vec![0usize; series.len()];
    let mut last = vec![0.0f64; series.len()];
    for &t in &grid {
        out.push_str(&format!("{t:.3}"));
        for (k, s) in series.iter().enumerate() {
            while idx[k] < s.t.len() && s.t[idx[k]] <= t {
                last[k] = s.v[idx[k]];
                idx[k] += 1;
            }
            out.push_str(&format!(",{:.6}", last[k]));
        }
        out.push('\n');
    }
    out
}

/// Render one series as a terminal chart (rows of `#`), `width` columns.
pub fn ascii_chart(s: &TimeSeries, width: usize, height: usize) -> String {
    if s.is_empty() || width == 0 || height == 0 {
        return String::new();
    }
    let (t0, t1) = (s.t[0], *s.t.last().unwrap());
    let span = (t1 - t0).max(1e-9);
    // Bucket means per column.
    let mut sums = vec![0.0f64; width];
    let mut counts = vec![0usize; width];
    for (&t, &v) in s.t.iter().zip(&s.v) {
        let col = (((t - t0) / span) * (width - 1) as f64).round() as usize;
        sums[col] += v;
        counts[col] += 1;
    }
    let cols: Vec<f64> = sums
        .iter()
        .zip(&counts)
        .map(|(&s, &c)| if c > 0 { s / c as f64 } else { f64::NAN })
        .collect();
    // Carry forward empty buckets.
    let mut filled = Vec::with_capacity(width);
    let mut lastv = cols.iter().copied().find(|v| !v.is_nan()).unwrap_or(0.0);
    for v in cols {
        if !v.is_nan() {
            lastv = v;
        }
        filled.push(lastv);
    }
    let vmax = filled.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let vmin = filled.iter().copied().fold(f64::INFINITY, f64::min);
    let vspan = (vmax - vmin).max(1e-9);

    let mut rows = vec![vec![' '; width]; height];
    for (x, &v) in filled.iter().enumerate() {
        let h = (((v - vmin) / vspan) * (height - 1) as f64).round() as usize;
        for row in rows.iter().rev().take(h + 1) {
            let _ = row; // height fill below
        }
        for y in 0..=h {
            rows[height - 1 - y][x] = '#';
        }
    }
    let mut out = format!("{} [{:.3} .. {:.3}]\n", s.name, vmin, vmax);
    for row in rows {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    out.push('+');
    out.extend(std::iter::repeat('-').take(width));
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp() -> TimeSeries {
        let mut s = TimeSeries::new("ramp");
        for i in 0..10 {
            s.push(i as f64, i as f64 * 2.0);
        }
        s
    }

    #[test]
    fn stats() {
        let s = ramp();
        assert_eq!(s.len(), 10);
        assert_eq!(s.max(), 18.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.mean(), 9.0);
        assert_eq!(s.mean_between(0.0, 5.0), 4.0);
    }

    #[test]
    fn peaks() {
        let mut s = TimeSeries::new("spiky");
        for (t, v) in [(0.0, 1.0), (1.0, 5.0), (2.0, 1.0), (3.0, 6.0), (4.0, 1.0)] {
            s.push(t, v);
        }
        let p = s.peaks_above(3.0);
        assert_eq!(p, vec![1.0, 3.0]);
    }

    #[test]
    fn csv_carry_forward() {
        let mut a = TimeSeries::new("a");
        a.push(0.0, 1.0);
        a.push(2.0, 3.0);
        let mut b = TimeSeries::new("b");
        b.push(1.0, 10.0);
        let csv = to_csv(&[&a, &b]);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "t,a,b");
        assert_eq!(lines.len(), 4); // header + t=0,1,2
        assert!(lines[2].starts_with("1.000,1.000000,10.000000"));
        assert!(lines[3].starts_with("2.000,3.000000,10.000000"));
    }

    #[test]
    fn chart_renders() {
        let chart = ascii_chart(&ramp(), 20, 5);
        assert!(chart.contains('#'));
        assert_eq!(chart.lines().count(), 7); // title + 5 rows + axis
    }

    #[test]
    fn empty_series_safe() {
        let s = TimeSeries::new("empty");
        assert_eq!(ascii_chart(&s, 10, 3), "");
        assert_eq!(s.mean(), 0.0);
    }

    #[test]
    fn percentile_empty_is_nan() {
        assert!(TimeSeries::new("p").percentile(50.0).is_nan());
        // All-NaN behaves like empty.
        let s = TimeSeries::from_values("nan", &[f64::NAN, f64::NAN]);
        assert!(s.percentile(99.0).is_nan());
    }

    #[test]
    fn percentile_single_value() {
        let s = TimeSeries::from_values("one", &[7.5]);
        assert_eq!(s.percentile(0.0), 7.5);
        assert_eq!(s.percentile(50.0), 7.5);
        assert_eq!(s.percentile(100.0), 7.5);
    }

    #[test]
    fn percentile_nearest_rank_with_duplicates() {
        let s = TimeSeries::from_values("dup", &[2.0, 1.0, 2.0, 2.0, 4.0, 3.0]);
        assert_eq!(s.percentile(50.0), 2.0);
        assert_eq!(s.percentile(99.0), 4.0);
        assert_eq!(s.percentile(100.0), 4.0);
        // Out-of-range p clamps rather than panicking.
        assert_eq!(s.percentile(-10.0), 1.0);
        assert_eq!(s.percentile(200.0), 4.0);
    }

    #[test]
    fn percentile_skips_nan_samples() {
        let s = TimeSeries::from_values("mix", &[1.0, f64::NAN, 3.0, 2.0]);
        assert_eq!(s.percentile(50.0), 2.0);
        assert_eq!(s.percentile(99.0), 3.0);
    }

    #[test]
    fn from_values_indexes_time() {
        let s = TimeSeries::from_values("fv", &[5.0, 6.0]);
        assert_eq!(s.t, vec![0.0, 1.0]);
        assert_eq!(s.v, vec![5.0, 6.0]);
    }
}
