//! The pluggable compute-backend boundary.
//!
//! The paper's C/R layer is deliberately substrate-agnostic: DMTCP wraps
//! *any* process, and the NERSC scripts run the same workload under
//! shifter, podman-hpc or bare metal. This module mirrors that design at
//! the compute layer. Everything above the transport kernels — the C/R
//! workflows, the service thread, the workloads, the benches — talks to a
//! [`ComputeBackend`] trait object and never to a concrete engine.
//!
//! Two implementations ship today:
//!
//! * [`ReferenceBackend`](super::reference::ReferenceBackend) — a pure-Rust
//!   port of the kernel semantics specified by
//!   `python/compile/kernels/ref.py` (the independent oracle the Pallas
//!   kernel is verified against). Always available, no artifacts or
//!   external runtime needed, bit-reproducible. The default.
//! * [`Engine`](super::engine::Engine) — the PJRT/XLA engine executing the
//!   AOT-lowered HLO artifacts. Feature-gated behind `pjrt` and selected
//!   with `NERSC_CR_BACKEND=pjrt`.
//!
//! Selection happens once, in [`load_backend`]; see the decision table
//! there. `DESIGN.md` §Backends documents the contract in prose.

use std::path::Path;

use crate::error::{Error, Result};
use crate::runtime::manifest::Manifest;
use crate::runtime::state::{ParticleState, StaticInputs};

/// Compile/execute statistics (perf bookkeeping, `EXPERIMENTS.md` §Perf).
#[derive(Debug, Default, Clone)]
pub struct BackendStats {
    /// Artifact compilations performed (0 for backends that don't compile).
    pub compiles: u64,
    /// Wall seconds spent compiling.
    pub compile_secs: f64,
    /// Kernel invocations (a fused scan counts once).
    pub executions: u64,
    /// Wall seconds spent executing.
    pub execute_secs: f64,
    /// Kernel steps advanced (a scan counts `scan_steps`).
    pub steps: u64,
}

/// A transport/scoring compute engine.
///
/// Implementations are **single-threaded** by contract: one backend
/// instance lives on one thread (the PJRT client is `Rc`-backed and not
/// `Send`). Multi-threaded callers go through
/// [`ComputeService`](super::service::ComputeService), which owns a backend
/// on a dedicated thread and serves cloneable handles.
///
/// Correctness contract (enforced by `rust/tests/integration_runtime.rs`
/// and `rust/tests/reference_backend.rs`):
///
/// * `transport_step` and `transport_step_ref` agree exactly on integer
///   state (rng counters, liveness) and to float tolerance elsewhere.
/// * One `transport_scan` equals `manifest().scan_steps` repeated
///   `transport_step` calls.
/// * Same inputs produce bit-identical outputs (the C/R keystone).
/// * RNG counters advance by exactly `manifest().rng_draws_per_step` per
///   step, so a checkpoint/restart resumes the Monte-Carlo stream exactly.
pub trait ComputeBackend {
    /// Short backend identifier (`"reference"`, `"pjrt"`), for logs and
    /// reports.
    fn name(&self) -> &'static str;

    /// The artifact manifest this backend was configured from (shapes,
    /// scan length, RNG stride).
    fn manifest(&self) -> &Manifest;

    /// Advance one transport step (the production kernel path).
    fn transport_step(&self, state: &mut ParticleState, si: &StaticInputs) -> Result<()>;

    /// Advance one transport step through the backend's reference/oracle
    /// path (A/B checking). Backends without a distinct oracle lowering
    /// may route this to [`Self::transport_step`].
    fn transport_step_ref(&self, state: &mut ParticleState, si: &StaticInputs) -> Result<()> {
        self.transport_step(state, si)
    }

    /// Advance `manifest().scan_steps` fused steps (the hot path: one
    /// backend round-trip per scan).
    fn transport_scan(&self, state: &mut ParticleState, si: &StaticInputs) -> Result<()>;

    /// The oracle-lowering variant of [`Self::transport_scan`]; identical
    /// numerics, used for A/B perf comparisons (`NERSC_CR_SCAN=ref`).
    fn transport_scan_ref(&self, state: &mut ParticleState, si: &StaticInputs) -> Result<()> {
        self.transport_scan(state, si)
    }

    /// Detector readout over the scoring grid:
    /// `(roi_edep, total_edep, hit_voxels)`.
    fn score_roi(&self, edep: &[f32], roi_mask: &[f32]) -> Result<(f32, f32, f32)>;

    /// Dose-volume histogram of the scoring grid inside the ROI: counts of
    /// voxels per energy bin over `[e_min, e_max)` (overflow clamps into
    /// the last bin), `manifest().spectrum_bins` bins.
    fn detector_spectrum(
        &self,
        edep: &[f32],
        roi_mask: &[f32],
        e_min: f32,
        e_max: f32,
    ) -> Result<Vec<f32>>;

    /// Statistics snapshot.
    fn stats(&self) -> BackendStats;
}

/// Which backend [`load_backend`] should construct.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// The pure-Rust reference backend (always available).
    Reference,
    /// The PJRT/XLA artifact engine (requires the `pjrt` cargo feature).
    Pjrt,
}

impl BackendKind {
    /// Resolve the backend choice from `NERSC_CR_BACKEND`
    /// (`reference` | `pjrt`; unset defaults to `reference`).
    pub fn from_env() -> Result<Self> {
        match std::env::var("NERSC_CR_BACKEND").as_deref() {
            Err(_) | Ok("") | Ok("reference") => Ok(Self::Reference),
            Ok("pjrt") => Ok(Self::Pjrt),
            Ok(other) => Err(Error::Usage(format!(
                "NERSC_CR_BACKEND={other:?}: expected \"reference\" or \"pjrt\""
            ))),
        }
    }
}

/// Construct the backend selected by `NERSC_CR_BACKEND` (see
/// [`BackendKind::from_env`]).
///
/// * `Reference`: loads `manifest.txt` from `dir` when present (so shapes
///   match any AOT artifacts lying around) and otherwise falls back to the
///   compiled-in default dimensions — no filesystem requirement at all.
/// * `Pjrt`: requires the `pjrt` cargo feature *and* real artifacts in
///   `dir`; errors out otherwise.
pub fn load_backend(dir: &Path) -> Result<Box<dyn ComputeBackend>> {
    match BackendKind::from_env()? {
        BackendKind::Reference => {
            let manifest = Manifest::load_or_default(dir)?;
            load_backend_with(BackendKind::Reference, dir, manifest)
        }
        BackendKind::Pjrt => pjrt_backend(dir),
    }
}

/// As [`load_backend`], but with the backend choice already resolved and
/// the manifest already parsed, so callers that do both eagerly (like
/// `ComputeService::start`) resolve the environment exactly once and don't
/// parse — or log the missing-manifest fallback — twice. Only the
/// reference backend consults `manifest`; the PJRT engine always re-reads
/// its own from `dir`.
pub fn load_backend_with(
    kind: BackendKind,
    dir: &Path,
    manifest: Manifest,
) -> Result<Box<dyn ComputeBackend>> {
    match kind {
        BackendKind::Reference => {
            Ok(Box::new(super::reference::ReferenceBackend::new(manifest)))
        }
        BackendKind::Pjrt => pjrt_backend(dir),
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_backend(dir: &Path) -> Result<Box<dyn ComputeBackend>> {
    Ok(Box::new(super::engine::Engine::load(dir)?))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend(_dir: &Path) -> Result<Box<dyn ComputeBackend>> {
    Err(Error::Usage(
        "NERSC_CR_BACKEND=pjrt but this build has no PJRT support; \
         rebuild with `--features pjrt` (and real xla bindings, see \
         vendor/README.md)"
            .into(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_backend_is_reference() {
        // Guarded rather than forced: tests never mutate process-global
        // env, so only assert when the variable is genuinely unset.
        if std::env::var("NERSC_CR_BACKEND").is_err() {
            assert_eq!(BackendKind::from_env().unwrap(), BackendKind::Reference);
        }
    }

    #[test]
    fn loads_without_artifacts() {
        // Same guard as above: meaningful only under the default selection.
        if std::env::var("NERSC_CR_BACKEND").is_err() {
            let backend = load_backend(Path::new("/nonexistent-ncr-artifacts")).unwrap();
            assert_eq!(backend.name(), "reference");
            assert!(backend.manifest().batch > 0);
        }
    }
}
