//! Compute service: a dedicated thread owning a (possibly non-`Send`)
//! [`ComputeBackend`], serving transport/score requests to any number of
//! worker threads through cloneable [`ComputeHandle`]s.
//!
//! This mirrors the serving-system shape the paper's environment implies
//! (many MPI ranks sharing node-local accelerators): the DMTCP-analog user
//! processes run on their own threads and the request path into the
//! backend is a channel hop, never a Python call. Which backend serves is
//! decided once at startup by [`backend::load_backend_with`]
//! (`NERSC_CR_BACKEND`, default: the pure-Rust reference backend).

use std::path::Path;
use std::sync::mpsc;
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;

use crate::error::{Error, Result};
use crate::runtime::backend::{self, BackendStats, ComputeBackend};
use crate::runtime::manifest::Manifest;
use crate::runtime::state::{ParticleState, StaticInputs};

enum Request {
    Step {
        state: ParticleState,
        si: Arc<StaticInputs>,
        use_ref: bool,
        reply: mpsc::Sender<Result<ParticleState>>,
    },
    Scan {
        state: ParticleState,
        si: Arc<StaticInputs>,
        /// Number of scan invocations (each advances `scan_steps` steps).
        repeats: u32,
        reply: mpsc::Sender<Result<ParticleState>>,
    },
    ScoreRoi {
        edep: Vec<f32>,
        mask: Vec<f32>,
        reply: mpsc::Sender<Result<(f32, f32, f32)>>,
    },
    Spectrum {
        edep: Vec<f32>,
        mask: Vec<f32>,
        e_range: (f32, f32),
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    Stats {
        reply: mpsc::Sender<(&'static str, BackendStats)>,
    },
    Shutdown,
}

/// Owns the backend thread; dropping shuts it down.
pub struct ComputeService {
    tx: mpsc::Sender<Request>,
    manifest: Manifest,
    join: Option<JoinHandle<()>>,
}

/// Cheap, clonable, `Send` handle into the compute service.
#[derive(Clone)]
pub struct ComputeHandle {
    tx: mpsc::Sender<Request>,
    manifest: Manifest,
}

impl ComputeService {
    /// Spawn the service thread and construct the backend selected by
    /// `NERSC_CR_BACKEND` from `dir` (see [`backend::load_backend`]).
    ///
    /// Backend construction (artifact compilation, for PJRT) happens on
    /// the service thread; this call blocks until the backend is ready
    /// (or failed), so callers get load errors eagerly.
    pub fn start(dir: &Path) -> Result<Self> {
        // Manifest parsed on the caller thread too: cheap, and lets handles
        // answer shape questions without a channel hop. Only the reference
        // backend may fall back to compiled-in shapes; PJRT requires real
        // artifacts, so its manifest errors surface here, eagerly.
        let kind = backend::BackendKind::from_env()?;
        let manifest = match kind {
            backend::BackendKind::Reference => Manifest::load_or_default(dir)?,
            backend::BackendKind::Pjrt => Manifest::load(dir)?,
        };
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let dir = dir.to_path_buf();
        let manifest_for_backend = manifest.clone();
        let join = std::thread::Builder::new()
            .name("compute-backend".into())
            .spawn(move || {
                let backend = match backend::load_backend_with(kind, &dir, manifest_for_backend) {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok(()));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                log::debug!("compute service: {} backend ready", backend.name());
                Self::serve(backend, rx);
            })
            .expect("spawn compute-backend thread");
        ready_rx
            .recv()
            .map_err(|_| Error::Backend("backend thread died during load".into()))??;
        Ok(Self {
            tx,
            manifest,
            join: Some(join),
        })
    }

    fn serve(backend: Box<dyn ComputeBackend>, rx: mpsc::Receiver<Request>) {
        // Hot-path selection: both scan lowerings produce bit-identical
        // results (asserted by tests), so this is purely a perf knob.
        let use_ref_scan = std::env::var("NERSC_CR_SCAN").as_deref() == Ok("ref");
        while let Ok(req) = rx.recv() {
            match req {
                Request::Step {
                    mut state,
                    si,
                    use_ref,
                    reply,
                } => {
                    let r = if use_ref {
                        backend.transport_step_ref(&mut state, &si)
                    } else {
                        backend.transport_step(&mut state, &si)
                    };
                    let _ = reply.send(r.map(|()| state));
                }
                Request::Scan {
                    mut state,
                    si,
                    repeats,
                    reply,
                } => {
                    let mut out = Ok(());
                    for _ in 0..repeats {
                        out = if use_ref_scan {
                            // CPU-deployment hot path (NERSC_CR_SCAN=ref):
                            // the oracle lowering of the same graph,
                            // bit-identical outputs (EXPERIMENTS.md §Perf).
                            backend.transport_scan_ref(&mut state, &si)
                        } else {
                            backend.transport_scan(&mut state, &si)
                        };
                        if out.is_err() {
                            break;
                        }
                    }
                    let _ = reply.send(out.map(|()| state));
                }
                Request::ScoreRoi { edep, mask, reply } => {
                    let _ = reply.send(backend.score_roi(&edep, &mask));
                }
                Request::Spectrum {
                    edep,
                    mask,
                    e_range,
                    reply,
                } => {
                    let spec = backend.detector_spectrum(&edep, &mask, e_range.0, e_range.1);
                    let _ = reply.send(spec);
                }
                Request::Stats { reply } => {
                    let _ = reply.send((backend.name(), backend.stats()));
                }
                Request::Shutdown => break,
            }
        }
    }

    /// A new handle for a worker thread.
    pub fn handle(&self) -> ComputeHandle {
        ComputeHandle {
            tx: self.tx.clone(),
            manifest: self.manifest.clone(),
        }
    }

    /// The manifest the service was configured from.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }
}

impl Drop for ComputeService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl ComputeHandle {
    /// The manifest the service was configured from.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn roundtrip<T>(&self, build: impl FnOnce(mpsc::Sender<Result<T>>) -> Request) -> Result<T> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(build(reply))
            .map_err(|_| Error::Backend("compute service is down".into()))?;
        rx.recv()
            .map_err(|_| Error::Backend("compute service dropped the request".into()))?
    }

    /// One transport step (production path, or the oracle with `use_ref`).
    pub fn step(
        &self,
        state: ParticleState,
        si: &Arc<StaticInputs>,
        use_ref: bool,
    ) -> Result<ParticleState> {
        let si = Arc::clone(si);
        self.roundtrip(|reply| Request::Step {
            state,
            si,
            use_ref,
            reply,
        })
    }

    /// `repeats` fused scans (each `manifest.scan_steps` steps).
    pub fn scan(
        &self,
        state: ParticleState,
        si: &Arc<StaticInputs>,
        repeats: u32,
    ) -> Result<ParticleState> {
        let si = Arc::clone(si);
        self.roundtrip(|reply| Request::Scan {
            state,
            si,
            repeats,
            reply,
        })
    }

    /// Detector readout.
    pub fn score_roi(&self, edep: Vec<f32>, mask: Vec<f32>) -> Result<(f32, f32, f32)> {
        self.roundtrip(|reply| Request::ScoreRoi { edep, mask, reply })
    }

    /// Dose-volume histogram over `[e_min, e_max)`.
    pub fn detector_spectrum(
        &self,
        edep: Vec<f32>,
        mask: Vec<f32>,
        e_min: f32,
        e_max: f32,
    ) -> Result<Vec<f32>> {
        self.roundtrip(|reply| Request::Spectrum {
            edep,
            mask,
            e_range: (e_min, e_max),
            reply,
        })
    }

    /// Backend statistics snapshot, tagged with the backend name.
    pub fn stats(&self) -> Result<(&'static str, BackendStats)> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Stats { reply })
            .map_err(|_| Error::Backend("compute service is down".into()))?;
        rx.recv()
            .map_err(|_| Error::Backend("compute service dropped the request".into()))
    }
}

/// A process-wide shared compute service (examples/benches convenience):
/// started on first use with `artifacts/` from `NERSC_CR_ARTIFACTS` or the
/// workspace default.
pub fn shared() -> Result<ComputeHandle> {
    static SHARED: OnceLock<Mutex<Option<ComputeService>>> = OnceLock::new();
    let cell = SHARED.get_or_init(|| Mutex::new(None));
    let mut guard = cell.lock().expect("shared compute service poisoned");
    if guard.is_none() {
        let dir = std::env::var("NERSC_CR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        *guard = Some(ComputeService::start(Path::new(&dir))?);
    }
    Ok(guard.as_ref().unwrap().handle())
}
