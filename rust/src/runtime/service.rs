//! Compute service: a dedicated thread owning the (non-`Send`) [`Engine`],
//! serving transport/score requests to any number of worker threads through
//! cloneable [`ComputeHandle`]s.
//!
//! This mirrors the serving-system shape the paper's environment implies
//! (many MPI ranks sharing node-local accelerators): the DMTCP-analog user
//! processes run on their own threads and the request path into PJRT is a
//! channel hop, never a Python call.

use std::path::Path;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::error::{Error, Result};
use crate::runtime::engine::{Engine, EngineStats};
use crate::runtime::manifest::Manifest;
use crate::runtime::state::{ParticleState, StaticInputs};

enum Request {
    Step {
        state: ParticleState,
        si: Arc<StaticInputs>,
        use_ref: bool,
        reply: mpsc::Sender<Result<ParticleState>>,
    },
    Scan {
        state: ParticleState,
        si: Arc<StaticInputs>,
        /// Number of scan invocations (each advances `scan_steps` steps).
        repeats: u32,
        reply: mpsc::Sender<Result<ParticleState>>,
    },
    ScoreRoi {
        edep: Vec<f32>,
        mask: Vec<f32>,
        reply: mpsc::Sender<Result<(f32, f32, f32)>>,
    },
    Stats {
        reply: mpsc::Sender<EngineStats>,
    },
    Shutdown,
}

/// Owns the engine thread; dropping shuts it down.
pub struct ComputeService {
    tx: mpsc::Sender<Request>,
    manifest: Manifest,
    join: Option<JoinHandle<()>>,
}

/// Cheap, clonable, `Send` handle into the compute service.
#[derive(Clone)]
pub struct ComputeHandle {
    tx: mpsc::Sender<Request>,
    manifest: Manifest,
}

impl ComputeService {
    /// Spawn the engine thread and compile artifacts from `dir`.
    ///
    /// Compilation happens on the service thread; this call blocks until the
    /// engine is ready (or failed), so callers get load errors eagerly.
    pub fn start(dir: &Path) -> Result<Self> {
        // Manifest parsed on the caller thread too: cheap, and lets handles
        // answer shape questions without a channel hop.
        let manifest = Manifest::load(dir)?;
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let dir = dir.to_path_buf();
        let join = std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || {
                let engine = match Engine::load(&dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                Self::serve(engine, rx);
            })
            .expect("spawn pjrt-engine thread");
        ready_rx
            .recv()
            .map_err(|_| Error::Xla("engine thread died during load".into()))??;
        Ok(Self {
            tx,
            manifest,
            join: Some(join),
        })
    }

    fn serve(engine: Engine, rx: mpsc::Receiver<Request>) {
        // Hot-path selection: both artifacts lower from the same L2 graph
        // and produce bit-identical results (asserted by tests).
        let use_ref_scan = std::env::var("NERSC_CR_SCAN").as_deref() == Ok("ref");
        while let Ok(req) = rx.recv() {
            match req {
                Request::Step {
                    mut state,
                    si,
                    use_ref,
                    reply,
                } => {
                    let r = if use_ref {
                        engine.transport_step_ref(&mut state, &si)
                    } else {
                        engine.transport_step(&mut state, &si)
                    };
                    let _ = reply.send(r.map(|()| state));
                }
                Request::Scan {
                    mut state,
                    si,
                    repeats,
                    reply,
                } => {
                    let mut out = Ok(());
                    for _ in 0..repeats {
                        out = if use_ref_scan {
                            // CPU-deployment hot path (NERSC_CR_SCAN=ref):
                            // the pure-jnp lowering of the same L2 graph,
                            // bit-identical outputs, ~25% faster on the CPU
                            // PJRT plugin (see EXPERIMENTS.md §Perf).
                            engine.transport_scan_ref(&mut state, &si)
                        } else {
                            engine.transport_scan(&mut state, &si)
                        };
                        if out.is_err() {
                            break;
                        }
                    }
                    let _ = reply.send(out.map(|()| state));
                }
                Request::ScoreRoi { edep, mask, reply } => {
                    let _ = reply.send(engine.score_roi(&edep, &mask));
                }
                Request::Stats { reply } => {
                    let _ = reply.send(engine.stats());
                }
                Request::Shutdown => break,
            }
        }
    }

    /// A new handle for a worker thread.
    pub fn handle(&self) -> ComputeHandle {
        ComputeHandle {
            tx: self.tx.clone(),
            manifest: self.manifest.clone(),
        }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }
}

impl Drop for ComputeService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl ComputeHandle {
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn roundtrip<T>(
        &self,
        build: impl FnOnce(mpsc::Sender<Result<T>>) -> Request,
    ) -> Result<T> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(build(reply))
            .map_err(|_| Error::Xla("compute service is down".into()))?;
        rx.recv()
            .map_err(|_| Error::Xla("compute service dropped the request".into()))?
    }

    /// One transport step (Pallas artifact, or the jnp oracle with `use_ref`).
    pub fn step(
        &self,
        state: ParticleState,
        si: &Arc<StaticInputs>,
        use_ref: bool,
    ) -> Result<ParticleState> {
        let si = Arc::clone(si);
        self.roundtrip(|reply| Request::Step {
            state,
            si,
            use_ref,
            reply,
        })
    }

    /// `repeats` fused scans (each `manifest.scan_steps` steps).
    pub fn scan(
        &self,
        state: ParticleState,
        si: &Arc<StaticInputs>,
        repeats: u32,
    ) -> Result<ParticleState> {
        let si = Arc::clone(si);
        self.roundtrip(|reply| Request::Scan {
            state,
            si,
            repeats,
            reply,
        })
    }

    /// Detector readout.
    pub fn score_roi(&self, edep: Vec<f32>, mask: Vec<f32>) -> Result<(f32, f32, f32)> {
        self.roundtrip(|reply| Request::ScoreRoi { edep, mask, reply })
    }

    /// Engine statistics snapshot.
    pub fn stats(&self) -> Result<EngineStats> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Stats { reply })
            .map_err(|_| Error::Xla("compute service is down".into()))?;
        rx.recv()
            .map_err(|_| Error::Xla("compute service dropped the request".into()))
    }
}

/// A process-wide shared compute service (examples/benches convenience):
/// started on first use with `artifacts/` from `NERSC_CR_ARTIFACTS` or the
/// workspace default.
pub fn shared() -> Result<ComputeHandle> {
    static SHARED: once_cell::sync::OnceCell<Mutex<Option<ComputeService>>> =
        once_cell::sync::OnceCell::new();
    let cell = SHARED.get_or_init(|| Mutex::new(None));
    let mut guard = cell.lock().expect("shared compute service poisoned");
    if guard.is_none() {
        let dir = std::env::var("NERSC_CR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        *guard = Some(ComputeService::start(Path::new(&dir))?);
    }
    Ok(guard.as_ref().unwrap().handle())
}
