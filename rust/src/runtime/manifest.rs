//! Parser for `artifacts/manifest.txt` written by `python/compile/aot.py`.
//!
//! A deliberately tiny line-oriented `key value` format (no serde in the
//! offline closure): global shape constants plus one `artifact <name>
//! <sha256-12>` line per HLO module.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// Parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Particle batch size B used at AOT time.
    pub batch: usize,
    /// Voxel-grid edge length D (grid has D^3 cells).
    pub grid_d: usize,
    /// Number of material rows in the cross-section table.
    pub n_mat: usize,
    /// Steps fused per `transport_scan` call.
    pub scan_steps: usize,
    /// RNG draws consumed per particle per step (restart bookkeeping).
    pub rng_draws_per_step: u32,
    /// Detector-spectrum bin count (dose-volume histogram K).
    pub spectrum_bins: usize,
    /// artifact name -> content digest (12 hex chars).
    pub artifacts: BTreeMap<String, String>,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

impl Manifest {
    /// Load and parse `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::Manifest(format!("{}: {e}", path.display())))?;
        Self::parse(&text, dir)
    }

    /// The compiled-in default dimensions (mirrors `python/compile/model.py`:
    /// `BATCH/GRID_D/N_MAT/SCAN_STEPS` and the kernel constants). Used by
    /// the reference backend when no artifact manifest is on disk.
    pub fn default_reference(dir: &Path) -> Self {
        Self {
            batch: 4096,
            grid_d: 32,
            n_mat: 8,
            scan_steps: 8,
            rng_draws_per_step: 4,
            spectrum_bins: 128,
            artifacts: BTreeMap::new(),
            dir: dir.to_path_buf(),
        }
    }

    /// Load `<dir>/manifest.txt` if it exists, otherwise fall back to
    /// [`Self::default_reference`]. A manifest that exists but fails to
    /// parse is still an error (silent fallback would mask corruption),
    /// and the fallback itself is logged so a mistyped artifact dir is
    /// observable rather than quietly running at the default geometry.
    pub fn load_or_default(dir: &Path) -> Result<Self> {
        if dir.join("manifest.txt").exists() {
            Self::load(dir)
        } else {
            log::warn!(
                "no manifest.txt under {}; using compiled-in reference shapes \
                 (batch 4096, grid 32^3, scan 8)",
                dir.display()
            );
            Ok(Self::default_reference(dir))
        }
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut kv: BTreeMap<&str, &str> = BTreeMap::new();
        let mut artifacts = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let key = parts.next().unwrap();
            if key == "artifact" {
                let name = parts
                    .next()
                    .ok_or_else(|| Error::Manifest(format!("line {lineno}: artifact w/o name")))?;
                let digest = parts
                    .next()
                    .ok_or_else(|| Error::Manifest(format!("line {lineno}: artifact w/o digest")))?;
                artifacts.insert(name.to_string(), digest.to_string());
            } else {
                let val = parts
                    .next()
                    .ok_or_else(|| Error::Manifest(format!("line {lineno}: {key} w/o value")))?;
                kv.insert(key, val);
            }
        }
        let get = |k: &str| -> Result<usize> {
            kv.get(k)
                .ok_or_else(|| Error::Manifest(format!("missing key {k}")))?
                .parse()
                .map_err(|_| Error::Manifest(format!("bad value for {k}")))
        };
        let format = get("format")?;
        if format != 1 {
            return Err(Error::Manifest(format!("unsupported format {format}")));
        }
        let spectrum_bins = kv
            .get("spectrum_bins")
            .and_then(|v| v.parse().ok())
            .unwrap_or(128);
        Ok(Self {
            batch: get("batch")?,
            spectrum_bins,
            grid_d: get("grid_d")?,
            n_mat: get("n_mat")?,
            scan_steps: get("scan_steps")?,
            rng_draws_per_step: get("rng_draws_per_step")? as u32,
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    /// Total voxel count D^3.
    pub fn n_voxels(&self) -> usize {
        self.grid_d * self.grid_d * self.grid_d
    }

    /// Path of one artifact's HLO text.
    pub fn artifact_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// Names of all artifacts.
    pub fn artifact_names(&self) -> impl Iterator<Item = &str> {
        self.artifacts.keys().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "format 1\nbatch 4096\ngrid_d 32\nn_mat 8\nscan_steps 8\n\
                          rng_draws_per_step 4\nartifact transport_step abc123def456\n\
                          artifact score_roi 000111222333\n";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.batch, 4096);
        assert_eq!(m.grid_d, 32);
        assert_eq!(m.n_voxels(), 32 * 32 * 32);
        assert_eq!(m.scan_steps, 8);
        assert_eq!(m.rng_draws_per_step, 4);
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(
            m.artifact_path("score_roi"),
            PathBuf::from("/tmp/a/score_roi.hlo.txt")
        );
    }

    #[test]
    fn missing_key_rejected() {
        assert!(Manifest::parse("format 1\nbatch 8\n", Path::new(".")).is_err());
    }

    #[test]
    fn wrong_format_rejected() {
        let text = SAMPLE.replace("format 1", "format 9");
        assert!(Manifest::parse(&text, Path::new(".")).is_err());
    }

    #[test]
    fn load_or_default_falls_back_when_missing() {
        let m = Manifest::load_or_default(Path::new("/nonexistent-ncr-manifest")).unwrap();
        assert_eq!(m.batch, 4096);
        assert_eq!(m.grid_d, 32);
        assert_eq!(m.scan_steps, 8);
        assert_eq!(m.rng_draws_per_step, 4);
        assert_eq!(m.spectrum_bins, 128);
        assert!(m.artifacts.is_empty());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let text = format!("# header\n\n{SAMPLE}");
        assert!(Manifest::parse(&text, Path::new(".")).is_ok());
    }
}
