//! In-memory particle/scoring state and its two serializations:
//! XLA literals (for PJRT execution) and raw byte segments (for DMTCP-style
//! checkpoint images).
//!
//! The state layout mirrors the L2 convention in `python/compile/model.py`:
//! `pos f32[B,3], dcos f32[B,3], energy f32[B], weight f32[B], alive f32[B],
//! rng u32[B], edep f32[D^3]`. Because the RNG is counter-based and lives in
//! this state, serializing + restoring it resumes the Monte-Carlo stream
//! *bit-exactly* — the keystone of the C/R correctness tests.

use crate::error::{Error, Result};
use crate::util::bytes::{bytes_to_f32s, bytes_to_u32s, f32s_to_bytes, u32s_to_bytes};
use crate::util::rng::SplitMix64;

/// Per-run static inputs: geometry, cross-sections, world parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct StaticInputs {
    /// Flattened D^3 material-index grid.
    pub grid: Vec<i32>,
    /// Per-material rows `(s0, s1, f_abs, f_loss, g, pad)`, row-major [M,6].
    pub xs: Vec<f32>,
    /// `(voxel_size, 1/voxel_size, e_cut, max_step, D, pad, pad, pad)`.
    pub params: [f32; 8],
    /// Material count M.
    pub n_mat: usize,
    /// Grid edge length D.
    pub grid_d: usize,
}

impl StaticInputs {
    /// Validate shapes against a manifest's dims.
    pub fn validate(&self, grid_d: usize, n_mat: usize) -> Result<()> {
        let d3 = grid_d * grid_d * grid_d;
        if self.grid.len() != d3 {
            return Err(Error::Workload(format!(
                "grid len {} != D^3 {d3}",
                self.grid.len()
            )));
        }
        if self.xs.len() != n_mat * 6 {
            return Err(Error::Workload(format!(
                "xs len {} != M*6 {}",
                self.xs.len(),
                n_mat * 6
            )));
        }
        if self.params[4] as usize != grid_d {
            return Err(Error::Workload(format!(
                "params D {} != grid_d {grid_d}",
                self.params[4]
            )));
        }
        Ok(())
    }
}

/// The mutable simulation state (one "MPI rank"'s worth of particles).
#[derive(Debug, Clone, PartialEq)]
pub struct ParticleState {
    /// Positions, `[B,3]` row-major (world units).
    pub pos: Vec<f32>,
    /// Unit direction cosines, `[B,3]` row-major.
    pub dcos: Vec<f32>,
    /// Kinetic energy per particle, `[B]` (MeV).
    pub energy: Vec<f32>,
    /// Statistical weight per particle, `[B]`.
    pub weight: Vec<f32>,
    /// Liveness per particle, `[B]` (1.0 alive / 0.0 dead).
    pub alive: Vec<f32>,
    /// Counter-based RNG state per particle, `[B]`.
    pub rng: Vec<u32>,
    /// Accumulated energy-deposition scoring grid, `[D^3]` flattened.
    pub edep: Vec<f32>,
    /// Steps completed so far (restart bookkeeping + progress reporting).
    pub steps_done: u64,
}

impl ParticleState {
    /// Batch size B.
    pub fn batch(&self) -> usize {
        self.energy.len()
    }

    /// Check that the per-particle vectors agree with the batch size
    /// (`energy.len()`): `pos`/`dcos` are `[B,3]`, the rest `[B]`.
    /// Shared by segment restore and the compute backends.
    pub fn check_consistent(&self) -> Result<()> {
        let b = self.batch();
        if self.pos.len() != b * 3
            || self.dcos.len() != b * 3
            || self.weight.len() != b
            || self.alive.len() != b
            || self.rng.len() != b
        {
            return Err(Error::Workload(format!(
                "state vectors inconsistent: batch {b}, pos {}, dcos {}, weight {}, \
                 alive {}, rng {}",
                self.pos.len(),
                self.dcos.len(),
                self.weight.len(),
                self.alive.len(),
                self.rng.len()
            )));
        }
        Ok(())
    }

    /// Number of particles still alive.
    pub fn alive_count(&self) -> usize {
        self.alive.iter().filter(|&&a| a > 0.5).count()
    }

    /// Total deposited energy (sum of the scoring grid).
    pub fn total_edep(&self) -> f64 {
        self.edep.iter().map(|&x| x as f64).sum()
    }

    /// Total in-flight energy of live particles.
    pub fn live_energy(&self) -> f64 {
        self.energy
            .iter()
            .zip(&self.alive)
            .filter(|(_, &a)| a > 0.5)
            .map(|(&e, _)| e as f64)
            .sum()
    }

    /// Approximate resident size in bytes (LDMS memory accounting).
    pub fn size_bytes(&self) -> usize {
        4 * (self.pos.len()
            + self.dcos.len()
            + self.energy.len()
            + self.weight.len()
            + self.alive.len()
            + self.rng.len()
            + self.edep.len())
            + 8
    }

    /// Sample a fresh batch from a source: all particles start at `origin`
    /// with isotropic directions and energies drawn by `sample_energy`.
    pub fn from_source(
        batch: usize,
        n_voxels: usize,
        origin: [f32; 3],
        seed: u64,
        mut sample_energy: impl FnMut(&mut SplitMix64) -> f32,
    ) -> Self {
        let mut r = SplitMix64::new(seed);
        let mut pos = Vec::with_capacity(batch * 3);
        let mut dcos = Vec::with_capacity(batch * 3);
        let mut energy = Vec::with_capacity(batch);
        for _ in 0..batch {
            pos.extend_from_slice(&origin);
            // Isotropic direction via uniform cos(theta), phi.
            let cz = r.gen_f64(-1.0, 1.0);
            let sz = (1.0 - cz * cz).max(0.0).sqrt();
            let phi = r.gen_f64(0.0, std::f64::consts::TAU);
            dcos.push((sz * phi.cos()) as f32);
            dcos.push((sz * phi.sin()) as f32);
            dcos.push(cz as f32);
            energy.push(sample_energy(&mut r));
        }
        // Distinct RNG counter lanes per particle: wide stride so 2^32/B
        // steps never collide between lanes.
        let stride = (u32::MAX / batch.max(1) as u32).max(1);
        Self {
            pos,
            dcos,
            energy,
            weight: vec![1.0; batch],
            alive: vec![1.0; batch],
            rng: (0..batch as u32).map(|i| i.wrapping_mul(stride)).collect(),
            edep: vec![0.0; n_voxels],
            steps_done: 0,
        }
    }

    /// Serialize to named byte segments (the checkpoint "memory regions").
    ///
    /// Each segment is `(name, bytes)`; the DMTCP image layer wraps them
    /// with headers, CRCs and optional gzip.
    pub fn to_segments(&self) -> Vec<(String, Vec<u8>)> {
        let mut steps = Vec::with_capacity(8);
        steps.extend_from_slice(&self.steps_done.to_le_bytes());
        vec![
            ("pos".into(), f32s_to_bytes(&self.pos)),
            ("dcos".into(), f32s_to_bytes(&self.dcos)),
            ("energy".into(), f32s_to_bytes(&self.energy)),
            ("weight".into(), f32s_to_bytes(&self.weight)),
            ("alive".into(), f32s_to_bytes(&self.alive)),
            ("rng".into(), u32s_to_bytes(&self.rng)),
            ("edep".into(), f32s_to_bytes(&self.edep)),
            ("steps_done".into(), steps),
        ]
    }

    /// Reconstruct from segments produced by [`Self::to_segments`].
    pub fn from_segments(segments: &[(String, Vec<u8>)]) -> Result<Self> {
        let find = |name: &str| -> Result<&Vec<u8>> {
            segments
                .iter()
                .find(|(n, _)| n == name)
                .map(|(_, b)| b)
                .ok_or_else(|| Error::Image(format!("missing segment {name:?}")))
        };
        let steps_b = find("steps_done")?;
        if steps_b.len() != 8 {
            return Err(Error::Image("steps_done segment malformed".into()));
        }
        let state = Self {
            pos: bytes_to_f32s(find("pos")?)?,
            dcos: bytes_to_f32s(find("dcos")?)?,
            energy: bytes_to_f32s(find("energy")?)?,
            weight: bytes_to_f32s(find("weight")?)?,
            alive: bytes_to_f32s(find("alive")?)?,
            rng: bytes_to_u32s(find("rng")?)?,
            edep: bytes_to_f32s(find("edep")?)?,
            steps_done: u64::from_le_bytes(steps_b.as_slice().try_into().unwrap()),
        };
        state
            .check_consistent()
            .map_err(|_| Error::Image("inconsistent segment lengths".into()))?;
        Ok(state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_state() -> ParticleState {
        ParticleState::from_source(64, 4 * 4 * 4, [2.0, 2.0, 2.0], 42, |r| {
            1.0 + r.next_f32() * 5.0
        })
    }

    #[test]
    fn from_source_shapes_and_units() {
        let s = sample_state();
        assert_eq!(s.batch(), 64);
        assert_eq!(s.pos.len(), 64 * 3);
        assert_eq!(s.alive_count(), 64);
        assert_eq!(s.total_edep(), 0.0);
        assert_eq!(s.steps_done, 0);
        // directions are unit vectors
        for i in 0..64 {
            let d = &s.dcos[i * 3..i * 3 + 3];
            let n = (d[0] * d[0] + d[1] * d[1] + d[2] * d[2]).sqrt();
            assert!((n - 1.0).abs() < 1e-5, "row {i}: |d|={n}");
        }
    }

    #[test]
    fn from_source_deterministic() {
        let a = sample_state();
        let b = sample_state();
        assert_eq!(a, b);
    }

    #[test]
    fn rng_lanes_distinct() {
        let s = sample_state();
        let mut lanes = s.rng.clone();
        lanes.sort_unstable();
        lanes.dedup();
        assert_eq!(lanes.len(), s.batch());
    }

    #[test]
    fn segments_roundtrip_bitwise() {
        let mut s = sample_state();
        s.steps_done = 17;
        s.edep[5] = 1.25;
        let segs = s.to_segments();
        let back = ParticleState::from_segments(&segs).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn segment_corruption_detected() {
        let s = sample_state();
        let mut segs = s.to_segments();
        segs.retain(|(n, _)| n != "rng");
        assert!(ParticleState::from_segments(&segs).is_err());
        let mut segs2 = s.to_segments();
        segs2.iter_mut().find(|(n, _)| n == "pos").unwrap().1.pop();
        assert!(ParticleState::from_segments(&segs2).is_err());
    }

    #[test]
    fn static_inputs_validation() {
        let ok = StaticInputs {
            grid: vec![0; 8],
            xs: vec![0.0; 12],
            params: [1.0, 1.0, 0.01, 2.0, 2.0, 0.0, 0.0, 0.0],
            n_mat: 2,
            grid_d: 2,
        };
        assert!(ok.validate(2, 2).is_ok());
        assert!(ok.validate(3, 2).is_err());
        assert!(ok.validate(2, 3).is_err());
    }
}
