//! The PJRT execution engine (thread-local, `pjrt` feature).
//!
//! Loads HLO-text artifacts, compiles each once on the PJRT CPU client, and
//! executes them with in-memory state. `xla::PjRtClient` is `Rc`-backed and
//! therefore **not Send**: an [`Engine`] lives on one thread. Multi-threaded
//! callers go through [`super::service::ComputeService`], which owns a
//! backend on a dedicated thread and serves cloneable handles.
//!
//! This module only builds with `--features pjrt`. The offline build links
//! the `vendor/xla` stub (every runtime call errors out); swap in the real
//! xla-rs bindings to execute artifacts — the call sites are identical.
//! Select at runtime with `NERSC_CR_BACKEND=pjrt` (see
//! [`super::backend::load_backend`]).

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::runtime::backend::{BackendStats, ComputeBackend};
use crate::runtime::manifest::Manifest;
use crate::runtime::state::{ParticleState, StaticInputs};

/// Artifact name: one Pallas-kernel transport step.
pub const STEP: &str = "transport_step";
/// Artifact name: one pure-jnp oracle transport step.
pub const STEP_REF: &str = "transport_step_ref";
/// Artifact name: the fused Pallas-kernel scan.
pub const SCAN: &str = "transport_scan";
/// Artifact name: the fused pure-jnp oracle scan.
pub const SCAN_REF: &str = "transport_scan_ref";
/// Artifact name: detector ROI readout.
pub const SCORE_ROI: &str = "score_roi";
/// Artifact name: dose-volume histogram readout.
pub const SPECTRUM: &str = "detector_spectrum";

/// A PJRT CPU engine with a compiled-executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    stats: std::cell::RefCell<BackendStats>,
}

impl Engine {
    /// Load the manifest and compile the given artifacts (all if `None`).
    pub fn load_subset(dir: &Path, names: Option<&[&str]>) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut engine = Self {
            client,
            manifest,
            exes: HashMap::new(),
            stats: Default::default(),
        };
        let all: Vec<String> = engine.manifest.artifact_names().map(String::from).collect();
        let wanted: Vec<String> = match names {
            Some(ns) => ns.iter().map(|s| s.to_string()).collect(),
            None => all,
        };
        for name in wanted {
            engine.compile_artifact(&name)?;
        }
        Ok(engine)
    }

    /// Load the manifest and compile every artifact.
    pub fn load(dir: &Path) -> Result<Self> {
        Self::load_subset(dir, None)
    }

    /// Compile (or re-compile) one artifact from its HLO text.
    pub fn compile_artifact(&mut self, name: &str) -> Result<()> {
        if !self.manifest.artifacts.contains_key(name) {
            return Err(Error::Manifest(format!("unknown artifact {name:?}")));
        }
        let path = self.manifest.artifact_path(name);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Manifest("non-utf8 artifact path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let dt = t0.elapsed().as_secs_f64();
        let mut st = self.stats.borrow_mut();
        st.compiles += 1;
        st.compile_secs += dt;
        log::debug!("compiled {name} in {dt:.3}s");
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// The PJRT platform backing this engine.
    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    fn exe(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.exes
            .get(name)
            .ok_or_else(|| Error::Backend(format!("artifact {name:?} not compiled")))
    }

    /// Build the 10 input literals for a transport artifact.
    fn transport_inputs(
        &self,
        state: &ParticleState,
        si: &StaticInputs,
    ) -> Result<Vec<xla::Literal>> {
        let b = state.batch() as i64;
        let m = si.n_mat as i64;
        Ok(vec![
            xla::Literal::vec1(&state.pos).reshape(&[b, 3])?,
            xla::Literal::vec1(&state.dcos).reshape(&[b, 3])?,
            xla::Literal::vec1(&state.energy),
            xla::Literal::vec1(&state.weight),
            xla::Literal::vec1(&state.alive),
            xla::Literal::vec1(&state.rng),
            xla::Literal::vec1(&state.edep),
            xla::Literal::vec1(&si.grid),
            xla::Literal::vec1(&si.xs).reshape(&[m, 6])?,
            xla::Literal::vec1(&si.params),
        ])
    }

    /// Unpack the 7-tuple output back into `state`.
    fn unpack_transport(&self, result: xla::Literal, state: &mut ParticleState) -> Result<()> {
        let parts = result.to_tuple()?;
        if parts.len() != 7 {
            return Err(Error::Backend(format!(
                "transport output arity {} != 7",
                parts.len()
            )));
        }
        let mut it = parts.into_iter();
        state.pos = it.next().unwrap().to_vec::<f32>()?;
        state.dcos = it.next().unwrap().to_vec::<f32>()?;
        state.energy = it.next().unwrap().to_vec::<f32>()?;
        state.weight = it.next().unwrap().to_vec::<f32>()?;
        state.alive = it.next().unwrap().to_vec::<f32>()?;
        state.rng = it.next().unwrap().to_vec::<u32>()?;
        state.edep = it.next().unwrap().to_vec::<f32>()?;
        Ok(())
    }

    fn run_transport(
        &self,
        artifact: &str,
        steps: u64,
        state: &mut ParticleState,
        si: &StaticInputs,
    ) -> Result<()> {
        if state.batch() != self.manifest.batch {
            return Err(Error::Workload(format!(
                "state batch {} != artifact batch {}",
                state.batch(),
                self.manifest.batch
            )));
        }
        si.validate(self.manifest.grid_d, self.manifest.n_mat)?;
        let inputs = self.transport_inputs(state, si)?;
        let t0 = Instant::now();
        let bufs = self.exe(artifact)?.execute::<xla::Literal>(&inputs)?;
        let out = bufs[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: one tuple literal out.
        self.unpack_transport(out, state)?;
        state.steps_done += steps;
        let mut st = self.stats.borrow_mut();
        st.executions += 1;
        st.execute_secs += t0.elapsed().as_secs_f64();
        st.steps += steps;
        Ok(())
    }
}

impl ComputeBackend for Engine {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Advance one transport step (Pallas-kernel artifact).
    fn transport_step(&self, state: &mut ParticleState, si: &StaticInputs) -> Result<()> {
        self.run_transport(STEP, 1, state, si)
    }

    /// Advance one transport step through the pure-jnp oracle artifact
    /// (A/B checking against the Pallas path from Rust).
    fn transport_step_ref(&self, state: &mut ParticleState, si: &StaticInputs) -> Result<()> {
        self.run_transport(STEP_REF, 1, state, si)
    }

    /// Advance `manifest.scan_steps` fused steps (the hot path: one PJRT
    /// round-trip per scan).
    fn transport_scan(&self, state: &mut ParticleState, si: &StaticInputs) -> Result<()> {
        self.run_transport(SCAN, self.manifest.scan_steps as u64, state, si)
    }

    /// Advance `manifest.scan_steps` fused steps through the pure-jnp
    /// oracle lowering (identical numerics to the Pallas path — asserted
    /// by tests — but a different HLO loop structure; used for A/B perf
    /// comparisons and as the CPU-deployment hot path when
    /// `NERSC_CR_SCAN=ref`).
    fn transport_scan_ref(&self, state: &mut ParticleState, si: &StaticInputs) -> Result<()> {
        self.run_transport(SCAN_REF, self.manifest.scan_steps as u64, state, si)
    }

    /// Detector readout: `(roi_edep, total_edep, hit_voxels)`.
    fn score_roi(&self, edep: &[f32], roi_mask: &[f32]) -> Result<(f32, f32, f32)> {
        let n = self.manifest.n_voxels();
        if edep.len() != n || roi_mask.len() != n {
            return Err(Error::Workload(format!(
                "score_roi expects {n}-voxel grids, got {} / {}",
                edep.len(),
                roi_mask.len()
            )));
        }
        let inputs = vec![xla::Literal::vec1(edep), xla::Literal::vec1(roi_mask)];
        let t0 = Instant::now();
        let bufs = self.exe(SCORE_ROI)?.execute::<xla::Literal>(&inputs)?;
        let out = bufs[0][0].to_literal_sync()?;
        let parts = out.to_tuple()?;
        if parts.len() != 3 {
            return Err(Error::Backend(format!("score_roi arity {} != 3", parts.len())));
        }
        let mut st = self.stats.borrow_mut();
        st.executions += 1;
        st.execute_secs += t0.elapsed().as_secs_f64();
        drop(st);
        let vals: Vec<f32> = parts
            .iter()
            .map(|l| l.to_vec::<f32>().map(|v| v[0]))
            .collect::<std::result::Result<_, _>>()?;
        Ok((vals[0], vals[1], vals[2]))
    }

    /// Dose-volume histogram of the scoring grid inside the ROI. Runs the
    /// Pallas spectrum kernel's artifact.
    fn detector_spectrum(
        &self,
        edep: &[f32],
        roi_mask: &[f32],
        e_min: f32,
        e_max: f32,
    ) -> Result<Vec<f32>> {
        let n = self.manifest.n_voxels();
        if edep.len() != n || roi_mask.len() != n {
            return Err(Error::Workload(format!(
                "detector_spectrum expects {n}-voxel grids, got {} / {}",
                edep.len(),
                roi_mask.len()
            )));
        }
        let vox: Vec<i32> = (0..n as i32).collect();
        let params = [e_min, e_max, 0.0, 0.0];
        let inputs = vec![
            xla::Literal::vec1(edep),
            xla::Literal::vec1(&vox),
            xla::Literal::vec1(roi_mask),
            xla::Literal::vec1(&params),
        ];
        let t0 = Instant::now();
        let bufs = self.exe(SPECTRUM)?.execute::<xla::Literal>(&inputs)?;
        let out = bufs[0][0].to_literal_sync()?;
        let spectrum = out.to_tuple1()?.to_vec::<f32>()?;
        let mut st = self.stats.borrow_mut();
        st.executions += 1;
        st.execute_secs += t0.elapsed().as_secs_f64();
        drop(st);
        if spectrum.len() != self.manifest.spectrum_bins {
            return Err(Error::Backend(format!(
                "spectrum arity {} != manifest bins {}",
                spectrum.len(),
                self.manifest.spectrum_bins
            )));
        }
        Ok(spectrum)
    }

    fn stats(&self) -> BackendStats {
        self.stats.borrow().clone()
    }
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("platform", &self.client.platform_name())
            .field("artifacts", &self.exes.keys().collect::<Vec<_>>())
            .finish()
    }
}
