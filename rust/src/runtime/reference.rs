//! The pure-Rust reference compute backend.
//!
//! A scalar f32 port of the kernel semantics specified by
//! `python/compile/kernels/ref.py` (the independent oracle the Pallas
//! kernel is verified against) plus the L2 scoring scatter-add from
//! `python/compile/model.py`. Operation order and precision deliberately
//! mirror the JAX lowering — all math is `f32`, the RNG is the same
//! lowbias32 counter hash — so results agree with the artifact engine to
//! float tolerance and with themselves bit-exactly (the C/R keystone).
//!
//! This backend needs no artifacts, no Python and no XLA runtime: it is
//! what `cargo test` and the default service run everywhere. Golden-value
//! tests against the Python suite's expectations live in
//! `rust/tests/reference_backend.rs`.

use std::cell::RefCell;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::runtime::backend::{BackendStats, ComputeBackend};
use crate::runtime::manifest::Manifest;
use crate::runtime::state::{ParticleState, StaticInputs};

/// RNG draws consumed per particle per step. Must stay in lock-step with
/// `RNG_DRAWS_PER_STEP` in `python/compile/kernels/transport.py`: restart
/// correctness depends on it.
pub const RNG_DRAWS_PER_STEP: u32 = 4;

/// 2π at f32 precision (`jnp.float32(TWO_PI)` in the kernels rounds to
/// the same nearest f32).
const TWO_PI: f32 = std::f32::consts::TAU;

/// lowbias32 integer hash (Chris Wellons); uint32 wrap-around semantics.
/// Must match `hash_u32` in `python/compile/kernels/ref.py` bit-for-bit.
#[inline]
pub fn hash_u32(mut x: u32) -> u32 {
    x ^= x >> 16;
    x = x.wrapping_mul(0x7FEB_352D);
    x ^= x >> 15;
    x = x.wrapping_mul(0x846C_A68B);
    x ^= x >> 16;
    x
}

/// Map a u32 to f32 in `[0, 1)` using the top 24 bits (matches `u01`).
#[inline]
pub fn u01(bits: u32) -> f32 {
    (bits >> 8) as f32 * (1.0 / (1 << 24) as f32)
}

#[inline]
fn rsqrt(x: f32) -> f32 {
    1.0 / x.sqrt()
}

/// The reference backend: manifest-shaped, artifact-free.
pub struct ReferenceBackend {
    manifest: Manifest,
    stats: RefCell<BackendStats>,
}

impl ReferenceBackend {
    /// Build from a manifest (shapes, scan length, RNG stride).
    pub fn new(manifest: Manifest) -> Self {
        Self {
            manifest,
            stats: RefCell::new(BackendStats::default()),
        }
    }

    /// Validate manifest compatibility and state/static-input shape
    /// consistency before a kernel run (mirrors the PJRT engine's checks,
    /// so the backends stay interchangeable).
    fn validate(&self, state: &ParticleState, si: &StaticInputs) -> Result<()> {
        if self.manifest.rng_draws_per_step != RNG_DRAWS_PER_STEP {
            return Err(Error::Manifest(format!(
                "manifest declares {} rng draws/step but this kernel consumes {}; \
                 the Monte-Carlo streams would desynchronize",
                self.manifest.rng_draws_per_step, RNG_DRAWS_PER_STEP
            )));
        }
        if state.batch() != self.manifest.batch {
            return Err(Error::Workload(format!(
                "state batch {} != manifest batch {}",
                state.batch(),
                self.manifest.batch
            )));
        }
        si.validate(self.manifest.grid_d, self.manifest.n_mat)?;
        state.check_consistent()?;
        if state.edep.len() != si.grid.len() {
            return Err(Error::Workload(format!(
                "scoring grid {} voxels != material grid {} voxels",
                state.edep.len(),
                si.grid.len()
            )));
        }
        Ok(())
    }

    /// One transport step over every particle, scatter-adding deposits
    /// into `state.edep`. The body is `ref.py` line for line.
    fn step_once(state: &mut ParticleState, si: &StaticInputs) {
        let d = si.params[4] as i32;
        let inv_vox = si.params[1];
        let world = si.params[0] * si.params[4];
        let e_cut = si.params[2];
        let max_step = si.params[3];
        let n_mat = si.n_mat as i32;

        let voxel = |x: f32| -> i32 { ((x * inv_vox) as i32).clamp(0, d - 1) };
        let flatten = |p: &[f32; 3]| -> usize {
            ((voxel(p[0]) * d + voxel(p[1])) * d + voxel(p[2])) as usize
        };

        for i in 0..state.batch() {
            let alive_b = state.alive[i] > 0.5;
            let counter = state.rng[i];
            // RNG counters advance whether the particle is alive or not
            // (the lanes stay in lock-step, exactly as in the kernel).
            state.rng[i] = counter.wrapping_add(RNG_DRAWS_PER_STEP);
            if !alive_b {
                continue; // dead particles are frozen; deposits are zero
            }
            let pos = [state.pos[3 * i], state.pos[3 * i + 1], state.pos[3 * i + 2]];
            let dir = [state.dcos[3 * i], state.dcos[3 * i + 1], state.dcos[3 * i + 2]];
            let energy = state.energy[i];

            // --- current voxel & material --------------------------------
            let mat = si.grid[flatten(&pos)].clamp(0, n_mat - 1) as usize;
            let row = &si.xs[mat * 6..mat * 6 + 6];
            let (s0, s1, f_abs, f_loss, g) = (row[0], row[1], row[2], row[3], row[4]);

            // --- free path -----------------------------------------------
            let sigma = s0 + s1 * rsqrt(energy.max(1e-6));
            let u1 = u01(hash_u32(counter.wrapping_add(1)));
            let path = -(u1 + 1e-7).ln() / sigma.max(1e-6);
            let collided = path <= max_step;
            let step_len = path.min(max_step);

            // --- advance -------------------------------------------------
            let npos = [
                pos[0] + dir[0] * step_len,
                pos[1] + dir[1] * step_len,
                pos[2] + dir[2] * step_len,
            ];
            let inside = npos.iter().all(|&x| (0.0..world).contains(&x));

            // --- interaction ---------------------------------------------
            let u2 = u01(hash_u32(counter.wrapping_add(2)));
            let absorbed = collided && inside && u2 < f_abs;
            let scattered = collided && inside && !absorbed;

            let dep_collision = if absorbed {
                energy
            } else if scattered {
                energy * f_loss
            } else {
                0.0
            };
            let e_after = if absorbed {
                0.0
            } else if scattered {
                energy * (1.0 - f_loss)
            } else {
                energy
            };

            // --- energy cutoff: deposit the remainder locally -------------
            let cut = inside && !absorbed && e_after < e_cut;
            let deposit = if inside {
                dep_collision + if cut { e_after } else { 0.0 }
            } else {
                0.0
            };
            let e_new = if absorbed || cut { 0.0 } else { e_after };
            let alive_new = if inside && !absorbed && !cut { 1.0 } else { 0.0 };

            // --- scatter direction (forward-peaked iso mix) ---------------
            let u3 = u01(hash_u32(counter.wrapping_add(3)));
            let u4 = u01(hash_u32(counter.wrapping_add(4)));
            let cz = 2.0 * u3 - 1.0;
            let sz = (1.0 - cz * cz).max(0.0).sqrt();
            let phi = TWO_PI * u4;
            let iso = [sz * phi.cos(), sz * phi.sin(), cz];
            let mixed = [
                g * dir[0] + (1.0 - g) * iso[0],
                g * dir[1] + (1.0 - g) * iso[1],
                g * dir[2] + (1.0 - g) * iso[2],
            ];
            let dot = mixed[0] * mixed[0] + mixed[1] * mixed[1] + mixed[2] * mixed[2];
            let norm = rsqrt(dot.max(1e-12));
            let new_dir = if scattered {
                [mixed[0] * norm, mixed[1] * norm, mixed[2] * norm]
            } else {
                dir
            };

            // --- write back + scoring scatter-add -------------------------
            state.pos[3 * i..3 * i + 3].copy_from_slice(&npos);
            state.dcos[3 * i..3 * i + 3].copy_from_slice(&new_dir);
            state.energy[i] = e_new;
            state.alive[i] = alive_new;
            let out_flat = if inside { flatten(&npos) } else { 0 };
            state.edep[out_flat] += deposit * state.weight[i];
        }
    }

    fn run(&self, steps: u64, state: &mut ParticleState, si: &StaticInputs) -> Result<()> {
        self.validate(state, si)?;
        let t0 = Instant::now();
        for _ in 0..steps {
            Self::step_once(state, si);
        }
        state.steps_done += steps;
        let mut st = self.stats.borrow_mut();
        st.executions += 1;
        st.execute_secs += t0.elapsed().as_secs_f64();
        st.steps += steps;
        Ok(())
    }
}

impl ComputeBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn transport_step(&self, state: &mut ParticleState, si: &StaticInputs) -> Result<()> {
        self.run(1, state, si)
    }

    fn transport_scan(&self, state: &mut ParticleState, si: &StaticInputs) -> Result<()> {
        self.run(self.manifest.scan_steps as u64, state, si)
    }

    fn score_roi(&self, edep: &[f32], roi_mask: &[f32]) -> Result<(f32, f32, f32)> {
        let n = self.manifest.n_voxels();
        if edep.len() != n || roi_mask.len() != n {
            return Err(Error::Workload(format!(
                "score_roi expects {n}-voxel grids, got {} / {}",
                edep.len(),
                roi_mask.len()
            )));
        }
        let t0 = Instant::now();
        let mut roi = 0.0f64;
        let mut total = 0.0f64;
        let mut hits = 0u64;
        for (&e, &m) in edep.iter().zip(roi_mask) {
            total += e as f64;
            roi += (e * m) as f64;
            if e > 0.0 {
                hits += 1;
            }
        }
        let mut st = self.stats.borrow_mut();
        st.executions += 1;
        st.execute_secs += t0.elapsed().as_secs_f64();
        Ok((roi as f32, total as f32, hits as f32))
    }

    fn detector_spectrum(
        &self,
        edep: &[f32],
        roi_mask: &[f32],
        e_min: f32,
        e_max: f32,
    ) -> Result<Vec<f32>> {
        let n = self.manifest.n_voxels();
        if edep.len() != n || roi_mask.len() != n {
            return Err(Error::Workload(format!(
                "detector_spectrum expects {n}-voxel grids, got {} / {}",
                edep.len(),
                roi_mask.len()
            )));
        }
        let k = self.manifest.spectrum_bins;
        if k == 0 {
            return Err(Error::Manifest("spectrum_bins must be >= 1".into()));
        }
        let width = ((e_max - e_min) / k as f32).max(1e-9);
        let t0 = Instant::now();
        let mut spectrum = vec![0.0f32; k];
        for (&e, &m) in edep.iter().zip(roi_mask) {
            if m > 0.5 && e > 0.0 {
                let idx = (((e - e_min) / width) as i32).clamp(0, k as i32 - 1) as usize;
                spectrum[idx] += 1.0;
            }
        }
        let mut st = self.stats.borrow_mut();
        st.executions += 1;
        st.execute_secs += t0.elapsed().as_secs_f64();
        Ok(spectrum)
    }

    fn stats(&self) -> BackendStats {
        self.stats.borrow().clone()
    }
}

impl std::fmt::Debug for ReferenceBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ReferenceBackend")
            .field("batch", &self.manifest.batch)
            .field("grid_d", &self.manifest.grid_d)
            .field("scan_steps", &self.manifest.scan_steps)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_matches_lowbias32() {
        // Independent re-derivation, as in python/tests/test_kernel.py.
        fn low(mut x: u64) -> u32 {
            x &= 0xFFFF_FFFF;
            x ^= x >> 16;
            x = (x * 0x7FEB_352D) & 0xFFFF_FFFF;
            x ^= x >> 15;
            x = (x * 0x846C_A68B) & 0xFFFF_FFFF;
            x ^= x >> 16;
            x as u32
        }
        for v in [0u32, 1, 2, 0xDEAD_BEEF, 12345, u32::MAX] {
            assert_eq!(hash_u32(v), low(v as u64), "hash_u32({v:#x})");
        }
    }

    #[test]
    fn u01_in_unit_interval() {
        for bits in [0u32, 1, 255, 256, 0x8000_0000, u32::MAX] {
            let u = u01(bits);
            assert!((0.0..1.0).contains(&u), "u01({bits:#x}) = {u}");
        }
        assert_eq!(u01(0), 0.0);
    }
}
