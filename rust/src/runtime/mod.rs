//! PJRT runtime: load AOT artifacts, compile once, execute from the hot path.
//!
//! This is the only boundary between the Rust coordinator and the JAX/Pallas
//! compute stack. `make artifacts` (build time, Python) lowers the L2 model
//! to HLO *text* in `artifacts/`; at startup [`Engine::load`] parses the
//! manifest, compiles every module on the PJRT CPU client, and the request
//! path then only calls [`Engine::transport_scan`] / [`Engine::transport_step`]
//! with in-memory state — no Python anywhere.

pub mod engine;
pub mod manifest;
pub mod service;
pub mod state;

pub use engine::Engine;
pub use manifest::Manifest;
pub use service::{ComputeHandle, ComputeService};
pub use state::{ParticleState, StaticInputs};
