//! The compute runtime: pluggable transport/scoring backends behind the
//! [`ComputeBackend`] trait, served to worker threads by [`ComputeService`].
//!
//! Two backends implement the trait:
//!
//! * [`reference::ReferenceBackend`] (default) — a pure-Rust port of the
//!   kernel semantics in `python/compile/kernels/ref.py`. No artifacts, no
//!   Python, no XLA; bit-reproducible; what tests and offline deployments
//!   run.
//! * [`engine::Engine`] (`--features pjrt`, `NERSC_CR_BACKEND=pjrt`) — the
//!   PJRT bridge: `make artifacts` (build time, Python) lowers the L2
//!   model to HLO *text* in `artifacts/`; at startup the engine parses the
//!   manifest and compiles every module on the PJRT CPU client. The
//!   request path then only moves in-memory state — no Python anywhere.
//!
//! Both execute the same logical kernels; the integration suite asserts
//! they agree (`rust/tests/integration_runtime.rs`,
//! `rust/tests/reference_backend.rs`). See `DESIGN.md` §Backends.

pub mod backend;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod manifest;
pub mod reference;
pub mod service;
pub mod state;

pub use backend::{load_backend, load_backend_with, BackendKind, BackendStats, ComputeBackend};
#[cfg(feature = "pjrt")]
pub use engine::Engine;
pub use manifest::Manifest;
pub use reference::ReferenceBackend;
pub use service::{ComputeHandle, ComputeService};
pub use state::{ParticleState, StaticInputs};
