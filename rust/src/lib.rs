//! # nersc_cr — checkpoint-restart for HPC with a DMTCP-style coordinator
//!
//! A full-system reproduction of *"Optimizing Checkpoint-Restart Mechanisms
//! for HPC with DMTCP in Containers at NERSC"* (Arndt, Blaschke, Gerhardt,
//! Timalsina, Tyler — LBNL, 2024) as a three-layer Rust + JAX/Pallas stack.
//!
//! The crate contains the paper's contribution — the C/R job-management
//! layer ([`cr`]), entered through the session-first
//! [`cr::session::CrSession`] builder over the workload-generic
//! [`cr::app::CrApp`] trait and [`cr::substrate::Substrate`] execution
//! environments — plus every substrate it depends on, built from scratch:
//!
//! * [`campaign`] — fleet-scale orchestration (L4) over sessions: a
//!   bounded concurrent executor, seeded failure injection, Young/Daly
//!   checkpoint-interval auto-tuning, aggregated campaign reports.
//! * [`dmtcp`] — a DMTCP-analog: central coordinator over real TCP sockets,
//!   per-process checkpoint threads, barrier protocol, gzip'd+CRC'd
//!   checkpoint images, PID/FD virtualization, plugin event hooks.
//! * [`slurm`] — a discrete-event batch-scheduler simulator: nodes,
//!   partitions, FIFO+backfill, preemption, pre-timelimit signals, requeue.
//! * [`container`] — shifter and podman-hpc runtime models: Containerfile
//!   builds, an image store/registry, squashfile migration, volume mounts.
//! * [`fsmodel`] — filesystem startup-performance models (the Fig 2
//!   substrate: HOME/SCRATCH/common-software/CVMFS vs container caches).
//! * [`workload`] — the Geant4-analog particle-transport application layer
//!   (versions, physics lists, sources, detectors) whose compute runs
//!   behind the pluggable [`runtime::ComputeBackend`] boundary.
//! * [`runtime`] — the compute runtime: a pure-Rust reference backend (the
//!   default — ports the kernel semantics of `python/compile/kernels/`)
//!   and, behind the `pjrt` feature, the PJRT/XLA engine that executes the
//!   AOT-lowered `artifacts/*.hlo.txt`. Python never runs at request time.
//! * [`metrics`] — an LDMS-analog resource sampler (the Fig 4 substrate).
//! * [`trace`] — structured spans across every layer above: the bounded
//!   global sink, the flight recorder that explains failed rounds, and
//!   the Chrome-trace exporter (DESIGN §14).
//! * [`simclock`] — the discrete-event simulation core.
//!
//! See `DESIGN.md` for the architecture and the experiment index mapping
//! every figure/table of the paper to modules and bench targets, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

#[deny(missing_docs)]
pub mod campaign;
pub mod cli;
pub mod container;
#[deny(missing_docs)]
pub mod cr;
#[deny(missing_docs)]
pub mod dmtcp;
pub mod error;
pub mod fsmodel;
pub mod logging;
pub mod metrics;
pub mod report;
#[deny(missing_docs)]
pub mod runtime;
pub mod simclock;
pub mod slurm;
#[deny(missing_docs)]
pub mod trace;
pub mod util;
pub mod workload;

pub use error::{Error, Result};
