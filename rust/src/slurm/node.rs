//! Cluster nodes and partitions.

use crate::simclock::SimTime;
use crate::slurm::job::JobId;

/// Node allocation state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeState {
    Idle,
    /// Allocated to a job.
    Busy(JobId),
    /// Out of service (maintenance / failure injection).
    Down,
}

/// One whole-node-allocatable compute node (Perlmutter-style scheduling:
/// CPU nodes are handed out whole).
#[derive(Debug, Clone)]
pub struct Node {
    pub id: usize,
    pub state: NodeState,
    /// Accumulated busy seconds (utilization accounting).
    pub busy_secs: SimTime,
    /// Time of the last state change.
    pub since: SimTime,
}

impl Node {
    pub fn new(id: usize) -> Self {
        Self {
            id,
            state: NodeState::Idle,
            busy_secs: 0,
            since: 0,
        }
    }

    pub fn is_idle(&self) -> bool {
        self.state == NodeState::Idle
    }

    /// Transition, folding elapsed busy time into the accumulator.
    pub fn set_state(&mut self, state: NodeState, now: SimTime) {
        if let NodeState::Busy(_) = self.state {
            self.busy_secs += now.saturating_sub(self.since);
        }
        self.state = state;
        self.since = now;
    }
}

/// A partition (queue) of the cluster.
#[derive(Debug, Clone)]
pub struct Partition {
    pub name: String,
    /// Scheduling priority tier: higher preempts lower.
    pub priority: u32,
    /// Jobs here may be preempted by higher-priority partitions.
    pub preemptable: bool,
    /// Maximum walltime a job may request.
    pub max_time: SimTime,
    /// Grace period between preemption signal and kill
    /// (Slurm `PreemptGraceTime`).
    pub grace_period: SimTime,
}

impl Partition {
    /// The standard three-queue layout used across our experiments,
    /// mirroring NERSC's regular / preempt / realtime setup.
    pub fn standard_set() -> Vec<Partition> {
        vec![
            Partition {
                name: "regular".into(),
                priority: 10,
                preemptable: false,
                max_time: 12 * 3_600,
                grace_period: 120,
            },
            Partition {
                name: "preempt".into(),
                priority: 1,
                preemptable: true,
                max_time: 24 * 3_600,
                grace_period: 120,
            },
            Partition {
                name: "realtime".into(),
                priority: 100,
                preemptable: false,
                max_time: 4 * 3_600,
                grace_period: 60,
            },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn busy_accounting() {
        let mut n = Node::new(0);
        assert!(n.is_idle());
        n.set_state(NodeState::Busy(7), 100);
        n.set_state(NodeState::Idle, 350);
        assert_eq!(n.busy_secs, 250);
        n.set_state(NodeState::Busy(8), 400);
        n.set_state(NodeState::Down, 500);
        assert_eq!(n.busy_secs, 350);
    }

    #[test]
    fn standard_partitions() {
        let ps = Partition::standard_set();
        assert_eq!(ps.len(), 3);
        let preempt = ps.iter().find(|p| p.name == "preempt").unwrap();
        assert!(preempt.preemptable);
        let rt = ps.iter().find(|p| p.name == "realtime").unwrap();
        assert!(rt.priority > 10);
    }
}
