//! `#SBATCH` batch-script parsing (the front door of the CR workflow).
//!
//! The paper's consolidated job script carries its C/R behaviour in Slurm
//! directives (`--signal`, `--requeue`, `--comment`, `--time-min`); this
//! parser turns such a script into a [`JobSpec`], including the
//! `nersc_cr`-specific extensions carried as comments:
//!
//! ```text
//! #NERSC_CR mode=checkpoint-restart interval=300 overhead=8
//! #NERSC_CR work=7200
//! ```

use crate::error::{Error, Result};
use crate::slurm::job::{CrMode, JobSpec};
use crate::slurm::signals::parse_signal_directive;
use crate::util::parse_hms;

/// Parse a batch script's directives into a [`JobSpec`].
pub fn parse_script(script: &str) -> Result<JobSpec> {
    let mut spec = JobSpec::default();
    let mut cr_mode: Option<&str> = None;
    let mut cr_interval: u64 = 300;
    let mut cr_overhead: u64 = 5;

    for (lineno, raw) in script.lines().enumerate() {
        let line = raw.trim();
        if let Some(rest) = line.strip_prefix("#SBATCH") {
            let rest = rest.trim();
            let (key, val) = parse_directive(rest)
                .map_err(|e| Error::Slurm(format!("line {}: {e}", lineno + 1)))?;
            apply_directive(&mut spec, &key, val.as_deref())
                .map_err(|e| Error::Slurm(format!("line {}: {e}", lineno + 1)))?;
        } else if let Some(rest) = line.strip_prefix("#NERSC_CR") {
            for tok in rest.split_whitespace() {
                let (k, v) = tok.split_once('=').ok_or_else(|| {
                    Error::Slurm(format!("line {}: bad token {tok:?}", lineno + 1))
                })?;
                match k {
                    "mode" => {
                        cr_mode = Some(match v {
                            "none" => "none",
                            "checkpoint-only" => "checkpoint-only",
                            "checkpoint-restart" => "checkpoint-restart",
                            _ => {
                                return Err(Error::Slurm(format!(
                                    "line {}: unknown CR mode {v:?}",
                                    lineno + 1
                                )))
                            }
                        })
                    }
                    "interval" => {
                        cr_interval = v
                            .parse()
                            .map_err(|_| Error::Slurm(format!("bad interval {v:?}")))?
                    }
                    "overhead" => {
                        cr_overhead = v
                            .parse()
                            .map_err(|_| Error::Slurm(format!("bad overhead {v:?}")))?
                    }
                    "work" => {
                        spec.work_total = v
                            .parse()
                            .map_err(|_| Error::Slurm(format!("bad work {v:?}")))?
                    }
                    _ => return Err(Error::Slurm(format!("unknown CR key {k:?}"))),
                }
            }
        }
    }

    spec.cr = match cr_mode {
        Some("checkpoint-only") => CrMode::CheckpointOnly {
            interval: cr_interval,
            overhead: cr_overhead,
        },
        Some("checkpoint-restart") => CrMode::CheckpointRestart {
            interval: cr_interval,
            overhead: cr_overhead,
        },
        _ => CrMode::None,
    };
    Ok(spec)
}

fn parse_directive(s: &str) -> Result<(String, Option<String>)> {
    // --key=value | --key value | --key | -K value (short form)
    let s = match s.strip_prefix("--") {
        Some(rest) => rest,
        None => s
            .strip_prefix('-')
            .ok_or_else(|| Error::Slurm(format!("expected --directive, got {s:?}")))?,
    };
    if let Some((k, v)) = s.split_once('=') {
        return Ok((k.to_string(), Some(v.to_string())));
    }
    match s.split_once(char::is_whitespace) {
        Some((k, v)) => Ok((k.to_string(), Some(v.trim().to_string()))),
        None => Ok((s.to_string(), None)),
    }
}

fn apply_directive(spec: &mut JobSpec, key: &str, val: Option<&str>) -> Result<()> {
    let need = |k: &str, v: Option<&str>| -> Result<String> {
        v.map(String::from)
            .ok_or_else(|| Error::Slurm(format!("--{k} needs a value")))
    };
    match key {
        "job-name" | "J" => spec.name = need(key, val)?,
        "partition" | "p" => spec.partition = need(key, val)?,
        "nodes" | "N" => {
            spec.nodes = need(key, val)?
                .parse()
                .map_err(|_| Error::Slurm("bad --nodes".into()))?
        }
        "time" | "t" => spec.time_limit = parse_hms(&need(key, val)?)?,
        "time-min" => spec.time_min = Some(parse_hms(&need(key, val)?)?),
        "signal" => spec.signal = Some(parse_signal_directive(&need(key, val)?)?),
        "requeue" => spec.requeue = true,
        "no-requeue" => spec.requeue = false,
        "comment" => spec.comment = need(key, val)?,
        "open-mode" | "output" | "error" | "qos" | "constraint" | "account" | "licenses"
        | "mail-type" | "mail-user" | "cpus-per-task" | "ntasks" | "exclusive" => {
            // Accepted Slurm directives that don't affect the simulation.
        }
        other => return Err(Error::Slurm(format!("unsupported directive --{other}"))),
    }
    Ok(())
}

/// Render a [`JobSpec`] back into a script (the CR module generates the
/// consolidated single job script this way).
pub fn render_script(spec: &JobSpec, body: &str) -> String {
    let mut s = String::from("#!/bin/bash\n");
    s.push_str(&format!("#SBATCH --job-name={}\n", spec.name));
    s.push_str(&format!("#SBATCH --partition={}\n", spec.partition));
    s.push_str(&format!("#SBATCH --nodes={}\n", spec.nodes));
    s.push_str(&format!(
        "#SBATCH --time={}\n",
        crate::util::format_hms(spec.time_limit)
    ));
    if let Some(tmin) = spec.time_min {
        s.push_str(&format!(
            "#SBATCH --time-min={}\n",
            crate::util::format_hms(tmin)
        ));
    }
    if let Some((sig, off)) = spec.signal {
        s.push_str(&format!("#SBATCH --signal=B:{}@{}\n", sig.name(), off));
    }
    if spec.requeue {
        s.push_str("#SBATCH --requeue\n");
    }
    if !spec.comment.is_empty() {
        s.push_str(&format!("#SBATCH --comment={}\n", spec.comment));
    }
    s.push_str("#SBATCH --open-mode=append\n");
    match spec.cr {
        CrMode::None => {}
        CrMode::CheckpointOnly { interval, overhead } => {
            s.push_str(&format!(
                "#NERSC_CR mode=checkpoint-only interval={interval} overhead={overhead}\n"
            ));
        }
        CrMode::CheckpointRestart { interval, overhead } => {
            s.push_str(&format!(
                "#NERSC_CR mode=checkpoint-restart interval={interval} overhead={overhead}\n"
            ));
        }
    }
    s.push_str(&format!("#NERSC_CR work={}\n", spec.work_total));
    s.push('\n');
    s.push_str(body);
    s.push('\n');
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slurm::signals::Signal;

    const SCRIPT: &str = r#"#!/bin/bash
#SBATCH --job-name=g4cr
#SBATCH --partition=preempt
#SBATCH --nodes=2
#SBATCH --time=02:00:00
#SBATCH --time-min=00:30:00
#SBATCH --signal=B:USR1@120
#SBATCH --requeue
#SBATCH --comment=ckpt-managed
#SBATCH --open-mode=append
#NERSC_CR mode=checkpoint-restart interval=300 overhead=8
#NERSC_CR work=7200

srun dmtcp_launch ./geant4_sim
"#;

    #[test]
    fn parses_full_script() {
        let spec = parse_script(SCRIPT).unwrap();
        assert_eq!(spec.name, "g4cr");
        assert_eq!(spec.partition, "preempt");
        assert_eq!(spec.nodes, 2);
        assert_eq!(spec.time_limit, 7_200);
        assert_eq!(spec.time_min, Some(1_800));
        assert_eq!(spec.signal, Some((Signal::Usr1, 120)));
        assert!(spec.requeue);
        assert_eq!(spec.comment, "ckpt-managed");
        assert_eq!(spec.work_total, 7_200);
        assert_eq!(
            spec.cr,
            CrMode::CheckpointRestart { interval: 300, overhead: 8 }
        );
    }

    #[test]
    fn roundtrip_render_parse() {
        let spec = parse_script(SCRIPT).unwrap();
        let script2 = render_script(&spec, "srun app");
        let spec2 = parse_script(&script2).unwrap();
        assert_eq!(spec2.name, spec.name);
        assert_eq!(spec2.time_limit, spec.time_limit);
        assert_eq!(spec2.time_min, spec.time_min);
        assert_eq!(spec2.signal, spec.signal);
        assert_eq!(spec2.cr, spec.cr);
        assert_eq!(spec2.work_total, spec.work_total);
    }

    #[test]
    fn space_separated_directives() {
        let spec = parse_script("#SBATCH --nodes 4\n#SBATCH -J x\n").unwrap();
        assert_eq!(spec.nodes, 4);
        // short-form single-letter keys parse via the same path
    }

    #[test]
    fn bad_directives_rejected() {
        assert!(parse_script("#SBATCH --frobnicate=1\n").is_err());
        assert!(parse_script("#SBATCH --time=abc\n").is_err());
        assert!(parse_script("#SBATCH nodes=2\n").is_err());
        assert!(parse_script("#NERSC_CR mode=weird\n").is_err());
        assert!(parse_script("#NERSC_CR interval\n").is_err());
    }

    #[test]
    fn non_directive_lines_ignored() {
        let spec = parse_script("#!/bin/bash\necho hi\n# comment\n").unwrap();
        assert_eq!(spec.name, "job");
    }
}
