//! Batch-system signals and `--signal` directive parsing.

use crate::error::{Error, Result};
use crate::simclock::SimTime;

/// The signals the batch system delivers to jobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Signal {
    /// Pre-timelimit / preemption warning (`scancel --signal=TERM`, or the
    /// scheduler's grace-period notice).
    Term,
    /// User-requested pre-limit notification (`--signal=B:USR1@t`): the CR
    /// module traps this to checkpoint + requeue.
    Usr1,
    /// Immediate termination (grace expired).
    Kill,
}

impl Signal {
    pub fn parse(s: &str) -> Result<Self> {
        match s.trim_start_matches("SIG") {
            "TERM" => Ok(Signal::Term),
            "USR1" => Ok(Signal::Usr1),
            "KILL" => Ok(Signal::Kill),
            other => Err(Error::Slurm(format!("unknown signal {other:?}"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Signal::Term => "TERM",
            Signal::Usr1 => "USR1",
            Signal::Kill => "KILL",
        }
    }
}

/// Parse `--signal=[B:]SIG@offset` (offset in seconds before the limit).
/// The `B:` prefix (signal only the batch shell) is accepted and ignored —
/// our job model has a single recipient.
pub fn parse_signal_directive(s: &str) -> Result<(Signal, SimTime)> {
    let s = s.strip_prefix("B:").unwrap_or(s);
    let (sig, off) = s
        .split_once('@')
        .ok_or_else(|| Error::Slurm(format!("--signal needs SIG@offset, got {s:?}")))?;
    let signal = Signal::parse(sig)?;
    let offset: SimTime = off
        .parse()
        .map_err(|_| Error::Slurm(format!("bad signal offset {off:?}")))?;
    Ok((signal, offset))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        assert_eq!(Signal::parse("TERM").unwrap(), Signal::Term);
        assert_eq!(Signal::parse("SIGUSR1").unwrap(), Signal::Usr1);
        assert_eq!(Signal::parse("KILL").unwrap(), Signal::Kill);
        assert!(Signal::parse("HUP").is_err());
    }

    #[test]
    fn parse_directive_forms() {
        assert_eq!(
            parse_signal_directive("B:USR1@120").unwrap(),
            (Signal::Usr1, 120)
        );
        assert_eq!(
            parse_signal_directive("TERM@60").unwrap(),
            (Signal::Term, 60)
        );
        assert!(parse_signal_directive("USR1").is_err());
        assert!(parse_signal_directive("USR1@abc").is_err());
    }
}
