//! Job specifications, states and accounting.

use crate::simclock::SimTime;
use crate::slurm::signals::Signal;

/// Job identifier.
pub type JobId = u64;

/// How a job uses checkpoint-restart (drives the three strategies of the
/// paper's Fig 4 and the overhead study).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CrMode {
    /// No C/R: preemption or timeout loses all progress.
    None,
    /// Periodic checkpoints; restarts begin from scratch anyway
    /// (the paper's "checkpoint-only" control).
    CheckpointOnly { interval: SimTime, overhead: SimTime },
    /// Periodic checkpoints + restart from the last image on requeue.
    CheckpointRestart { interval: SimTime, overhead: SimTime },
}

impl CrMode {
    /// Checkpoint interval, if checkpointing at all.
    pub fn interval(&self) -> Option<SimTime> {
        match self {
            CrMode::None => None,
            CrMode::CheckpointOnly { interval, .. }
            | CrMode::CheckpointRestart { interval, .. } => Some(*interval),
        }
    }

    /// Per-checkpoint walltime overhead.
    pub fn overhead(&self) -> SimTime {
        match self {
            CrMode::None => 0,
            CrMode::CheckpointOnly { overhead, .. }
            | CrMode::CheckpointRestart { overhead, .. } => *overhead,
        }
    }

    /// Whether restart resumes from the last checkpoint.
    pub fn restarts_from_ckpt(&self) -> bool {
        matches!(self, CrMode::CheckpointRestart { .. })
    }
}

/// A job submission (what `sbatch` parses out of a script).
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub name: String,
    pub partition: String,
    /// Whole nodes requested.
    pub nodes: u32,
    /// `--time`: walltime limit (seconds).
    pub time_limit: SimTime,
    /// `--time-min`: smallest acceptable limit for backfill shrinking.
    pub time_min: Option<SimTime>,
    /// `--signal=[B:]SIG@offset`: deliver `SIG` this many seconds before
    /// the limit.
    pub signal: Option<(Signal, SimTime)>,
    /// `--requeue` eligibility.
    pub requeue: bool,
    /// `--comment`: free text; the CR module stores remaining walltime here.
    pub comment: String,
    /// Total compute seconds the job needs to complete.
    pub work_total: SimTime,
    /// Checkpoint-restart behaviour.
    pub cr: CrMode,
}

impl Default for JobSpec {
    fn default() -> Self {
        Self {
            name: "job".into(),
            partition: "regular".into(),
            nodes: 1,
            time_limit: 3_600,
            time_min: None,
            signal: None,
            requeue: false,
            comment: String::new(),
            work_total: 1_800,
            cr: CrMode::None,
        }
    }
}

/// Job lifecycle states (Slurm names).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Pending,
    Running,
    Completed,
    /// Hit its (possibly shrunk) time limit without C/R.
    Timeout,
    /// Preempted and not requeue-eligible.
    Failed,
    Cancelled,
}

impl JobState {
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            JobState::Completed | JobState::Timeout | JobState::Failed | JobState::Cancelled
        )
    }
}

/// A job and its full accounting across incarnations.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: JobId,
    pub spec: JobSpec,
    pub state: JobState,
    pub submit_time: SimTime,
    /// Start of the *current* incarnation (None while pending).
    pub start_time: Option<SimTime>,
    /// Terminal time, once reached.
    pub end_time: Option<SimTime>,
    /// Walltime limit of the current incarnation (may be shrunk by
    /// backfill within `[time_min, time_limit]`).
    pub effective_limit: SimTime,
    /// Compute seconds finished before the current incarnation started
    /// (what C/R preserved).
    pub work_carried: SimTime,
    /// Compute seconds at the last checkpoint (any incarnation).
    pub work_at_ckpt: SimTime,
    /// Checkpoints taken in total.
    pub checkpoints: u32,
    /// Times this job was requeued.
    pub requeues: u32,
    /// Node ids of the current allocation.
    pub node_ids: Vec<usize>,
    /// Signal deliveries `(time, signal)` (observable by tests).
    pub signal_log: Vec<(SimTime, Signal)>,
    /// Wasted compute seconds (progress lost to preemption/timeout).
    pub work_lost: SimTime,
    /// A preemption signal has been delivered; the grace-period reap is
    /// pending (prevents double-victimization).
    pub preempt_pending: bool,
}

impl Job {
    pub fn new(id: JobId, spec: JobSpec, submit_time: SimTime) -> Self {
        let effective_limit = spec.time_limit;
        Self {
            id,
            spec,
            state: JobState::Pending,
            submit_time,
            start_time: None,
            end_time: None,
            effective_limit,
            work_carried: 0,
            work_at_ckpt: 0,
            checkpoints: 0,
            requeues: 0,
            node_ids: Vec::new(),
            signal_log: Vec::new(),
            work_lost: 0,
            preempt_pending: false,
        }
    }

    /// Compute seconds done as of sim-time `now` (current incarnation
    /// runs 1 work-second per wall-second, minus checkpoint overheads
    /// already accounted by the scheduler via `ckpt_overhead_so_far`).
    pub fn work_done(&self, now: SimTime, ckpt_overhead_so_far: SimTime) -> SimTime {
        match (self.state, self.start_time) {
            (JobState::Running, Some(s)) => {
                let ran = now.saturating_sub(s).saturating_sub(ckpt_overhead_so_far);
                (self.work_carried + ran).min(self.spec.work_total)
            }
            _ => self.work_carried,
        }
    }

    /// Remaining compute seconds at the start of an incarnation.
    pub fn work_remaining(&self) -> SimTime {
        self.spec.work_total.saturating_sub(self.work_carried)
    }

    /// Total checkpoint overhead the current incarnation will pay if it
    /// runs for `span` seconds of wall time.
    pub fn ckpt_overhead_for(&self, span: SimTime) -> SimTime {
        match self.spec.cr.interval() {
            Some(iv) if iv > 0 => (span / iv) * self.spec.cr.overhead(),
            _ => 0,
        }
    }

    /// Slurm-style one-line summary (`squeue`).
    pub fn squeue_line(&self, now: SimTime) -> String {
        let st = match self.state {
            JobState::Pending => "PD",
            JobState::Running => "R",
            JobState::Completed => "CD",
            JobState::Timeout => "TO",
            JobState::Failed => "F",
            JobState::Cancelled => "CA",
        };
        let elapsed = match (self.state, self.start_time) {
            (JobState::Running, Some(s)) => now - s,
            _ => 0,
        };
        format!(
            "{:>8} {:>10} {:>9} {:>2} {:>10} {:>6} {}",
            self.id,
            self.spec.partition,
            self.spec.name,
            st,
            crate::util::format_hms(elapsed),
            self.spec.nodes,
            self.spec.comment,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cr_mode_accessors() {
        assert_eq!(CrMode::None.interval(), None);
        assert_eq!(CrMode::None.overhead(), 0);
        assert!(!CrMode::None.restarts_from_ckpt());
        let co = CrMode::CheckpointOnly { interval: 300, overhead: 5 };
        assert_eq!(co.interval(), Some(300));
        assert!(!co.restarts_from_ckpt());
        let cr = CrMode::CheckpointRestart { interval: 300, overhead: 5 };
        assert!(cr.restarts_from_ckpt());
        assert_eq!(cr.overhead(), 5);
    }

    #[test]
    fn work_accounting() {
        let spec = JobSpec {
            work_total: 1_000,
            ..Default::default()
        };
        let mut j = Job::new(1, spec, 0);
        assert_eq!(j.work_remaining(), 1_000);
        j.state = JobState::Running;
        j.start_time = Some(100);
        assert_eq!(j.work_done(400, 0), 300);
        assert_eq!(j.work_done(400, 50), 250);
        // clamped at total
        assert_eq!(j.work_done(5_000, 0), 1_000);
        j.work_carried = 600;
        assert_eq!(j.work_remaining(), 400);
    }

    #[test]
    fn ckpt_overhead_accumulates_per_interval() {
        let spec = JobSpec {
            cr: CrMode::CheckpointRestart { interval: 100, overhead: 7 },
            ..Default::default()
        };
        let j = Job::new(1, spec, 0);
        assert_eq!(j.ckpt_overhead_for(0), 0);
        assert_eq!(j.ckpt_overhead_for(99), 0);
        assert_eq!(j.ckpt_overhead_for(100), 7);
        assert_eq!(j.ckpt_overhead_for(450), 28);
    }

    #[test]
    fn squeue_line_smoke() {
        let j = Job::new(42, JobSpec::default(), 0);
        let line = j.squeue_line(0);
        assert!(line.contains("42"));
        assert!(line.contains("PD"));
    }
}
