//! Batch-scheduler simulator (the Slurm substrate).
//!
//! A discrete-event cluster simulator with the behaviours the paper's C/R
//! workflow depends on: whole-node allocations, partitions with priority
//! tiers and a preemptable queue, FIFO + EASY backfill (including
//! `--time-min` shrink-to-fit — the "backfill opportunities within the
//! job's specified time constraints"), pre-timelimit `--signal` delivery,
//! preemption with grace periods, and `--requeue` with work carried from
//! the last checkpoint.

pub mod job;
pub mod node;
pub mod sbatch;
pub mod scheduler;
pub mod signals;

pub use job::{CrMode, Job, JobId, JobSpec, JobState};
pub use node::{Node, NodeState, Partition};
pub use sbatch::{parse_script, render_script};
pub use scheduler::{wall_needed, SlurmSim, TraceEvent};
pub use signals::{parse_signal_directive, Signal};
