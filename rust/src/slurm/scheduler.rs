//! The batch scheduler: FIFO + EASY backfill, preemption, pre-timelimit
//! signals, and requeue — the Slurm behaviours the paper's C/R workflow is
//! built on.
//!
//! Execution model: whole-node allocations; a running job completes one
//! work-second per wall-second, minus checkpoint overheads. A job whose
//! remaining work does not fit its (possibly backfill-shrunk) walltime
//! limit receives its `--signal` before the limit; the C/R behaviour at
//! that point — checkpoint and requeue with carried work, or lose progress
//! — is exactly the paper's comparison axis.

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::simclock::{EventQueue, SimTime};
use crate::slurm::job::{CrMode, Job, JobId, JobSpec, JobState};
use crate::slurm::node::{Node, NodeState, Partition};
use crate::slurm::signals::Signal;

/// Scheduler events (incarnation-stamped so a requeue invalidates the
/// previous incarnation's pending events).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Ev {
    /// Job finishes its work.
    Finish(JobId, u32),
    /// Job hits its effective walltime limit.
    Limit(JobId, u32),
    /// `--signal` delivery point before the limit.
    PreSignal(JobId, u32),
    /// Periodic checkpoint instant.
    Ckpt(JobId, u32),
    /// Grace period after preemption signal expired: reap the victim.
    Reap(JobId, u32),
    /// Re-run the scheduling pass.
    Schedule,
}

/// Observable trace of scheduler activity (tests + benches consume this).
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    Submitted { id: JobId, t: SimTime },
    Started { id: JobId, t: SimTime, nodes: Vec<usize>, limit: SimTime, backfilled: bool },
    Checkpointed { id: JobId, t: SimTime, work: SimTime },
    Signaled { id: JobId, t: SimTime, signal: Signal },
    Requeued { id: JobId, t: SimTime, carried: SimTime },
    Preempted { id: JobId, t: SimTime, by: JobId },
    Finished { id: JobId, t: SimTime },
    TimedOut { id: JobId, t: SimTime, lost: SimTime },
    Failed { id: JobId, t: SimTime, lost: SimTime },
}

/// The cluster + queue simulator.
pub struct SlurmSim {
    pub now: SimTime,
    events: EventQueue<Ev>,
    jobs: BTreeMap<JobId, Job>,
    nodes: Vec<Node>,
    partitions: BTreeMap<String, Partition>,
    pending: Vec<JobId>,
    next_id: JobId,
    /// Per-incarnation checkpoint counts (overhead accounting).
    ckpts_this_inc: BTreeMap<JobId, u32>,
    pub trace: Vec<TraceEvent>,
    /// Requeue budget per job (Slurm sites cap batch requeues; this also
    /// bounds the checkpoint-only livelock where a job restarts from
    /// scratch forever and starves the queue).
    pub max_requeues: u32,
}

/// Wall seconds needed to do `work` compute seconds with a checkpoint
/// every `iv` wall seconds costing `ov` (fixed point of
/// `w = work + floor(w/iv)*ov`).
pub fn wall_needed(work: SimTime, cr: &CrMode) -> SimTime {
    match cr.interval() {
        None => work,
        Some(0) => work,
        Some(iv) => {
            let ov = cr.overhead();
            let mut w = work;
            for _ in 0..64 {
                let next = work + (w / iv) * ov;
                if next == w {
                    break;
                }
                w = next;
            }
            w
        }
    }
}

impl SlurmSim {
    pub fn new(n_nodes: usize, partitions: Vec<Partition>) -> Self {
        Self {
            now: 0,
            events: EventQueue::new(),
            jobs: BTreeMap::new(),
            nodes: (0..n_nodes).map(Node::new).collect(),
            partitions: partitions.into_iter().map(|p| (p.name.clone(), p)).collect(),
            pending: Vec::new(),
            next_id: 100_000, // NERSC-looking job ids
            ckpts_this_inc: BTreeMap::new(),
            trace: Vec::new(),
            max_requeues: 200,
        }
    }

    /// Submit a job now. Returns the job id.
    pub fn submit(&mut self, spec: JobSpec) -> Result<JobId> {
        self.submit_at(spec, self.now)
    }

    /// Submit a job at a future time.
    pub fn submit_at(&mut self, spec: JobSpec, t: SimTime) -> Result<JobId> {
        let part = self
            .partitions
            .get(&spec.partition)
            .ok_or_else(|| Error::Slurm(format!("unknown partition {:?}", spec.partition)))?;
        if spec.time_limit > part.max_time {
            return Err(Error::Slurm(format!(
                "time limit {} exceeds partition max {}",
                spec.time_limit, part.max_time
            )));
        }
        if spec.nodes as usize > self.nodes.len() {
            return Err(Error::Slurm(format!(
                "job wants {} nodes, cluster has {}",
                spec.nodes,
                self.nodes.len()
            )));
        }
        if t < self.now {
            return Err(Error::Slurm("cannot submit in the past".into()));
        }
        let id = self.next_id;
        self.next_id += 1;
        let mut job = Job::new(id, spec, t);
        if t == self.now {
            self.pending.push(id);
            self.trace.push(TraceEvent::Submitted { id, t });
            self.jobs.insert(id, job);
            self.try_schedule();
        } else {
            job.state = JobState::Pending;
            self.jobs.insert(id, job);
            self.events.schedule(t, Ev::Schedule);
            // Delayed submissions surface via a marker checked in run():
            self.events.schedule(t, Ev::Finish(id, u32::MAX)); // sentinel, see run()
        }
        Ok(id)
    }

    pub fn job(&self, id: JobId) -> Option<&Job> {
        self.jobs.get(&id)
    }

    pub fn jobs(&self) -> impl Iterator<Item = &Job> {
        self.jobs.values()
    }

    pub fn n_idle(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_idle()).count()
    }

    /// Cluster utilization over `[0, now]`.
    pub fn utilization(&self) -> f64 {
        if self.now == 0 || self.nodes.is_empty() {
            return 0.0;
        }
        let busy: SimTime = self
            .nodes
            .iter()
            .map(|n| {
                n.busy_secs
                    + match n.state {
                        NodeState::Busy(_) => self.now - n.since,
                        _ => 0,
                    }
            })
            .sum();
        busy as f64 / (self.nodes.len() as u64 * self.now) as f64
    }

    /// Run until the event queue drains or `max_t` is reached.
    pub fn run(&mut self, max_t: SimTime) {
        while let Some(t_next) = self.events.peek_time() {
            if t_next > max_t {
                self.now = max_t;
                return;
            }
            let (t, ev) = self.events.pop().unwrap();
            self.now = t;
            self.handle(ev);
        }
        // Queue drained before max_t: advance the clock to the requested
        // horizon (bounded runs measure utilization over that window).
        self.now = if max_t == SimTime::MAX {
            self.now.max(
                self.jobs
                    .values()
                    .filter_map(|j| j.end_time)
                    .max()
                    .unwrap_or(self.now),
            )
        } else {
            max_t
        };
    }

    /// True when every job reached a terminal state.
    pub fn all_done(&self) -> bool {
        self.jobs.values().all(|j| j.state.is_terminal())
    }

    // --- event handling -------------------------------------------------

    fn handle(&mut self, ev: Ev) {
        match ev {
            Ev::Schedule => self.try_schedule(),
            Ev::Finish(id, inc) if inc == u32::MAX => {
                // Deferred-submission sentinel: move the job into pending.
                if let Some(j) = self.jobs.get(&id) {
                    if j.state == JobState::Pending && !self.pending.contains(&id) {
                        self.pending.push(id);
                        self.trace.push(TraceEvent::Submitted { id, t: self.now });
                        self.try_schedule();
                    }
                }
            }
            Ev::Finish(id, inc) => self.on_finish(id, inc),
            Ev::Limit(id, inc) => self.on_limit(id, inc),
            Ev::PreSignal(id, inc) => self.on_presignal(id, inc),
            Ev::Ckpt(id, inc) => self.on_ckpt(id, inc),
            Ev::Reap(id, inc) => self.on_reap(id, inc),
        }
    }

    fn live_incarnation(&self, id: JobId, inc: u32) -> bool {
        self.jobs
            .get(&id)
            .map(|j| j.state == JobState::Running && j.requeues == inc)
            .unwrap_or(false)
    }

    fn on_finish(&mut self, id: JobId, inc: u32) {
        if !self.live_incarnation(id, inc) {
            return;
        }
        let now = self.now;
        let job = self.jobs.get_mut(&id).unwrap();
        job.work_carried = job.spec.work_total;
        job.state = JobState::Completed;
        job.end_time = Some(now);
        self.trace.push(TraceEvent::Finished { id, t: now });
        self.release_nodes(id);
        self.try_schedule();
    }

    fn on_limit(&mut self, id: JobId, inc: u32) {
        if !self.live_incarnation(id, inc) {
            return;
        }
        let now = self.now;
        let overhead = self.inc_overhead(id);
        let job = self.jobs.get_mut(&id).unwrap();
        // If the job had CR+requeue it already checkpoint-requeued at the
        // PreSignal; reaching Limit while still running means no C/R saved
        // it: the incarnation's progress is lost.
        let done = job.work_done(now, overhead);
        let lost = done.saturating_sub(if job.spec.cr.restarts_from_ckpt() {
            job.work_at_ckpt
        } else {
            0
        });
        if job.spec.requeue && job.spec.cr.restarts_from_ckpt() {
            // Defensive path: requeue from the last periodic checkpoint.
            job.work_lost += lost;
            let carried = job.work_at_ckpt;
            self.requeue(id, carried);
        } else {
            job.state = JobState::Timeout;
            job.end_time = Some(now);
            job.work_lost += done;
            self.trace.push(TraceEvent::TimedOut { id, t: now, lost: done });
            self.release_nodes(id);
        }
        self.try_schedule();
    }

    fn on_presignal(&mut self, id: JobId, inc: u32) {
        if !self.live_incarnation(id, inc) {
            return;
        }
        let now = self.now;
        let overhead = self.inc_overhead(id);
        let job = self.jobs.get_mut(&id).unwrap();
        let signal = job.spec.signal.map(|(s, _)| s).unwrap_or(Signal::Usr1);
        job.signal_log.push((now, signal));
        self.trace.push(TraceEvent::Signaled { id, t: now, signal });

        let job = self.jobs.get_mut(&id).unwrap();
        match (job.spec.requeue, job.spec.cr) {
            (true, CrMode::CheckpointRestart { overhead: ov, .. }) => {
                // func_trap: checkpoint now, requeue with carried work.
                let done = job.work_done(now, overhead);
                job.work_at_ckpt = done;
                job.checkpoints += 1;
                self.trace.push(TraceEvent::Checkpointed { id, t: now, work: done });
                // The checkpoint write occupies the node for `ov` seconds,
                // then the job leaves the allocation.
                let carried = done;
                let _ = ov; // wall cost absorbed into the requeue instant
                self.requeue(id, carried);
                self.try_schedule();
            }
            (true, CrMode::CheckpointOnly { .. }) => {
                // Images exist but are not used: requeue from scratch.
                let done = job.work_done(now, overhead);
                job.work_lost += done;
                self.requeue(id, 0);
                self.try_schedule();
            }
            _ => {
                // Signal logged; the job runs on until Limit.
            }
        }
    }

    fn on_ckpt(&mut self, id: JobId, inc: u32) {
        if !self.live_incarnation(id, inc) {
            return;
        }
        let now = self.now;
        *self.ckpts_this_inc.entry(id).or_insert(0) += 1;
        let overhead = self.inc_overhead(id);
        let job = self.jobs.get_mut(&id).unwrap();
        let done = job.work_done(now, overhead);
        job.work_at_ckpt = done;
        job.checkpoints += 1;
        self.trace.push(TraceEvent::Checkpointed { id, t: now, work: done });
        // Next periodic checkpoint.
        if let Some(iv) = job.spec.cr.interval() {
            let inc = job.requeues;
            self.events.schedule(now + iv, Ev::Ckpt(id, inc));
        }
    }

    fn on_reap(&mut self, id: JobId, inc: u32) {
        if !self.live_incarnation(id, inc) {
            return;
        }
        let now = self.now;
        let overhead = self.inc_overhead(id);
        let grace = self
            .jobs
            .get(&id)
            .and_then(|j| self.partitions.get(&j.spec.partition))
            .map(|p| p.grace_period)
            .unwrap_or(0);
        let job = self.jobs.get_mut(&id).unwrap();
        let done = job.work_done(now, overhead);
        if job.spec.requeue && job.spec.cr.restarts_from_ckpt() && grace > 0 {
            // The grace-period checkpoint (func_trap on SIGTERM) succeeded.
            job.work_at_ckpt = done;
            job.checkpoints += 1;
            self.trace.push(TraceEvent::Checkpointed { id, t: now, work: done });
            self.requeue(id, done);
        } else if job.spec.requeue && job.spec.cr.restarts_from_ckpt() {
            // No grace to checkpoint in (hard kill): recover from the last
            // *periodic* checkpoint; the slice since then is lost — this
            // is where the checkpoint interval matters (see the
            // `ablation_interval` bench).
            let carried = job.work_at_ckpt.min(done);
            job.work_lost += done.saturating_sub(carried);
            self.requeue(id, carried);
        } else if job.spec.requeue {
            let carried = 0;
            job.work_lost += done;
            self.requeue(id, carried);
        } else {
            job.state = JobState::Failed;
            job.end_time = Some(now);
            job.work_lost += done;
            self.trace.push(TraceEvent::Failed { id, t: now, lost: done });
            self.release_nodes(id);
        }
        self.try_schedule();
    }

    fn inc_overhead(&self, id: JobId) -> SimTime {
        let count = self.ckpts_this_inc.get(&id).copied().unwrap_or(0) as u64;
        self.jobs
            .get(&id)
            .map(|j| count * j.spec.cr.overhead())
            .unwrap_or(0)
    }

    fn requeue(&mut self, id: JobId, carried: SimTime) {
        let now = self.now;
        let max = self.max_requeues;
        let job = self.jobs.get_mut(&id).unwrap();
        if job.requeues >= max {
            // Requeue budget exhausted (site policy): fail the job rather
            // than let a non-converging requeue loop starve the cluster.
            let lost = job.work_done(now, 0).saturating_sub(carried) + carried;
            job.state = JobState::Failed;
            job.end_time = Some(now);
            job.work_lost += lost.saturating_sub(carried);
            self.trace.push(TraceEvent::Failed { id, t: now, lost });
            self.release_nodes(id);
            return;
        }
        job.state = JobState::Pending;
        job.start_time = None;
        job.work_carried = carried;
        job.preempt_pending = false;
        job.requeues += 1;
        job.effective_limit = job.spec.time_limit;
        // The paper's script updates the job comment with remaining time.
        job.spec.comment = format!(
            "remaining={}",
            crate::util::format_hms(job.work_remaining())
        );
        self.ckpts_this_inc.remove(&id);
        self.trace.push(TraceEvent::Requeued { id, t: now, carried });
        self.release_nodes(id);
        self.pending.push(id);
    }

    fn release_nodes(&mut self, id: JobId) {
        let now = self.now;
        let node_ids = self
            .jobs
            .get_mut(&id)
            .map(|j| std::mem::take(&mut j.node_ids))
            .unwrap_or_default();
        for nid in node_ids {
            self.nodes[nid].set_state(NodeState::Idle, now);
        }
    }

    // --- scheduling -------------------------------------------------------

    /// Release times of currently running jobs: `(t, nodes_freed)` sorted.
    fn release_schedule(&self) -> Vec<(SimTime, usize)> {
        let mut rel: Vec<(SimTime, usize)> = self
            .jobs
            .values()
            .filter(|j| j.state == JobState::Running)
            .map(|j| {
                let end = j
                    .start_time
                    .map(|s| s + j.effective_limit)
                    .unwrap_or(self.now);
                (end, j.node_ids.len())
            })
            .collect();
        rel.sort_unstable();
        rel
    }

    /// Earliest time at which `want` nodes will be free.
    fn reservation_time(&self, want: usize) -> SimTime {
        let mut free = self.n_idle();
        if free >= want {
            return self.now;
        }
        for (t, n) in self.release_schedule() {
            free += n;
            if free >= want {
                return t.max(self.now);
            }
        }
        SimTime::MAX
    }

    fn priority_of(&self, id: JobId) -> (u32, SimTime, JobId) {
        let j = &self.jobs[&id];
        let p = self.partitions.get(&j.spec.partition).map(|p| p.priority).unwrap_or(0);
        (p, j.submit_time, id)
    }

    fn idle_node_ids(&self, want: usize) -> Vec<usize> {
        self.nodes
            .iter()
            .filter(|n| n.is_idle())
            .take(want)
            .map(|n| n.id)
            .collect()
    }

    fn start_job(&mut self, id: JobId, limit: SimTime, backfilled: bool) {
        let now = self.now;
        let want = self.jobs[&id].spec.nodes as usize;
        let node_ids = self.idle_node_ids(want);
        assert_eq!(node_ids.len(), want, "start_job without enough idle nodes");
        for &nid in &node_ids {
            self.nodes[nid].set_state(NodeState::Busy(id), now);
        }
        self.ckpts_this_inc.remove(&id);
        let job = self.jobs.get_mut(&id).unwrap();
        job.state = JobState::Running;
        job.start_time = Some(now);
        job.effective_limit = limit;
        job.node_ids = node_ids.clone();
        let inc = job.requeues;

        let need = wall_needed(job.work_remaining(), &job.spec.cr);
        let spec_signal = job.spec.signal;
        let cr_interval = job.spec.cr.interval();
        self.trace.push(TraceEvent::Started { id, t: now, nodes: node_ids, limit, backfilled });

        if need <= limit {
            self.events.schedule(now + need, Ev::Finish(id, inc));
        } else {
            if let Some((_, off)) = spec_signal {
                let at = now + limit.saturating_sub(off);
                self.events.schedule(at, Ev::PreSignal(id, inc));
            }
            self.events.schedule(now + limit, Ev::Limit(id, inc));
        }
        if let Some(iv) = cr_interval {
            if iv > 0 && iv < limit.min(need) {
                self.events.schedule(now + iv, Ev::Ckpt(id, inc));
            }
        }
    }

    /// FIFO + EASY backfill + preemption pass.
    fn try_schedule(&mut self) {
        // Priority order: partition priority desc, then submit time, id.
        let mut order: Vec<JobId> = self
            .pending
            .iter()
            .copied()
            .filter(|id| self.jobs[id].state == JobState::Pending)
            .collect();
        order.sort_by_key(|&id| {
            let (p, t, i) = self.priority_of(id);
            (std::cmp::Reverse(p), t, i)
        });
        self.pending = order.clone();

        let mut reservation: Option<(SimTime, usize)> = None; // (time, head nodes)
        let mut started = Vec::new();

        for &id in &order {
            let (want, limit, time_min, partition) = {
                let j = &self.jobs[&id];
                (
                    j.spec.nodes as usize,
                    j.spec.time_limit,
                    j.spec.time_min,
                    j.spec.partition.clone(),
                )
            };
            let idle = self.n_idle();

            if reservation.is_none() {
                // Head-of-queue job.
                if idle >= want {
                    self.start_job(id, limit, false);
                    started.push(id);
                    continue;
                }
                // Try preemption for high-priority partitions. If initiated,
                // reserve the head job's slot at the end of the victims'
                // grace period so backfill does not re-fill the nodes the
                // preemption is about to free.
                if let Some(free_at) = self.try_preempt_for(id, want, &partition) {
                    reservation = Some((free_at, want));
                    continue;
                }
                let r = self.reservation_time(want);
                reservation = Some((r, want));
                continue;
            }

            // Backfill candidates behind the reservation.
            let (r_time, _r_nodes) = reservation.unwrap();
            if idle < want {
                continue;
            }
            // Full-length fit before the reservation?
            if self.now + limit <= r_time {
                self.start_job(id, limit, true);
                started.push(id);
                continue;
            }
            // Shrink-to-fit within [time_min, window] (the paper:
            // "seeking backfill opportunities within the job's specified
            // time constraints").
            if let Some(tmin) = time_min {
                let window = r_time.saturating_sub(self.now);
                if window >= tmin {
                    self.start_job(id, window, true);
                    started.push(id);
                    continue;
                }
            }
        }
        self.pending.retain(|id| !started.contains(id));
    }

    /// Try to free `want` nodes for `id` by preempting lower-priority,
    /// preemptable jobs. Returns the time the nodes will be free if
    /// preemption was initiated.
    fn try_preempt_for(&mut self, id: JobId, want: usize, partition: &str) -> Option<SimTime> {
        let my_prio = match self.partitions.get(partition) {
            Some(p) => p.priority,
            None => return None,
        };
        let idle = self.n_idle();
        if idle >= want {
            return None;
        }
        let mut needed = want - idle;

        // Victims: preemptable, lower priority, prefer most-recently started
        // (least sunk work) — collected before mutating.
        let mut victims: Vec<(SimTime, JobId, usize, SimTime)> = self
            .jobs
            .values()
            .filter(|j| j.state == JobState::Running && !j.preempt_pending)
            .filter(|j| {
                self.partitions
                    .get(&j.spec.partition)
                    .map(|p| p.preemptable && p.priority < my_prio)
                    .unwrap_or(false)
            })
            .map(|j| {
                let grace = self
                    .partitions
                    .get(&j.spec.partition)
                    .map(|p| p.grace_period)
                    .unwrap_or(0);
                (j.start_time.unwrap_or(0), j.id, j.node_ids.len(), grace)
            })
            .collect();
        victims.sort_by_key(|&(start, vid, _, _)| (std::cmp::Reverse(start), vid));

        let mut chosen = Vec::new();
        for (_, vid, n, grace) in victims {
            if needed == 0 {
                break;
            }
            chosen.push((vid, grace));
            needed = needed.saturating_sub(n);
        }
        if needed > 0 {
            return None; // even preempting everything wouldn't fit
        }
        let now = self.now;
        let mut free_at = now;
        for (vid, grace) in chosen {
            let job = self.jobs.get_mut(&vid).unwrap();
            job.signal_log.push((now, Signal::Term));
            job.preempt_pending = true;
            self.trace.push(TraceEvent::Signaled { id: vid, t: now, signal: Signal::Term });
            self.trace.push(TraceEvent::Preempted { id: vid, t: now, by: id });
            let inc = self.jobs[&vid].requeues;
            self.events.schedule(now + grace, Ev::Reap(vid, inc));
            free_at = free_at.max(now + grace);
        }
        Some(free_at)
    }

    /// `squeue`-style listing.
    pub fn squeue(&self) -> String {
        let mut out = String::from(
            "   JOBID  PARTITION      NAME ST       TIME  NODES COMMENT\n",
        );
        for j in self.jobs.values() {
            if !j.state.is_terminal() {
                out.push_str(&j.squeue_line(self.now));
                out.push('\n');
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim(n: usize) -> SlurmSim {
        SlurmSim::new(n, Partition::standard_set())
    }

    fn basic_spec(work: SimTime, limit: SimTime) -> JobSpec {
        JobSpec {
            work_total: work,
            time_limit: limit,
            ..Default::default()
        }
    }

    #[test]
    fn single_job_completes() {
        let mut s = sim(4);
        let id = s.submit(basic_spec(600, 3_600)).unwrap();
        s.run(SimTime::MAX);
        let j = s.job(id).unwrap();
        assert_eq!(j.state, JobState::Completed);
        assert_eq!(j.end_time, Some(600));
        assert_eq!(j.work_lost, 0);
    }

    #[test]
    fn job_without_cr_times_out() {
        let mut s = sim(1);
        let id = s.submit(basic_spec(10_000, 3_600)).unwrap();
        s.run(SimTime::MAX);
        let j = s.job(id).unwrap();
        assert_eq!(j.state, JobState::Timeout);
        assert_eq!(j.work_lost, 3_600);
    }

    #[test]
    fn cr_job_requeues_and_completes() {
        let mut s = sim(1);
        let spec = JobSpec {
            work_total: 8_000,
            time_limit: 3_600,
            requeue: true,
            signal: Some((Signal::Usr1, 120)),
            cr: CrMode::CheckpointRestart { interval: 600, overhead: 10 },
            ..Default::default()
        };
        let id = s.submit(spec).unwrap();
        s.run(SimTime::MAX);
        let j = s.job(id).unwrap();
        assert_eq!(j.state, JobState::Completed, "trace: {:?}", s.trace);
        assert!(j.requeues >= 2, "requeues={}", j.requeues);
        assert!(j.checkpoints >= j.requeues);
        // Work lost only to the slice between last ckpt and the signal —
        // with signal-time checkpointing, nothing.
        assert_eq!(j.work_lost, 0);
        // USR1 was delivered before each limit.
        assert!(j
            .signal_log
            .iter()
            .filter(|(_, s)| *s == Signal::Usr1)
            .count() >= 2);
    }

    #[test]
    fn checkpoint_only_job_restarts_from_scratch() {
        let mut s = sim(1);
        let spec = JobSpec {
            work_total: 5_000,
            time_limit: 3_600,
            requeue: true,
            signal: Some((Signal::Usr1, 120)),
            cr: CrMode::CheckpointOnly { interval: 600, overhead: 10 },
            ..Default::default()
        };
        let id = s.submit(spec).unwrap();
        // Run long enough to see it never converge quickly: each
        // incarnation does (3600-120) wall - overheads and then loses it.
        s.run(40_000);
        let j = s.job(id).unwrap();
        assert!(j.requeues >= 1);
        assert!(j.work_lost > 0, "checkpoint-only must lose work on requeue");
    }

    #[test]
    fn two_jobs_share_cluster_fifo() {
        let mut s = sim(2);
        let a = s.submit(JobSpec { nodes: 2, ..basic_spec(1_000, 3_600) }).unwrap();
        let b = s.submit(JobSpec { nodes: 2, ..basic_spec(1_000, 3_600) }).unwrap();
        s.run(SimTime::MAX);
        let (ja, jb) = (s.job(a).unwrap(), s.job(b).unwrap());
        assert_eq!(ja.end_time, Some(1_000));
        assert_eq!(jb.start_time, Some(1_000), "FIFO order violated");
        assert_eq!(jb.end_time, Some(2_000));
    }

    #[test]
    fn backfill_fills_hole_without_delaying_head() {
        let mut s = sim(4);
        // A: occupies 3 nodes for 1000s.
        let a = s.submit(JobSpec { nodes: 3, ..basic_spec(1_000, 1_000) }).unwrap();
        // B (head of queue): needs all 4 -> reservation at t=1000.
        let b = s.submit(JobSpec { nodes: 4, ..basic_spec(500, 3_600) }).unwrap();
        // C: 1 node, 400s <= window -> backfills at t=0 on the idle node.
        let c = s.submit(JobSpec { nodes: 1, ..basic_spec(400, 400) }).unwrap();
        s.run(SimTime::MAX);
        let (ja, jb, jc) = (s.job(a).unwrap(), s.job(b).unwrap(), s.job(c).unwrap());
        assert_eq!(jc.start_time, Some(0), "C should backfill immediately");
        assert_eq!(ja.end_time, Some(1_000));
        assert_eq!(jb.start_time, Some(1_000), "backfill delayed the head job");
        let started_backfilled = s.trace.iter().any(|e| matches!(e,
            TraceEvent::Started { id, backfilled: true, .. } if *id == c));
        assert!(started_backfilled);
    }

    #[test]
    fn backfill_shrinks_to_time_min() {
        let mut s = sim(2);
        // A: 1 node busy until t=1000.
        let _a = s.submit(JobSpec { nodes: 1, ..basic_spec(1_000, 1_000) }).unwrap();
        // B: head, needs 2 nodes -> reserved at t=1000.
        let _b = s.submit(JobSpec { nodes: 2, ..basic_spec(500, 3_600) }).unwrap();
        // C: wants 2h but accepts >= 600s; window is 1000s -> shrunk start.
        let c = s
            .submit(JobSpec {
                nodes: 1,
                time_min: Some(600),
                requeue: true,
                signal: Some((Signal::Usr1, 100)),
                cr: CrMode::CheckpointRestart { interval: 300, overhead: 5 },
                ..basic_spec(5_000, 7_200)
            })
            .unwrap();
        s.run(SimTime::MAX);
        let jc = s.job(c).unwrap();
        assert_eq!(jc.start_time.is_some(), true);
        let started = s.trace.iter().find_map(|e| match e {
            TraceEvent::Started { id, t, limit, backfilled, .. } if *id == c && *t == 0 => {
                Some((*limit, *backfilled))
            }
            _ => None,
        });
        let (limit, backfilled) = started.expect("C did not start at t=0");
        assert!(backfilled);
        assert_eq!(limit, 1_000, "effective limit should shrink to the window");
        assert_eq!(jc.state, JobState::Completed, "C/R must carry C to completion");
    }

    #[test]
    fn realtime_preempts_preemptable() {
        let mut s = sim(2);
        // Fill the cluster with preemptable C/R work.
        let low = s
            .submit(JobSpec {
                partition: "preempt".into(),
                nodes: 2,
                requeue: true,
                cr: CrMode::CheckpointRestart { interval: 300, overhead: 5 },
                ..basic_spec(10_000, 20_000)
            })
            .unwrap();
        s.run(100); // let it start
        assert_eq!(s.job(low).unwrap().state, JobState::Running);
        // Urgent job arrives.
        let hi = s
            .submit(JobSpec {
                partition: "realtime".into(),
                nodes: 2,
                ..basic_spec(600, 3_600)
            })
            .unwrap();
        s.run(SimTime::MAX);
        let (jl, jh) = (s.job(low).unwrap(), s.job(hi).unwrap());
        assert_eq!(jh.state, JobState::Completed);
        // Preempted job checkpointed in its grace period, requeued, resumed,
        // and completed with zero loss.
        assert_eq!(jl.state, JobState::Completed, "trace: {:?}", s.trace);
        assert!(jl.requeues >= 1);
        assert_eq!(jl.work_lost, 0);
        assert!(jl.signal_log.iter().any(|(_, sig)| *sig == Signal::Term));
        // The victim's grace delayed the urgent job by exactly grace_period.
        assert!(jh.start_time.unwrap() >= 100);
    }

    #[test]
    fn preempted_without_requeue_fails() {
        let mut s = sim(1);
        let low = s
            .submit(JobSpec {
                partition: "preempt".into(),
                nodes: 1,
                requeue: false,
                ..basic_spec(10_000, 20_000)
            })
            .unwrap();
        s.run(50);
        let _hi = s
            .submit(JobSpec {
                partition: "realtime".into(),
                nodes: 1,
                ..basic_spec(100, 3_600)
            })
            .unwrap();
        s.run(SimTime::MAX);
        let jl = s.job(low).unwrap();
        assert_eq!(jl.state, JobState::Failed);
        assert!(jl.work_lost > 0);
    }

    #[test]
    fn periodic_checkpoints_recorded() {
        let mut s = sim(1);
        let id = s
            .submit(JobSpec {
                cr: CrMode::CheckpointRestart { interval: 100, overhead: 2 },
                ..basic_spec(1_000, 3_600)
            })
            .unwrap();
        s.run(SimTime::MAX);
        let j = s.job(id).unwrap();
        assert_eq!(j.state, JobState::Completed);
        assert!(j.checkpoints >= 9, "checkpoints={}", j.checkpoints);
        // Overhead stretches wallclock: 1000 work + >=9 ckpts * 2s.
        assert!(j.end_time.unwrap() >= 1_018);
    }

    #[test]
    fn utilization_accounting() {
        let mut s = sim(2);
        let _ = s.submit(JobSpec { nodes: 2, ..basic_spec(500, 3_600) }).unwrap();
        s.run(1_000);
        // 2 nodes busy 500s of 1000s elapsed = 0.5
        let u = s.utilization();
        assert!((u - 0.5).abs() < 0.01, "u={u}");
    }

    #[test]
    fn deferred_submission() {
        let mut s = sim(1);
        let id = s.submit_at(basic_spec(100, 3_600), 500).unwrap();
        s.run(SimTime::MAX);
        let j = s.job(id).unwrap();
        assert_eq!(j.start_time, Some(500));
        assert_eq!(j.end_time, Some(600));
    }

    #[test]
    fn invalid_submissions_rejected() {
        let mut s = sim(2);
        assert!(s
            .submit(JobSpec { partition: "nope".into(), ..Default::default() })
            .is_err());
        assert!(s.submit(JobSpec { nodes: 5, ..Default::default() }).is_err());
        assert!(s
            .submit(JobSpec { time_limit: 999_999_999, ..Default::default() })
            .is_err());
    }

    #[test]
    fn wall_needed_fixed_point() {
        assert_eq!(wall_needed(1_000, &CrMode::None), 1_000);
        let cr = CrMode::CheckpointRestart { interval: 100, overhead: 10 };
        let w = wall_needed(1_000, &cr);
        // w = 1000 + floor(w/100)*10 -> w = 1110 (floor(1110/100) = 11)
        assert_eq!(w, 1_110);
        assert_eq!(w - (w / 100) * 10, 1_000, "wall minus overheads = work");
    }
}
