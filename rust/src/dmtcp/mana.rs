//! MANA-style split-process checkpointing (the paper's §VII direction).
//!
//! "MANA (MPI-Agnostic Network-Agnostic) ... promises enhanced efficiency
//! and flexibility for MPI applications through its innovative
//! split-process approach, which simplifies the checkpointing process by
//! focusing on application state while abstracting away MPI library and
//! network specifics."
//!
//! The split is expressed here as a segment-name convention: segments
//! whose names start with [`LIB_PREFIX`] belong to the *lower half* (MPI
//! library, network endpoints, transport caches). [`ManaState`] wraps any
//! [`Checkpointable`] and
//!
//! * **excludes** lower-half segments from the image (smaller, faster,
//!   implementation-oblivious checkpoints), and
//! * **re-initializes** the lower half on restart through a user-supplied
//!   `reinit` hook (the moral equivalent of re-running `MPI_Init` and
//!   rebuilding communicators on the new allocation).
//!
//! The ablation bench `ckpt_overhead` quantifies the image-size/time win
//! over whole-process DMTCP images for library-heavy states.

use std::sync::{Arc, Mutex};

use crate::dmtcp::process::Checkpointable;
use crate::error::Result;

/// Lower-half segment-name prefix.
pub const LIB_PREFIX: &str = "lib:";

/// Re-initialization hook run after the upper half is restored.
pub type ReinitFn<S> = Box<dyn Fn(&mut S) -> Result<()> + Send>;

/// A split-process wrapper: checkpoints only the application (upper-half)
/// segments of `S`, rebuilding the rest via `reinit` on restore.
pub struct ManaState<S: Checkpointable> {
    inner: Arc<Mutex<S>>,
    reinit: ReinitFn<S>,
    exclude_lib: bool,
}

impl<S: Checkpointable> ManaState<S> {
    /// Wrap `inner` with MANA lower-half exclusion ON.
    pub fn new(inner: Arc<Mutex<S>>, reinit: ReinitFn<S>) -> Self {
        Self::with_exclusion(inner, reinit, true)
    }

    /// Like [`ManaState::new`], but with lower-half exclusion as a knob:
    /// `exclude_lib = false` keeps `lib:` segments in the image (the
    /// whole-process DMTCP baseline of the MANA ablation) while *still*
    /// running `reinit` on restore — a restored lower half is stale for
    /// the new incarnation either way, so the rebuild is unconditional.
    pub fn with_exclusion(inner: Arc<Mutex<S>>, reinit: ReinitFn<S>, exclude_lib: bool) -> Self {
        Self {
            inner,
            reinit,
            exclude_lib,
        }
    }

    /// Shared handle to the wrapped state.
    pub fn inner(&self) -> Arc<Mutex<S>> {
        Arc::clone(&self.inner)
    }

    /// Is this a lower-half (library) segment?
    pub fn is_lib_segment(name: &str) -> bool {
        name.starts_with(LIB_PREFIX)
    }
}

impl<S: Checkpointable> Checkpointable for ManaState<S> {
    fn segments(&self) -> Vec<(String, Vec<u8>)> {
        self.inner
            .lock()
            .expect("mana inner poisoned")
            .segments()
            .into_iter()
            .filter(|(name, _)| !self.exclude_lib || !Self::is_lib_segment(name))
            .collect()
    }

    fn restore(&mut self, segments: &[(String, Vec<u8>)]) -> Result<()> {
        // Upper half from the image; lower half rebuilt for the *current*
        // incarnation (new nodes, new endpoints).
        let mut inner = self.inner.lock().expect("mana inner poisoned");
        inner.restore(segments)?;
        (self.reinit)(&mut inner)
    }

    fn steps_done(&self) -> u64 {
        self.inner.lock().expect("mana inner poisoned").steps_done()
    }

    fn size_bytes(&self) -> usize {
        self.inner.lock().expect("mana inner poisoned").size_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// An app with both halves: science data + an "MPI library" state.
    struct MpiApp {
        science: Vec<u8>,
        /// lower half: endpoint table only valid for this incarnation
        endpoints: Vec<u8>,
        reinit_count: u32,
    }

    impl Checkpointable for MpiApp {
        fn segments(&self) -> Vec<(String, Vec<u8>)> {
            vec![
                ("science".into(), self.science.clone()),
                (format!("{LIB_PREFIX}endpoints"), self.endpoints.clone()),
            ]
        }

        fn restore(&mut self, segments: &[(String, Vec<u8>)]) -> Result<()> {
            for (name, data) in segments {
                match name.as_str() {
                    "science" => self.science = data.clone(),
                    n if n == &format!("{LIB_PREFIX}endpoints") => {
                        self.endpoints = data.clone()
                    }
                    _ => {}
                }
            }
            Ok(())
        }
    }

    fn mana(inner: Arc<Mutex<MpiApp>>) -> ManaState<MpiApp> {
        ManaState::new(inner, Box::new(|app| {
            app.endpoints = b"fresh-endpoints".to_vec();
            app.reinit_count += 1;
            Ok(())
        }))
    }

    #[test]
    fn lib_segments_excluded_from_image() {
        let inner = Arc::new(Mutex::new(MpiApp {
            science: vec![1, 2, 3],
            endpoints: b"node17:4242".to_vec(),
            reinit_count: 0,
        }));
        let m = mana(Arc::clone(&inner));
        let segs = m.segments();
        assert_eq!(segs.len(), 1);
        assert_eq!(segs[0].0, "science");
    }

    #[test]
    fn restore_rebuilds_lower_half() {
        let inner = Arc::new(Mutex::new(MpiApp {
            science: vec![1, 2, 3],
            endpoints: b"node17:4242".to_vec(),
            reinit_count: 0,
        }));
        let m = mana(Arc::clone(&inner));
        let segs = m.segments();

        // "Restart on a different machine": stale lower half.
        let inner2 = Arc::new(Mutex::new(MpiApp {
            science: Vec::new(),
            endpoints: b"STALE".to_vec(),
            reinit_count: 0,
        }));
        let mut m2 = mana(Arc::clone(&inner2));
        m2.restore(&segs).unwrap();
        let app = inner2.lock().unwrap();
        assert_eq!(app.science, vec![1, 2, 3]);
        assert_eq!(app.endpoints, b"fresh-endpoints");
        assert_eq!(app.reinit_count, 1);
    }

    #[test]
    fn exclusion_off_keeps_lib_segments_but_still_reinits() {
        let inner = Arc::new(Mutex::new(MpiApp {
            science: vec![1, 2, 3],
            endpoints: b"node17:4242".to_vec(),
            reinit_count: 0,
        }));
        let m = ManaState::with_exclusion(
            Arc::clone(&inner),
            Box::new(|app: &mut MpiApp| {
                app.endpoints = b"fresh-endpoints".to_vec();
                app.reinit_count += 1;
                Ok(())
            }),
            false,
        );
        let segs = m.segments();
        assert_eq!(segs.len(), 2, "whole-process mode keeps the lower half");
        let inner2 = Arc::new(Mutex::new(MpiApp {
            science: Vec::new(),
            endpoints: b"STALE".to_vec(),
            reinit_count: 0,
        }));
        let mut m2 = ManaState::with_exclusion(
            Arc::clone(&inner2),
            Box::new(|app: &mut MpiApp| {
                app.endpoints = b"fresh-endpoints".to_vec();
                app.reinit_count += 1;
                Ok(())
            }),
            false,
        );
        m2.restore(&segs).unwrap();
        let app = inner2.lock().unwrap();
        assert_eq!(app.science, vec![1, 2, 3]);
        // Restored stale endpoints are rebuilt regardless of the knob.
        assert_eq!(app.endpoints, b"fresh-endpoints");
        assert_eq!(app.reinit_count, 1);
    }

    #[test]
    fn image_shrinks_for_library_heavy_states() {
        let inner = Arc::new(Mutex::new(MpiApp {
            science: vec![0; 1_000],
            endpoints: vec![0; 100_000], // big MPI buffers
            reinit_count: 0,
        }));
        let full_bytes: usize = inner
            .lock()
            .unwrap()
            .segments()
            .iter()
            .map(|(_, d)| d.len())
            .sum();
        let m = mana(inner);
        let mana_bytes: usize = m.segments().iter().map(|(_, d)| d.len()).sum();
        assert!(mana_bytes * 50 < full_bytes, "{mana_bytes} vs {full_bytes}");
    }
}
