//! `dmtcp_command` — one-shot control client for a running coordinator.
//!
//! The NERSC CR module drives checkpoints from job scripts via
//! `dmtcp_command --checkpoint`, finding the coordinator through the
//! `dmtcp_command.<jobid>` rendezvous file the coordinator wrote at start.

use std::net::{SocketAddr, TcpStream};
use std::path::Path;

use crate::dmtcp::protocol::{
    recv_from_coordinator, send_to_coordinator, FromCoordinator, ToCoordinator,
};
use crate::error::{Error, Result};

/// Parse a `dmtcp_command.<jobid>` rendezvous file ("host port\n").
pub fn read_command_file(path: &Path) -> Result<SocketAddr> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::Protocol(format!("{}: {e}", path.display())))?;
    let mut parts = text.split_whitespace();
    let host = parts
        .next()
        .ok_or_else(|| Error::Protocol("empty command file".into()))?;
    let port: u16 = parts
        .next()
        .ok_or_else(|| Error::Protocol("command file missing port".into()))?
        .parse()
        .map_err(|_| Error::Protocol("bad port in command file".into()))?;
    format!("{host}:{port}")
        .parse()
        .map_err(|e| Error::Protocol(format!("bad coordinator address: {e}")))
}

/// Control client bound to one coordinator.
pub struct DmtcpCommand {
    addr: SocketAddr,
}

/// Coordinator status snapshot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CoordStatus {
    /// Registered checkpoint threads.
    pub clients: u32,
    /// Highest completed checkpoint round.
    pub last_ckpt_id: u64,
    /// Coordinator epoch (bumps on coordinator restart).
    pub epoch: u64,
}

/// Result of a requested checkpoint round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CkptResult {
    /// The completed round's id.
    pub ckpt_id: u64,
    /// Images written in the round.
    pub images: u32,
    /// Bytes stored across those images.
    pub total_stored_bytes: u64,
}

impl DmtcpCommand {
    /// A command client for the coordinator at `addr`.
    pub fn new(addr: SocketAddr) -> Self {
        Self { addr }
    }

    /// Connect via a rendezvous file.
    pub fn from_command_file(path: &Path) -> Result<Self> {
        Ok(Self::new(read_command_file(path)?))
    }

    fn roundtrip(&self, msg: &ToCoordinator) -> Result<FromCoordinator> {
        let mut stream = TcpStream::connect(self.addr)?;
        stream.set_nodelay(true).ok();
        send_to_coordinator(&mut stream, msg)?;
        recv_from_coordinator(&mut stream)
    }

    /// `dmtcp_command --checkpoint`: drive a full barrier, blocking until
    /// all images are written.
    pub fn checkpoint(&self) -> Result<CkptResult> {
        match self.roundtrip(&ToCoordinator::CommandCheckpoint)? {
            FromCoordinator::CkptComplete {
                ckpt_id,
                images,
                total_stored_bytes,
            } => Ok(CkptResult {
                ckpt_id,
                images,
                total_stored_bytes,
            }),
            FromCoordinator::Error { message } => Err(Error::Protocol(message)),
            other => Err(Error::Protocol(format!("unexpected reply {other:?}"))),
        }
    }

    /// `dmtcp_command --status`.
    pub fn status(&self) -> Result<CoordStatus> {
        match self.roundtrip(&ToCoordinator::CommandStatus)? {
            FromCoordinator::Status {
                clients,
                last_ckpt_id,
                epoch,
            } => Ok(CoordStatus {
                clients,
                last_ckpt_id,
                epoch,
            }),
            FromCoordinator::Error { message } => Err(Error::Protocol(message)),
            other => Err(Error::Protocol(format!("unexpected reply {other:?}"))),
        }
    }

    /// `dmtcp_command --quit`: kill attached processes, stop the listener.
    pub fn quit(&self) -> Result<()> {
        let mut stream = TcpStream::connect(self.addr)?;
        send_to_coordinator(&mut stream, &ToCoordinator::CommandQuit)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn command_file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("ncr_cmdfile_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("dmtcp_command.777");
        std::fs::write(&p, "127.0.0.1 45123\n").unwrap();
        let addr = read_command_file(&p).unwrap();
        assert_eq!(addr, "127.0.0.1:45123".parse().unwrap());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn command_file_garbage_rejected() {
        let dir = std::env::temp_dir().join(format!("ncr_cmdfile_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for (name, content) in [("a", ""), ("b", "justhost"), ("c", "h p")] {
            let p = dir.join(name);
            std::fs::write(&p, content).unwrap();
            assert!(read_command_file(&p).is_err(), "{content:?} accepted");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
