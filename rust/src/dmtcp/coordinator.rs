//! The central checkpoint coordinator (`dmtcp_coordinator` analog).
//!
//! One coordinator instance manages one computation: worker processes
//! connect over TCP (see [`crate::dmtcp::protocol`]), a checkpoint request
//! drives all of them through the five-phase barrier, and the results are
//! collected into [`ImageInfo`] records. Multiple coordinators can run
//! side-by-side for independent computations (the paper: "with the support
//! for multiple coordinators, the architecture enables independent,
//! parallel checkpointing processes") — each is just a value of
//! [`Coordinator`] on its own port.
//!
//! The coordinator also writes the `dmtcp_command.<jobid>` rendezvous file
//! that the NERSC CR module uses to find it from job scripts.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::dmtcp::image::ImageInfo;
use crate::dmtcp::protocol::{
    recv_to_coordinator, send_from_coordinator, FromCoordinator, Phase, ToCoordinator,
};
use crate::error::{Error, Result};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Bind address; port 0 picks an ephemeral port.
    pub bind: String,
    /// Directory checkpoint images are written into.
    pub ckpt_dir: PathBuf,
    /// gzip images (DMTCP `--gzip`, the NERSC default).
    pub gzip: bool,
    /// When set, write `dmtcp_command.<jobid>` into `command_file_dir`.
    pub jobid: Option<String>,
    /// Where the rendezvous file goes (a job's working directory).
    pub command_file_dir: PathBuf,
    /// Barrier timeout per phase.
    pub phase_timeout: Duration,
    /// When the configured `bind` port is already taken (two jobs booting
    /// concurrently on one host with a pinned `DMTCP_COORD_PORT`), fall
    /// back to an ephemeral port instead of failing the session — the
    /// rendezvous file carries the actual port either way, so nothing
    /// downstream depends on the requested one.
    pub retry_ephemeral: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            bind: "127.0.0.1:0".into(),
            ckpt_dir: std::env::temp_dir().join("nersc_cr_ckpt"),
            gzip: true,
            jobid: None,
            command_file_dir: std::env::temp_dir(),
            phase_timeout: Duration::from_secs(30),
            retry_ephemeral: true,
        }
    }
}

/// Per-connected-process record.
struct ClientConn {
    stream: TcpStream,
    name: String,
    real_pid: u64,
    n_threads: u32,
    /// Gang rank advertised in Hello (`None` for independent processes).
    rank: Option<u32>,
}

/// One in-flight checkpoint round.
struct Round {
    ckpt_id: u64,
    phase: Phase,
    pending: HashSet<u64>,
    images: Vec<ImageInfo>,
    failed: Option<String>,
}

#[derive(Default)]
struct CoordState {
    clients: HashMap<u64, ClientConn>,
    pid_table: crate::dmtcp::virtualization::PidTable,
    round: Option<Round>,
    last_ckpt_id: u64,
    /// Total images ever written (metrics).
    images_written: u64,
    total_stored_bytes: u64,
    /// Raw (logical) bytes the images described — the denominator of the
    /// incremental pipeline's savings.
    total_raw_bytes: u64,
    /// Chunks written to / reused from the content-addressed store.
    total_chunks_written: u64,
    total_chunks_deduped: u64,
}

/// Lifetime checkpoint-store totals across all rounds of a coordinator —
/// the chunks-written-vs-deduped and logical-vs-stored accounting the
/// incremental pipeline is judged by.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreTotals {
    /// Images written across all completed rounds.
    pub images_written: u64,
    /// Bytes actually stored (manifest + new chunks, or whole v1 files).
    pub stored_bytes: u64,
    /// Raw (logical) bytes those images described.
    pub logical_bytes: u64,
    /// Chunks newly written to the content-addressed store.
    pub chunks_written: u64,
    /// Chunks reused instead of rewritten.
    pub chunks_deduped: u64,
}

struct Shared {
    state: Mutex<CoordState>,
    cv: Condvar,
    epoch: u64,
    next_ckpt_id: AtomicU64,
    shutdown: AtomicBool,
    config: CoordinatorConfig,
}

/// A running coordinator. Dropping it shuts the listener down.
pub struct Coordinator {
    shared: Arc<Shared>,
    addr: SocketAddr,
    listener_join: Option<std::thread::JoinHandle<()>>,
    command_file: Option<PathBuf>,
}

impl Coordinator {
    /// Start a coordinator (the paper's `start_coordinator` primitive).
    ///
    /// When the configured bind port is already in use and
    /// [`CoordinatorConfig::retry_ephemeral`] is set (the default), the
    /// coordinator falls back to an ephemeral port on the same address
    /// instead of failing — two computations booting concurrently on one
    /// host both come up, each on its own port.
    pub fn start(config: CoordinatorConfig) -> Result<Self> {
        let listener = match TcpListener::bind(&config.bind) {
            Ok(l) => l,
            Err(e) if e.kind() == std::io::ErrorKind::AddrInUse && config.retry_ephemeral => {
                let host = config
                    .bind
                    .rsplit_once(':')
                    .map(|(h, _)| h)
                    .unwrap_or("127.0.0.1");
                log::warn!(
                    "coordinator bind {} in use; retrying on an ephemeral port",
                    config.bind
                );
                TcpListener::bind(format!("{host}:0"))?
            }
            Err(e) => return Err(e.into()),
        };
        let addr = listener.local_addr()?;
        std::fs::create_dir_all(&config.ckpt_dir)?;

        // Rendezvous file: `dmtcp_command.<jobid>` with "host port".
        // Written to a temp name and renamed into place: rename is atomic
        // on POSIX filesystems, so a concurrent reader (a job script
        // polling for the coordinator) sees either no file or a complete
        // "host port" line — never a partially written one.
        let command_file = match &config.jobid {
            Some(jobid) => {
                let p = config.command_file_dir.join(format!("dmtcp_command.{jobid}"));
                std::fs::create_dir_all(&config.command_file_dir)?;
                let tmp = config.command_file_dir.join(format!(
                    ".dmtcp_command.{jobid}.tmp.{}.{}",
                    std::process::id(),
                    addr.port()
                ));
                std::fs::write(&tmp, format!("{} {}\n", addr.ip(), addr.port()))?;
                if let Err(e) = std::fs::rename(&tmp, &p) {
                    let _ = std::fs::remove_file(&tmp);
                    return Err(e.into());
                }
                Some(p)
            }
            None => None,
        };

        let shared = Arc::new(Shared {
            state: Mutex::new(CoordState {
                pid_table: crate::dmtcp::virtualization::PidTable::new(),
                ..Default::default()
            }),
            cv: Condvar::new(),
            epoch: 1,
            next_ckpt_id: AtomicU64::new(1),
            shutdown: AtomicBool::new(false),
            config,
        });

        let accept_shared = Arc::clone(&shared);
        let listener_join = std::thread::Builder::new()
            .name("dmtcp-coord-accept".into())
            .spawn(move || {
                // Nonblocking accept so shutdown is prompt.
                listener
                    .set_nonblocking(true)
                    .expect("listener nonblocking");
                while !accept_shared.shutdown.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            stream.set_nodelay(true).ok();
                            let s = Arc::clone(&accept_shared);
                            std::thread::Builder::new()
                                .name("dmtcp-coord-client".into())
                                .spawn(move || client_loop(s, stream))
                                .expect("spawn client thread");
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn accept thread");

        Ok(Self {
            shared,
            addr,
            listener_join: Some(listener_join),
            command_file,
        })
    }

    /// The coordinator's socket address (workers connect here).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Path of the rendezvous file, when configured.
    pub fn command_file(&self) -> Option<&Path> {
        self.command_file.as_deref()
    }

    /// Number of currently attached processes.
    pub fn num_clients(&self) -> usize {
        self.shared.state.lock().unwrap().clients.len()
    }

    /// Block until `n` processes are attached (worker startup rendezvous).
    pub fn wait_for_clients(&self, n: usize, timeout: Duration) -> Result<()> {
        let deadline = std::time::Instant::now() + timeout;
        let mut st = self.shared.state.lock().unwrap();
        while st.clients.len() < n {
            let left = deadline.saturating_duration_since(std::time::Instant::now());
            if left.is_zero() {
                return Err(Error::Protocol(format!(
                    "timeout waiting for {n} clients (have {})",
                    st.clients.len()
                )));
            }
            let (g, _) = self.shared.cv.wait_timeout(st, left).unwrap();
            st = g;
        }
        Ok(())
    }

    /// Drive a full five-phase checkpoint barrier across all attached
    /// processes. Returns one [`ImageInfo`] per process.
    pub fn checkpoint_all(&self) -> Result<Vec<ImageInfo>> {
        checkpoint_all_inner(&self.shared)
    }

    /// Drive one all-or-nothing gang checkpoint barrier: every attached
    /// client must carry a gang rank, the ranks must be exactly
    /// `0..expected_ranks`, and the round must produce one image per rank —
    /// anything less is an error and nothing of the round is usable (the
    /// caller publishes the gang manifest only on `Ok`). Returns the
    /// images sorted by rank.
    pub fn checkpoint_gang(&self, expected_ranks: u32) -> Result<Vec<(u32, ImageInfo)>> {
        let rank_of: HashMap<u64, u32> = {
            let st = self.shared.state.lock().unwrap();
            let mut by_vpid = HashMap::new();
            let mut seen = HashSet::new();
            for (&vpid, c) in &st.clients {
                let r = c.rank.ok_or_else(|| {
                    Error::Protocol(format!(
                        "gang checkpoint: client {:?} (vpid {vpid}) advertised no rank",
                        c.name
                    ))
                })?;
                if !seen.insert(r) {
                    return Err(Error::Protocol(format!(
                        "gang checkpoint: rank {r} attached twice"
                    )));
                }
                by_vpid.insert(vpid, r);
            }
            if by_vpid.len() != expected_ranks as usize
                || (0..expected_ranks).any(|r| !seen.contains(&r))
            {
                return Err(Error::Protocol(format!(
                    "gang checkpoint: expected ranks 0..{expected_ranks}, have {} clients",
                    by_vpid.len()
                )));
            }
            by_vpid
        };
        let images = checkpoint_all_inner(&self.shared)?;
        let mut out = Vec::with_capacity(images.len());
        for info in images {
            let r = rank_of.get(&info.vpid).copied().ok_or_else(|| {
                Error::Protocol(format!(
                    "gang checkpoint: image from unknown vpid {}",
                    info.vpid
                ))
            })?;
            out.push((r, info));
        }
        out.sort_by_key(|(r, _)| *r);
        for (i, (r, _)) in out.iter().enumerate() {
            if *r != i as u32 {
                return Err(Error::Protocol(format!(
                    "gang checkpoint: incomplete image set (missing rank {i})"
                )));
            }
        }
        if out.len() != expected_ranks as usize {
            return Err(Error::Protocol(format!(
                "gang checkpoint: {} of {expected_ranks} rank images",
                out.len()
            )));
        }
        Ok(out)
    }

    /// Ensure future round ids start at or above `min`. A fresh
    /// coordinator numbers rounds from 1; a gang restart seeds this from
    /// the restored manifest's round id so round stamps — and with them
    /// the round-stamped rank-image and gang-manifest file names — stay
    /// unique across incarnations. Without it, a later generation's round
    /// 1 would overwrite the committed cut's files that the live gang
    /// manifest still references.
    pub fn bump_ckpt_id_to(&self, min: u64) {
        self.shared.next_ckpt_id.fetch_max(min, Ordering::Relaxed);
    }

    /// Broadcast a kill (preemption) to every attached process.
    pub fn kill_all(&self) {
        let mut st = self.shared.state.lock().unwrap();
        for (vpid, c) in st.clients.iter_mut() {
            if send_from_coordinator(&mut c.stream, &FromCoordinator::Kill).is_err() {
                log::warn!("kill: client {vpid} unreachable");
            }
        }
    }

    /// `(clients, last completed checkpoint id, epoch)`.
    pub fn status(&self) -> (usize, u64, u64) {
        let st = self.shared.state.lock().unwrap();
        (st.clients.len(), st.last_ckpt_id, self.shared.epoch)
    }

    /// Lifetime totals `(images_written, stored_bytes)`.
    pub fn totals(&self) -> (u64, u64) {
        let st = self.shared.state.lock().unwrap();
        (st.images_written, st.total_stored_bytes)
    }

    /// Lifetime checkpoint-store accounting (chunks written vs deduped,
    /// logical vs stored bytes).
    pub fn store_totals(&self) -> StoreTotals {
        let st = self.shared.state.lock().unwrap();
        StoreTotals {
            images_written: st.images_written,
            stored_bytes: st.total_stored_bytes,
            logical_bytes: st.total_raw_bytes,
            chunks_written: st.total_chunks_written,
            chunks_deduped: st.total_chunks_deduped,
        }
    }

    /// Stop accepting, kill attached processes, join the listener.
    pub fn shutdown(&mut self) {
        self.kill_all();
        self.shared.shutdown.store(true, Ordering::Relaxed);
        if let Some(j) = self.listener_join.take() {
            let _ = j.join();
        }
        if let Some(f) = &self.command_file {
            let _ = std::fs::remove_file(f);
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The barrier driver (also reachable from command connections).
fn checkpoint_all_inner(shared: &Arc<Shared>) -> Result<Vec<ImageInfo>> {
    let ckpt_id = shared.next_ckpt_id.fetch_add(1, Ordering::Relaxed);
    let dir = shared.config.ckpt_dir.to_string_lossy().to_string();

    {
        let mut st = shared.state.lock().unwrap();
        if st.round.is_some() {
            return Err(Error::Protocol("checkpoint already in progress".into()));
        }
        if st.clients.is_empty() {
            return Err(Error::Protocol("no clients attached".into()));
        }
        st.round = Some(Round {
            ckpt_id,
            phase: Phase::Suspend,
            pending: HashSet::new(),
            images: Vec::new(),
            failed: None,
        });
    }

    let result = drive_phases(shared, ckpt_id, &dir);

    // Tear down the round record, collect images.
    let mut st = shared.state.lock().unwrap();
    let round = st.round.take().expect("round vanished");
    let failure = match result {
        Err(e) => Some(e),
        Ok(()) => round.failed.map(Error::Protocol),
    };
    if let Some(e) = failure {
        // Abort: survivors may be parked mid-barrier waiting for the next
        // phase that will never come — release them so a failed round
        // costs the computation nothing but the (unpublished) checkpoint.
        for (vpid, c) in st.clients.iter_mut() {
            let msg = FromCoordinator::Phase {
                ckpt_id,
                phase: Phase::Resume,
                dir: dir.clone(),
            };
            if send_from_coordinator(&mut c.stream, &msg).is_err() {
                log::warn!("round {ckpt_id} abort: client {vpid} unreachable");
            }
        }
        return Err(e);
    }
    st.last_ckpt_id = ckpt_id;
    st.images_written += round.images.len() as u64;
    st.total_stored_bytes += round.images.iter().map(|i| i.stored_bytes).sum::<u64>();
    st.total_raw_bytes += round.images.iter().map(|i| i.raw_bytes).sum::<u64>();
    st.total_chunks_written += round.images.iter().map(|i| i.chunks_written).sum::<u64>();
    st.total_chunks_deduped += round.images.iter().map(|i| i.chunks_deduped).sum::<u64>();
    Ok(round.images)
}

fn drive_phases(shared: &Arc<Shared>, ckpt_id: u64, dir: &str) -> Result<()> {
    for phase in Phase::ALL {
        // Broadcast the phase to every (still-attached) client.
        {
            let mut st = shared.state.lock().unwrap();
            let vpids: Vec<u64> = st.clients.keys().copied().collect();
            if vpids.is_empty() {
                return Err(Error::Protocol(format!(
                    "all clients vanished before {phase:?}"
                )));
            }
            let round = st.round.as_mut().expect("no active round");
            round.phase = phase;
            round.pending = vpids.iter().copied().collect();
            for vpid in vpids {
                let c = st.clients.get_mut(&vpid).unwrap();
                let msg = FromCoordinator::Phase {
                    ckpt_id,
                    phase,
                    dir: dir.to_string(),
                };
                if send_from_coordinator(&mut c.stream, &msg).is_err() {
                    log::warn!("phase {phase:?}: client {vpid} unreachable");
                    // All-or-nothing: a client unreachable mid-barrier
                    // fails the whole round (the reader thread will reap
                    // the connection; the round must not "succeed" with a
                    // partial image set).
                    let round = st.round.as_mut().unwrap();
                    round.pending.remove(&vpid);
                    round.failed = Some(format!(
                        "client vpid {vpid} unreachable during {phase:?} of round {ckpt_id}"
                    ));
                }
            }
        }
        // Await all acks for this phase. A round marked failed (client
        // death or unreachability) aborts promptly — the teardown in
        // `checkpoint_all_inner` converts it into the error and resumes
        // the survivors; waiting out the timeout would only stall them.
        let deadline = std::time::Instant::now() + shared.config.phase_timeout;
        let mut st = shared.state.lock().unwrap();
        loop {
            let round = st.round.as_ref().expect("no active round");
            if round.failed.is_some() {
                return Ok(());
            }
            if round.pending.is_empty() {
                break;
            }
            let left = deadline.saturating_duration_since(std::time::Instant::now());
            if left.is_zero() {
                return Err(Error::Protocol(format!(
                    "phase {phase:?} timed out with {} clients pending",
                    round.pending.len()
                )));
            }
            let (g, _) = shared.cv.wait_timeout(st, left).unwrap();
            st = g;
        }
    }
    Ok(())
}

/// Per-connection reader loop: registration, acks, commands, departures.
fn client_loop(shared: Arc<Shared>, mut stream: TcpStream) {
    let mut vpid: Option<u64> = None;
    loop {
        let msg = match recv_to_coordinator(&mut stream) {
            Ok(m) => m,
            Err(_) => break, // disconnect
        };
        match msg {
            ToCoordinator::Hello {
                real_pid,
                name,
                n_threads,
                restored_vpid,
                rank,
            } => {
                let write_stream = match stream.try_clone() {
                    Ok(s) => s,
                    Err(_) => break,
                };
                let mut st = shared.state.lock().unwrap();
                let assigned = match restored_vpid {
                    Some(v) => match st.pid_table.adopt(v, real_pid) {
                        Ok(()) => v,
                        Err(e) => {
                            let _ = send_from_coordinator(
                                &mut stream,
                                &FromCoordinator::Error {
                                    message: e.to_string(),
                                },
                            );
                            continue;
                        }
                    },
                    None => match st.pid_table.register(real_pid) {
                        Ok(v) => v,
                        Err(e) => {
                            let _ = send_from_coordinator(
                                &mut stream,
                                &FromCoordinator::Error {
                                    message: e.to_string(),
                                },
                            );
                            continue;
                        }
                    },
                };
                st.clients.insert(
                    assigned,
                    ClientConn {
                        stream: write_stream,
                        name: name.clone(),
                        real_pid,
                        n_threads,
                        rank,
                    },
                );
                vpid = Some(assigned);
                shared.cv.notify_all();
                drop(st);
                log::debug!("client {name} attached as vpid {assigned} (pid {real_pid})");
                let _ = send_from_coordinator(
                    &mut stream,
                    &FromCoordinator::Welcome {
                        vpid: assigned,
                        epoch: shared.epoch,
                    },
                );
            }
            ToCoordinator::PhaseAck {
                vpid: v,
                ckpt_id,
                phase,
            } => {
                let mut st = shared.state.lock().unwrap();
                if let Some(round) = st.round.as_mut() {
                    if round.ckpt_id == ckpt_id && round.phase == phase {
                        round.pending.remove(&v);
                        shared.cv.notify_all();
                    } else {
                        log::warn!(
                            "stale ack from vpid {v}: round {ckpt_id}/{phase:?} vs {}/{:?}",
                            round.ckpt_id,
                            round.phase
                        );
                    }
                }
            }
            ToCoordinator::CkptDone {
                vpid: v,
                ckpt_id,
                path,
                stored_bytes,
                raw_bytes,
                write_secs,
                chunks_written,
                chunks_deduped,
            } => {
                let mut st = shared.state.lock().unwrap();
                if let Some(round) = st.round.as_mut() {
                    if round.ckpt_id == ckpt_id {
                        round.images.push(ImageInfo {
                            vpid: v,
                            ckpt_id,
                            path: PathBuf::from(path),
                            stored_bytes,
                            raw_bytes,
                            write_secs,
                            chunks_written,
                            chunks_deduped,
                        });
                    }
                }
            }
            ToCoordinator::Goodbye { vpid: v } => {
                let mut st = shared.state.lock().unwrap();
                st.clients.remove(&v);
                let _ = st.pid_table.unregister(v);
                remove_from_round(&mut st, v, "left");
                shared.cv.notify_all();
                break;
            }
            ToCoordinator::CommandCheckpoint => {
                let reply = match checkpoint_all_inner(&shared) {
                    Ok(images) => FromCoordinator::CkptComplete {
                        ckpt_id: {
                            let st = shared.state.lock().unwrap();
                            st.last_ckpt_id
                        },
                        images: images.len() as u32,
                        total_stored_bytes: images.iter().map(|i| i.stored_bytes).sum(),
                    },
                    Err(e) => FromCoordinator::Error {
                        message: e.to_string(),
                    },
                };
                let _ = send_from_coordinator(&mut stream, &reply);
            }
            ToCoordinator::CommandStatus => {
                let st = shared.state.lock().unwrap();
                let reply = FromCoordinator::Status {
                    clients: st.clients.len() as u32,
                    last_ckpt_id: st.last_ckpt_id,
                    epoch: shared.epoch,
                };
                drop(st);
                let _ = send_from_coordinator(&mut stream, &reply);
            }
            ToCoordinator::CommandQuit => {
                let mut st = shared.state.lock().unwrap();
                for (_, c) in st.clients.iter_mut() {
                    let _ = send_from_coordinator(&mut c.stream, &FromCoordinator::Kill);
                }
                drop(st);
                shared.shutdown.store(true, Ordering::Relaxed);
                break;
            }
        }
    }
    // Disconnect cleanup: a worker vanishing mid-round must not hang the
    // barrier (the round is marked failed instead).
    if let Some(v) = vpid {
        let mut st = shared.state.lock().unwrap();
        if st.clients.remove(&v).is_some() {
            let _ = st.pid_table.unregister(v);
            remove_from_round(&mut st, v, "disconnected");
            log::debug!("client vpid {v} detached");
        }
        shared.cv.notify_all();
    }
}

fn remove_from_round(st: &mut CoordState, vpid: u64, why: &str) {
    if let Some(round) = st.round.as_mut() {
        if round.pending.remove(&vpid) {
            round.failed = Some(format!(
                "client vpid {vpid} {why} during {:?} of round {}",
                round.phase, round.ckpt_id
            ));
        }
    }
}

/// Client metadata snapshot (for `dmtcp_command --status`-style listings).
pub fn client_table(coord: &Coordinator) -> BTreeMap<u64, (String, u64, u32)> {
    let st = coord.shared.state.lock().unwrap();
    st.clients
        .iter()
        .map(|(&v, c)| (v, (c.name.clone(), c.real_pid, c.n_threads)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    /// Regression test for concurrent boots colliding on a pinned port:
    /// with `retry_ephemeral` (the default) the second coordinator falls
    /// back to an ephemeral port instead of failing; with it disabled the
    /// collision surfaces as an error.
    #[test]
    fn pinned_port_collision_falls_back_to_ephemeral() {
        // Occupy a concrete port first.
        let blocker = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let taken = blocker.local_addr().unwrap().port();
        let dir = std::env::temp_dir().join(format!("ncr_coord_port_{}", std::process::id()));
        let cfg = |retry: bool| CoordinatorConfig {
            bind: format!("127.0.0.1:{taken}"),
            ckpt_dir: dir.join("ckpt"),
            retry_ephemeral: retry,
            ..Default::default()
        };
        let coord = Coordinator::start(cfg(true)).expect("ephemeral fallback");
        assert_ne!(coord.addr().port(), taken, "fallback must pick a new port");
        assert!(Coordinator::start(cfg(false)).is_err(), "no-retry must fail");
        drop(coord);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Regression test for the rendezvous-file race: the file is renamed
    /// into place atomically, so a reader polling it while coordinators
    /// come and go must only ever observe a complete "host port" line
    /// (or no file at all) — never a prefix of one.
    #[test]
    fn rendezvous_file_is_never_partially_visible() {
        let dir = std::env::temp_dir().join(format!("ncr_coord_atomic_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("dmtcp_command.race");
        let stop = Arc::new(AtomicBool::new(false));

        let reader = {
            let (path, stop) = (path.clone(), Arc::clone(&stop));
            std::thread::spawn(move || {
                let mut observed = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    match std::fs::read_to_string(&path) {
                        Ok(content) => {
                            observed += 1;
                            // A visible file must be the complete line.
                            assert!(
                                content.ends_with('\n'),
                                "partial rendezvous content: {content:?}"
                            );
                            let mut parts = content.trim().split(' ');
                            let host = parts.next().expect("host field");
                            let port = parts.next().expect("port field");
                            assert!(host.parse::<std::net::IpAddr>().is_ok(), "{content:?}");
                            assert!(port.parse::<u16>().is_ok(), "{content:?}");
                            assert_eq!(parts.next(), None, "{content:?}");
                        }
                        Err(e) => {
                            assert_eq!(
                                e.kind(),
                                std::io::ErrorKind::NotFound,
                                "unexpected read error: {e}"
                            );
                        }
                    }
                }
                observed
            })
        };

        for _ in 0..40 {
            let coord = Coordinator::start(CoordinatorConfig {
                ckpt_dir: dir.join("ckpt"),
                jobid: Some("race".into()),
                command_file_dir: dir.clone(),
                ..Default::default()
            })
            .unwrap();
            assert_eq!(coord.command_file(), Some(path.as_path()));
            drop(coord); // shutdown removes the file
        }
        stop.store(true, Ordering::Relaxed);
        let observed = reader.join().expect("reader panicked (partial content?)");
        assert!(observed > 0, "reader never saw the rendezvous file");

        // No staging debris: every temp file was renamed or cleaned up.
        let debris: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(debris.is_empty(), "staging files left behind: {debris:?}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
