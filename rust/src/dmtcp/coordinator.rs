//! The per-job checkpoint coordinator handle (`dmtcp_coordinator` analog).
//!
//! One [`Coordinator`] manages one computation (one *job*). Since the
//! multi-tenant rewrite it is a handle over the event-driven
//! [`CoordinatorDaemon`](crate::dmtcp::daemon::CoordinatorDaemon):
//!
//! * [`Coordinator::start`] boots a **private** daemon and registers the
//!   job on it — the default, and exactly the old one-coordinator-per-job
//!   deployment (the paper: "with the support for multiple coordinators,
//!   the architecture enables independent, parallel checkpointing
//!   processes");
//! * [`Coordinator::attach`] registers the job on a **shared** daemon, so
//!   whole fleets multiplex over one port with O(1) coordinator threads.
//!
//! Either way the handle's API is identical: checkpoint barriers, gang
//! rounds, kills, status and store totals are all scoped to this job and
//! this job only. The handle also writes (and on teardown removes) the
//! `dmtcp_command.<jobid>` rendezvous file that the NERSC CR module uses
//! to find the coordinator from job scripts.

use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::dmtcp::daemon::{CoordinatorDaemon, DaemonConfig, JobSpec};
use crate::dmtcp::image::ImageInfo;
use crate::error::{Error, Result};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Bind address; port 0 picks an ephemeral port. (Ignored by
    /// [`Coordinator::attach`] — the shared daemon is already bound.)
    pub bind: String,
    /// Directory checkpoint images are written into.
    pub ckpt_dir: PathBuf,
    /// gzip images (DMTCP `--gzip`, the NERSC default).
    pub gzip: bool,
    /// When set, write `dmtcp_command.<jobid>` into `command_file_dir`.
    pub jobid: Option<String>,
    /// Where the rendezvous file goes (a job's working directory).
    pub command_file_dir: PathBuf,
    /// Barrier timeout per phase.
    pub phase_timeout: Duration,
    /// When the configured `bind` port is already taken (two jobs booting
    /// concurrently on one host with a pinned `DMTCP_COORD_PORT`), fall
    /// back to an ephemeral port instead of failing the session — the
    /// rendezvous file carries the actual port either way, so nothing
    /// downstream depends on the requested one.
    pub retry_ephemeral: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            bind: "127.0.0.1:0".into(),
            ckpt_dir: std::env::temp_dir().join("nersc_cr_ckpt"),
            gzip: true,
            jobid: None,
            command_file_dir: std::env::temp_dir(),
            phase_timeout: Duration::from_secs(30),
            retry_ephemeral: true,
        }
    }
}

/// Lifetime checkpoint-store totals across all rounds of a coordinator —
/// the chunks-written-vs-deduped and logical-vs-stored accounting the
/// incremental pipeline is judged by.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreTotals {
    /// Images written across all completed rounds.
    pub images_written: u64,
    /// Bytes actually stored (manifest + new chunks, or whole v1 files).
    pub stored_bytes: u64,
    /// Raw (logical) bytes those images described.
    pub logical_bytes: u64,
    /// Chunks newly written to the content-addressed store.
    pub chunks_written: u64,
    /// Chunks reused instead of rewritten.
    pub chunks_deduped: u64,
}

/// Distinguishes anonymous (no-jobid) registrations on one daemon.
static ANON_JOB: AtomicU64 = AtomicU64::new(1);

/// A running coordinator handle for one job. Dropping it tears the job
/// down (and, for a private daemon, the daemon with it).
pub struct Coordinator {
    daemon: Arc<CoordinatorDaemon>,
    /// Private-daemon handles shut the daemon down on teardown; shared
    /// handles leave it running for the other jobs.
    owns_daemon: bool,
    job: String,
    addr: SocketAddr,
    command_file: Option<PathBuf>,
    closed: bool,
}

impl Coordinator {
    /// Start a coordinator (the paper's `start_coordinator` primitive):
    /// boot a private daemon and register this job on it.
    ///
    /// When the configured bind port is already in use and
    /// [`CoordinatorConfig::retry_ephemeral`] is set (the default), the
    /// daemon falls back to an ephemeral port on the same address instead
    /// of failing — two computations booting concurrently on one host
    /// both come up, each on its own port.
    pub fn start(config: CoordinatorConfig) -> Result<Self> {
        let daemon = CoordinatorDaemon::start(DaemonConfig {
            bind: config.bind.clone(),
            retry_ephemeral: config.retry_ephemeral,
            auto_register_jobs: false,
            ..Default::default()
        })?;
        Self::register_on(daemon, true, config)
    }

    /// Register this job on an already-running shared daemon: the
    /// multi-tenant path. The handle behaves exactly like a private
    /// coordinator, but its clients multiplex over the daemon's one port
    /// and its teardown leaves the daemon (and every other job) running.
    pub fn attach(daemon: &Arc<CoordinatorDaemon>, config: CoordinatorConfig) -> Result<Self> {
        Self::register_on(Arc::clone(daemon), false, config)
    }

    fn register_on(
        daemon: Arc<CoordinatorDaemon>,
        owns_daemon: bool,
        config: CoordinatorConfig,
    ) -> Result<Self> {
        let job = config.jobid.clone().unwrap_or_else(|| {
            format!(
                "anon-{}-{}",
                std::process::id(),
                ANON_JOB.fetch_add(1, Ordering::Relaxed)
            )
        });
        daemon.register_job(&JobSpec {
            job: job.clone(),
            ckpt_dir: config.ckpt_dir.clone(),
            phase_timeout: config.phase_timeout,
        })?;
        let addr = daemon.addr();

        // Rendezvous file: `dmtcp_command.<jobid>` with "host port".
        // Written to a temp name and renamed into place: rename is atomic
        // on POSIX filesystems, so a concurrent reader (a job script
        // polling for the coordinator) sees either no file or a complete
        // "host port" line — never a partially written one.
        let command_file = match &config.jobid {
            Some(jobid) => {
                let write = || -> Result<PathBuf> {
                    let p = config.command_file_dir.join(format!("dmtcp_command.{jobid}"));
                    std::fs::create_dir_all(&config.command_file_dir)?;
                    let tmp = config.command_file_dir.join(format!(
                        ".dmtcp_command.{jobid}.tmp.{}.{}",
                        std::process::id(),
                        addr.port()
                    ));
                    std::fs::write(&tmp, format!("{} {}\n", addr.ip(), addr.port()))?;
                    if let Err(e) = std::fs::rename(&tmp, &p) {
                        let _ = std::fs::remove_file(&tmp);
                        return Err(e.into());
                    }
                    Ok(p)
                };
                match write() {
                    Ok(p) => Some(p),
                    Err(e) => {
                        daemon.close_job(&job);
                        if owns_daemon {
                            daemon.shutdown();
                        }
                        return Err(e);
                    }
                }
            }
            None => None,
        };

        Ok(Self {
            daemon,
            owns_daemon,
            job,
            addr,
            command_file,
            closed: false,
        })
    }

    /// The coordinator's socket address (workers connect here). For a
    /// shared daemon this is the one port every job multiplexes over.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// This job's routing key on the daemon (what `Hello { job }` must
    /// carry; exported to clients as `DMTCP_JOB`).
    pub fn job(&self) -> &str {
        &self.job
    }

    /// The underlying daemon (shared by every co-located job's handle).
    pub fn daemon(&self) -> &Arc<CoordinatorDaemon> {
        &self.daemon
    }

    /// Path of the rendezvous file, when configured.
    pub fn command_file(&self) -> Option<&Path> {
        self.command_file.as_deref()
    }

    /// Number of currently attached processes (this job only).
    pub fn num_clients(&self) -> usize {
        self.daemon.num_clients(&self.job)
    }

    /// Block until `n` processes are attached (worker startup rendezvous).
    pub fn wait_for_clients(&self, n: usize, timeout: Duration) -> Result<()> {
        self.daemon.wait_for_clients(&self.job, n, timeout)
    }

    /// Drive a full five-phase checkpoint barrier across all attached
    /// processes of this job. Returns one [`ImageInfo`] per process.
    pub fn checkpoint_all(&self) -> Result<Vec<ImageInfo>> {
        let mut sp = crate::trace::span(crate::trace::names::COORD_CHECKPOINT)
            .with("job", || self.job.clone());
        let res = self
            .daemon
            .checkpoint_job(&self.job, None)
            .map(|(images, _ranks)| images);
        match &res {
            Ok(images) => sp.note_u64("images", images.len() as u64),
            Err(e) => sp.fail(&e.to_string()),
        }
        res
    }

    /// Drive one all-or-nothing gang checkpoint barrier: every attached
    /// client must carry a gang rank, the ranks must be exactly
    /// `0..expected_ranks`, and the round must produce one image per rank —
    /// anything less is an error and nothing of the round is usable (the
    /// caller publishes the gang manifest only on `Ok`). Returns the
    /// images sorted by rank.
    pub fn checkpoint_gang(&self, expected_ranks: u32) -> Result<Vec<(u32, ImageInfo)>> {
        let mut sp = crate::trace::span(crate::trace::names::COORD_CHECKPOINT_GANG)
            .with("job", || self.job.clone())
            .with_u64("ranks", expected_ranks as u64);
        let res = self.checkpoint_gang_inner(expected_ranks);
        match &res {
            Ok(out) => sp.note_u64("images", out.len() as u64),
            Err(e) => sp.fail(&e.to_string()),
        }
        res
    }

    fn checkpoint_gang_inner(&self, expected_ranks: u32) -> Result<Vec<(u32, ImageInfo)>> {
        let (images, rank_of) = self.daemon.checkpoint_job(&self.job, Some(expected_ranks))?;
        let mut out = Vec::with_capacity(images.len());
        for info in images {
            let r = rank_of.get(&info.vpid).copied().ok_or_else(|| {
                Error::Protocol(format!(
                    "gang checkpoint: image from unknown vpid {}",
                    info.vpid
                ))
            })?;
            out.push((r, info));
        }
        out.sort_by_key(|(r, _)| *r);
        for (i, (r, _)) in out.iter().enumerate() {
            if *r != i as u32 {
                return Err(Error::Protocol(format!(
                    "gang checkpoint: incomplete image set (missing rank {i})"
                )));
            }
        }
        if out.len() != expected_ranks as usize {
            return Err(Error::Protocol(format!(
                "gang checkpoint: {} of {expected_ranks} rank images",
                out.len()
            )));
        }
        Ok(out)
    }

    /// Ensure future round ids start at or above `min`. A fresh
    /// coordinator numbers rounds from 1; a gang restart seeds this from
    /// the restored manifest's round id so round stamps — and with them
    /// the round-stamped rank-image and gang-manifest file names — stay
    /// unique across incarnations. Without it, a later generation's round
    /// 1 would overwrite the committed cut's files that the live gang
    /// manifest still references.
    pub fn bump_ckpt_id_to(&self, min: u64) {
        self.daemon.bump_ckpt_id(&self.job, min);
    }

    /// Broadcast a kill (preemption) to every attached process of this
    /// job; other jobs on a shared daemon are untouched.
    pub fn kill_all(&self) {
        self.daemon.kill_job(&self.job);
    }

    /// Arm a one-shot fabric partition for this job's next barrier
    /// broadcast of `phase`: the given gang ranks become unreachable
    /// mid-phase, the round fails, and survivors are resumed (see
    /// [`CoordinatorDaemon::inject_partition`]). Private and shared
    /// coordinators behave identically — the injection lives on the
    /// daemon either way.
    pub fn inject_partition(
        &self,
        phase: crate::dmtcp::protocol::Phase,
        ranks: &[u32],
    ) -> Result<()> {
        self.daemon.inject_partition(&self.job, phase, ranks)
    }

    /// `(clients, last completed checkpoint id, epoch)`.
    pub fn status(&self) -> (usize, u64, u64) {
        self.daemon.job_status(&self.job)
    }

    /// Lifetime totals `(images_written, stored_bytes)`.
    pub fn totals(&self) -> (u64, u64) {
        self.daemon.job_totals(&self.job)
    }

    /// Lifetime checkpoint-store accounting (chunks written vs deduped,
    /// logical vs stored bytes).
    pub fn store_totals(&self) -> StoreTotals {
        self.daemon.job_store_totals(&self.job)
    }

    /// Tear this job down: kill its clients, remove it from the daemon's
    /// routing table, remove its rendezvous file — and for a private
    /// daemon, stop the daemon too. Idempotent.
    pub fn shutdown(&mut self) {
        if self.closed {
            return;
        }
        self.closed = true;
        self.daemon.kill_job(&self.job);
        self.daemon.close_job(&self.job);
        // Teardown always removes the rendezvous file: a stale
        // `dmtcp_command.<jobid>` in a shared workdir would point later
        // discovery at a dead (or worse, recycled) host/port.
        if let Some(f) = &self.command_file {
            let _ = std::fs::remove_file(f);
        }
        if self.owns_daemon {
            self.daemon.shutdown();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Client metadata snapshot (for `dmtcp_command --status`-style listings).
pub fn client_table(coord: &Coordinator) -> BTreeMap<u64, (String, u64, u32)> {
    coord.daemon.job_client_table(&coord.job)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    /// Regression test for concurrent boots colliding on a pinned port:
    /// with `retry_ephemeral` (the default) the second coordinator falls
    /// back to an ephemeral port instead of failing; with it disabled the
    /// collision surfaces as an error.
    #[test]
    fn pinned_port_collision_falls_back_to_ephemeral() {
        // Occupy a concrete port first.
        let blocker = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let taken = blocker.local_addr().unwrap().port();
        let dir = std::env::temp_dir().join(format!("ncr_coord_port_{}", std::process::id()));
        let cfg = |retry: bool| CoordinatorConfig {
            bind: format!("127.0.0.1:{taken}"),
            ckpt_dir: dir.join("ckpt"),
            retry_ephemeral: retry,
            ..Default::default()
        };
        let coord = Coordinator::start(cfg(true)).expect("ephemeral fallback");
        assert_ne!(coord.addr().port(), taken, "fallback must pick a new port");
        assert!(Coordinator::start(cfg(false)).is_err(), "no-retry must fail");
        drop(coord);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Regression test for the rendezvous-file race: the file is renamed
    /// into place atomically, so a reader polling it while coordinators
    /// come and go must only ever observe a complete "host port" line
    /// (or no file at all) — never a prefix of one.
    #[test]
    fn rendezvous_file_is_never_partially_visible() {
        let dir = std::env::temp_dir().join(format!("ncr_coord_atomic_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("dmtcp_command.race");
        let stop = Arc::new(AtomicBool::new(false));

        let reader = {
            let (path, stop) = (path.clone(), Arc::clone(&stop));
            std::thread::spawn(move || {
                let mut observed = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    match std::fs::read_to_string(&path) {
                        Ok(content) => {
                            observed += 1;
                            // A visible file must be the complete line.
                            assert!(
                                content.ends_with('\n'),
                                "partial rendezvous content: {content:?}"
                            );
                            let mut parts = content.trim().split(' ');
                            let host = parts.next().expect("host field");
                            let port = parts.next().expect("port field");
                            assert!(host.parse::<std::net::IpAddr>().is_ok(), "{content:?}");
                            assert!(port.parse::<u16>().is_ok(), "{content:?}");
                            assert_eq!(parts.next(), None, "{content:?}");
                        }
                        Err(e) => {
                            assert_eq!(
                                e.kind(),
                                std::io::ErrorKind::NotFound,
                                "unexpected read error: {e}"
                            );
                        }
                    }
                }
                observed
            })
        };

        for _ in 0..40 {
            let coord = Coordinator::start(CoordinatorConfig {
                ckpt_dir: dir.join("ckpt"),
                jobid: Some("race".into()),
                command_file_dir: dir.clone(),
                ..Default::default()
            })
            .unwrap();
            assert_eq!(coord.command_file(), Some(path.as_path()));
            drop(coord); // shutdown removes the file
        }
        stop.store(true, Ordering::Relaxed);
        let observed = reader.join().expect("reader panicked (partial content?)");
        assert!(observed > 0, "reader never saw the rendezvous file");

        // No staging debris: every temp file was renamed or cleaned up.
        let debris: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(debris.is_empty(), "staging files left behind: {debris:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Satellite regression: teardown removes the `dmtcp_command.<jobid>`
    /// rendezvous file in a *shared* workdir, so a restart incarnation's
    /// discovery can never read a dead coordinator's host/port — and on a
    /// shared daemon, closing one job removes only that job's file.
    #[test]
    fn teardown_removes_rendezvous_file_in_shared_workdir() {
        let dir = std::env::temp_dir().join(format!("ncr_coord_rdv_gc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = |jobid: &str| CoordinatorConfig {
            ckpt_dir: dir.join("ckpt"),
            jobid: Some(jobid.into()),
            command_file_dir: dir.clone(),
            ..Default::default()
        };

        // Incarnation 0 comes and goes; its file must go with it.
        let first = Coordinator::start(cfg("job.i00")).unwrap();
        let first_file = first.command_file().unwrap().to_path_buf();
        assert!(first_file.exists());
        drop(first);
        assert!(
            !first_file.exists(),
            "stale rendezvous file survived teardown"
        );

        // Restart-after-teardown in the same (shared) workdir: discovery
        // only ever sees the live incarnation's file.
        let second = Coordinator::start(cfg("job.i01")).unwrap();
        let found: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().starts_with("dmtcp_command."))
            .collect();
        assert_eq!(found.len(), 1, "stale files accumulated: {found:?}");
        let addr = crate::dmtcp::command::read_command_file(second.command_file().unwrap())
            .expect("live rendezvous file parses");
        assert_eq!(addr, second.addr());

        // Shared daemon: two jobs, two files, per-job removal.
        let daemon = CoordinatorDaemon::start(DaemonConfig::default()).unwrap();
        let mut a = Coordinator::attach(&daemon, cfg("shared.a")).unwrap();
        let b = Coordinator::attach(&daemon, cfg("shared.b")).unwrap();
        let (fa, fb) = (
            a.command_file().unwrap().to_path_buf(),
            b.command_file().unwrap().to_path_buf(),
        );
        assert!(fa.exists() && fb.exists());
        a.shutdown();
        assert!(!fa.exists(), "closed job's rendezvous file not removed");
        assert!(fb.exists(), "sibling job's rendezvous file removed");
        let addr_b = crate::dmtcp::command::read_command_file(&fb).unwrap();
        assert_eq!(addr_b, b.addr());
        drop(b);
        drop(second);
        std::fs::remove_dir_all(&dir).ok();
    }
}
