//! Checkpoint image format (the `.dmtcp` file analog).
//!
//! DMTCP writes one image per process containing the process's memory
//! regions plus enough metadata (environment, file descriptors, plugin
//! records) to reconstruct the runtime context after restart, optionally
//! piped through gzip. This module reproduces that design:
//!
//! ```text
//! magic  "NCRDMTCP"            8 bytes
//! version u32                  (1 = full image, 2 = chunk manifest)
//! flags   u32                  bit 0: body is gzip-compressed
//! body_crc u32                 CRC32 of the *stored* (possibly gzip'd) body
//! body_len u64                 stored body length
//! body  { header | segments }  see below
//! ```
//!
//! Version-1 body layout (before optional gzip):
//! `header`: virtual pid, process name, checkpoint id, generation,
//! steps-done hint, env-var map, fd-table entries, plugin records.
//! `segments`: count, then per segment `name, raw_len, raw_crc32, bytes`.
//!
//! Version 2 keeps the same outer frame and header encoding, but the
//! segment payload is a *manifest of chunk references* into the per-workdir
//! content-addressed [`crate::dmtcp::store::ImageStore`] — see that module
//! for the incremental pipeline. [`CheckpointImage::read_file`] reads both
//! versions transparently (the v1 full-image reader is the fallback for
//! pre-chunk images).
//!
//! Integrity is checked at three levels on load: magic/version, whole-body
//! CRC, and per-segment CRC — a truncated or bit-flipped image is rejected
//! rather than silently restoring garbage (the paper's "redundantly storing
//! checkpoint images" resilience story starts with *detecting* bad images).
//! Writes are atomic (`.tmp` + rename) so a preemption mid-write never
//! leaves a half image at the published path.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use flate2::read::GzDecoder;
use flate2::write::GzEncoder;
use flate2::Compression;

use crate::error::{Error, Result};
use crate::util::bytes::{ByteReader, PutBytes};

pub(crate) const MAGIC: &[u8; 8] = b"NCRDMTCP";
pub(crate) const VERSION_FULL: u32 = 1;
pub(crate) const VERSION_MANIFEST: u32 = 2;
/// A gang manifest: the consistent-cut record tying one checkpoint round's
/// per-rank images together (see [`crate::dmtcp::store::GangManifest`]).
pub(crate) const VERSION_GANG: u32 = 3;
pub(crate) const FLAG_GZIP: u32 = 1;

/// A virtualized file-descriptor table entry captured in the image.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FdEntry {
    /// Virtual descriptor number (stable across restarts).
    pub vfd: u32,
    /// Path or channel identity the descriptor points at.
    pub path: String,
    /// Append-mode hint (the paper's job scripts append output across
    /// requeues; restored writers must not truncate).
    pub append: bool,
}

/// Everything in a checkpoint image except the raw memory segments.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ImageHeader {
    /// Virtual PID of the checkpointed process.
    pub vpid: u64,
    /// Process name (for `dmtcp_restart` display and routing).
    pub name: String,
    /// Monotonic checkpoint id assigned by the coordinator.
    pub ckpt_id: u64,
    /// Restart generation (0 for first run, +1 per restart).
    pub generation: u32,
    /// Application progress hint (steps completed), for schedulers/logs.
    pub steps_done: u64,
    /// Captured environment variables.
    pub env: BTreeMap<String, String>,
    /// Captured (virtualized) file descriptors.
    pub fds: Vec<FdEntry>,
    /// Named plugin records (event-hook contributed blobs).
    pub plugin_records: BTreeMap<String, Vec<u8>>,
}

/// A full checkpoint image: header + named memory segments.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct CheckpointImage {
    /// Process metadata (identity, env, fds, plugin records).
    pub header: ImageHeader,
    /// Named memory segments (the "regions" of the process).
    pub segments: Vec<(String, Vec<u8>)>,
}

/// Encode an [`ImageHeader`] into `b` (shared by the v1 body and the v2
/// manifest body — the header wire format is identical across versions).
pub(crate) fn encode_header(h: &ImageHeader, b: &mut Vec<u8>) {
    b.put_u64(h.vpid);
    b.put_lp_str(&h.name);
    b.put_u64(h.ckpt_id);
    b.put_u32(h.generation);
    b.put_u64(h.steps_done);
    b.put_u32(h.env.len() as u32);
    for (k, v) in &h.env {
        b.put_lp_str(k);
        b.put_lp_str(v);
    }
    b.put_u32(h.fds.len() as u32);
    for fd in &h.fds {
        b.put_u32(fd.vfd);
        b.put_lp_str(&fd.path);
        b.put_u8(fd.append as u8);
    }
    b.put_u32(h.plugin_records.len() as u32);
    for (k, v) in &h.plugin_records {
        b.put_lp_str(k);
        b.put_lp_bytes(v);
    }
}

/// Decode an [`ImageHeader`] (inverse of [`encode_header`]); the reader is
/// left positioned at the first byte after the header.
pub(crate) fn decode_header(r: &mut ByteReader<'_>) -> Result<ImageHeader> {
    let vpid = r.get_u64()?;
    let name = r.get_lp_str()?;
    let ckpt_id = r.get_u64()?;
    let generation = r.get_u32()?;
    let steps_done = r.get_u64()?;
    let mut env = BTreeMap::new();
    for _ in 0..r.get_u32()? {
        let k = r.get_lp_str()?;
        let v = r.get_lp_str()?;
        env.insert(k, v);
    }
    let mut fds = Vec::new();
    for _ in 0..r.get_u32()? {
        fds.push(FdEntry {
            vfd: r.get_u32()?,
            path: r.get_lp_str()?,
            append: r.get_u8()? != 0,
        });
    }
    let mut plugin_records = BTreeMap::new();
    for _ in 0..r.get_u32()? {
        let k = r.get_lp_str()?;
        let v = r.get_lp_bytes()?.to_vec();
        plugin_records.insert(k, v);
    }
    Ok(ImageHeader {
        vpid,
        name,
        ckpt_id,
        generation,
        steps_done,
        env,
        fds,
        plugin_records,
    })
}

/// Wrap `body` in the outer frame (magic, version, flags, body CRC, length).
pub(crate) fn frame(version: u32, flags: u32, body: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(body.len() + 28);
    out.put_bytes(MAGIC);
    out.put_u32(version);
    out.put_u32(flags);
    out.put_u32(crc32fast::hash(body));
    out.put_u64(body.len() as u64);
    out.put_bytes(body);
    out
}

/// Verify the outer frame of `bytes` (magic, body CRC, exact length) and
/// return `(version, flags, body)`. Version validation is the caller's job
/// — this is shared by the v1 and v2 readers.
pub(crate) fn unframe(bytes: &[u8]) -> Result<(u32, u32, &[u8])> {
    let mut r = ByteReader::new(bytes);
    let magic = r.get_bytes(8)?;
    if magic != MAGIC {
        return Err(Error::Image("bad magic: not a checkpoint image".into()));
    }
    let version = r.get_u32()?;
    let flags = r.get_u32()?;
    let body_crc = r.get_u32()?;
    let body_len = r.get_u64()? as usize;
    let body = r.get_bytes(body_len)?;
    if r.remaining() != 0 {
        return Err(Error::Image("trailing bytes after image body".into()));
    }
    let got = crc32fast::hash(body);
    if got != body_crc {
        return Err(Error::Image(format!(
            "body CRC mismatch: stored {body_crc:08x}, computed {got:08x}"
        )));
    }
    Ok((version, flags, body))
}

impl CheckpointImage {
    /// Serialize the body (header + segments), before compression.
    fn encode_body(&self) -> Vec<u8> {
        let mut b = Vec::new();
        encode_header(&self.header, &mut b);
        b.put_u32(self.segments.len() as u32);
        for (name, data) in &self.segments {
            b.put_lp_str(name);
            b.put_u32(data.len() as u32);
            b.put_u32(crc32fast::hash(data));
            b.put_bytes(data);
        }
        b
    }

    fn decode_body(body: &[u8]) -> Result<Self> {
        let mut r = ByteReader::new(body);
        let header = decode_header(&mut r)?;
        let n_seg = r.get_u32()?;
        let mut segments = Vec::with_capacity(n_seg as usize);
        for _ in 0..n_seg {
            let name = r.get_lp_str()?;
            let len = r.get_u32()? as usize;
            let crc = r.get_u32()?;
            let data = r.get_bytes(len)?.to_vec();
            let got = crc32fast::hash(&data);
            if got != crc {
                return Err(Error::Image(format!(
                    "segment {name:?} CRC mismatch: stored {crc:08x}, computed {got:08x}"
                )));
            }
            segments.push((name, data));
        }
        if r.remaining() != 0 {
            return Err(Error::Image(format!(
                "{} trailing bytes after last segment",
                r.remaining()
            )));
        }
        Ok(Self { header, segments })
    }

    /// Serialize to bytes as a version-1 full image, optionally
    /// gzip-compressing the body (DMTCP's `--gzip`, the NERSC default).
    pub fn to_bytes(&self, gzip: bool) -> Result<Vec<u8>> {
        let raw = self.encode_body();
        let body = if gzip {
            let mut enc = GzEncoder::new(Vec::new(), Compression::fast());
            enc.write_all(&raw)?;
            enc.finish()?
        } else {
            raw
        };
        Ok(frame(VERSION_FULL, if gzip { FLAG_GZIP } else { 0 }, &body))
    }

    /// Parse a version-1 full image from bytes, verifying magic, version
    /// and CRCs. Version-2 manifests need their chunk store and go through
    /// [`crate::dmtcp::store::read_image_file`] (or [`Self::read_file`]).
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let (version, flags, body) = unframe(bytes)?;
        if version != VERSION_FULL {
            return Err(Error::Image(format!(
                "unsupported image version {version} for the in-memory reader \
                 (v2 manifests are read through their chunk store)"
            )));
        }
        Self::from_unframed(flags, body)
    }

    /// Decode a v1 body whose outer frame was already verified with
    /// [`unframe`] — readers that dispatch on the version avoid a second
    /// whole-body CRC pass this way.
    pub(crate) fn from_unframed(flags: u32, body: &[u8]) -> Result<Self> {
        let raw = if flags & FLAG_GZIP != 0 {
            let mut dec = GzDecoder::new(body);
            let mut out = Vec::new();
            dec.read_to_end(&mut out)
                .map_err(|e| Error::Image(format!("gzip: {e}")))?;
            out
        } else {
            body.to_vec()
        };
        Self::decode_body(&raw)
    }

    /// Write atomically to `path` (`.tmp` + rename) as a version-1 full
    /// image. Returns stored size. (The incremental v2 writer is
    /// [`crate::dmtcp::store::ImageStore::write_incremental`].)
    pub fn write_file(&self, path: &Path, gzip: bool) -> Result<u64> {
        let bytes = self.to_bytes(gzip)?;
        atomic_write(path, &bytes)?;
        Ok(bytes.len() as u64)
    }

    /// Read and verify an image file of either version: v1 full images
    /// decode standalone, v2 manifests reassemble from the chunk store
    /// sitting next to the image (`<dir>/store/`).
    pub fn read_file(path: &Path) -> Result<Self> {
        crate::dmtcp::store::read_image_file(path)
    }

    /// Total raw (uncompressed) segment bytes.
    pub fn raw_segment_bytes(&self) -> u64 {
        self.segments.iter().map(|(_, d)| d.len() as u64).sum()
    }
}

fn tmp_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_owned();
    os.push(".tmp");
    PathBuf::from(os)
}

/// Atomic publish: write to `<path>.tmp` then rename, so a preemption
/// mid-write never leaves a half image (or half chunk) at the final path.
pub(crate) fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = tmp_path(path);
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(&tmp, bytes)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Summary of one written checkpoint (coordinator bookkeeping + metrics).
#[derive(Debug, Clone)]
pub struct ImageInfo {
    /// The checkpointed process's virtual pid.
    pub vpid: u64,
    /// Checkpoint round the image belongs to.
    pub ckpt_id: u64,
    /// Where the image was written.
    pub path: PathBuf,
    /// Stored byte size: the whole file for v1 full images; manifest bytes
    /// plus *newly written* chunk bytes for v2 incremental images.
    pub stored_bytes: u64,
    /// Raw (logical, uncompressed) segment byte size.
    pub raw_bytes: u64,
    /// Wall time spent writing, seconds.
    pub write_secs: f64,
    /// Chunks newly written to the content-addressed store (0 for v1).
    pub chunks_written: u64,
    /// Chunks already present in the store and reused (0 for v1).
    pub chunks_deduped: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CheckpointImage {
        let mut env = BTreeMap::new();
        env.insert("DMTCP_COORD_HOST".into(), "127.0.0.1".into());
        env.insert("SLURM_JOB_ID".into(), "123456".into());
        let mut plugin_records = BTreeMap::new();
        plugin_records.insert("timer".into(), vec![1, 2, 3]);
        CheckpointImage {
            header: ImageHeader {
                vpid: 40001,
                name: "geant4_ws".into(),
                ckpt_id: 7,
                generation: 2,
                steps_done: 1234,
                env,
                fds: vec![
                    FdEntry { vfd: 1, path: "/out/job.out".into(), append: true },
                    FdEntry { vfd: 5, path: "/data/geom.bin".into(), append: false },
                ],
                plugin_records,
            },
            segments: vec![
                ("pos".into(), vec![0u8; 1024]),
                ("rng".into(), (0..=255).cycle().take(4096).collect()),
            ],
        }
    }

    #[test]
    fn roundtrip_plain_and_gzip() {
        let img = sample();
        for gzip in [false, true] {
            let bytes = img.to_bytes(gzip).unwrap();
            let back = CheckpointImage::from_bytes(&bytes).unwrap();
            assert_eq!(img, back, "gzip={gzip}");
        }
    }

    #[test]
    fn gzip_mode_compresses_redundant_images() {
        // The vendored deflate does real LZ77 + fixed-Huffman coding, so
        // this sample (a zero-filled segment plus a byte-cycle segment)
        // must come out strictly smaller than the plain encoding — and
        // still round-trip bit-identically.
        let img = sample();
        let plain = img.to_bytes(false).unwrap();
        let gz = img.to_bytes(true).unwrap();
        assert!(
            gz.len() < plain.len(),
            "gzip'd image did not shrink: {} vs {}",
            gz.len(),
            plain.len()
        );
        let back = CheckpointImage::from_bytes(&gz).unwrap();
        assert_eq!(back.to_bytes(false).unwrap(), plain);
    }

    #[test]
    fn body_corruption_detected() {
        let img = sample();
        let mut bytes = img.to_bytes(false).unwrap();
        let n = bytes.len();
        bytes[n - 10] ^= 0xFF;
        let err = CheckpointImage::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("CRC"), "{err}");
    }

    #[test]
    fn truncation_detected() {
        let img = sample();
        let bytes = img.to_bytes(true).unwrap();
        for cut in [0, 4, 10, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                CheckpointImage::from_bytes(&bytes[..cut]).is_err(),
                "cut={cut} accepted"
            );
        }
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = sample().to_bytes(false).unwrap();
        bytes[0] = b'X';
        assert!(CheckpointImage::from_bytes(&bytes).is_err());
    }

    #[test]
    fn file_roundtrip_and_atomicity() {
        let dir = std::env::temp_dir().join(format!("ncr_img_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p1.dmtcp");
        let img = sample();
        let stored = img.write_file(&path, true).unwrap();
        assert!(stored > 0);
        assert!(!tmp_path(&path).exists(), "tmp file left behind");
        let back = CheckpointImage::read_file(&path).unwrap();
        assert_eq!(img, back);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_image_roundtrips() {
        let img = CheckpointImage::default();
        let back = CheckpointImage::from_bytes(&img.to_bytes(true).unwrap()).unwrap();
        assert_eq!(img, back);
    }
}
