//! Coordinator ⇄ checkpoint-thread wire protocol.
//!
//! DMTCP's checkpoint threads talk to the central coordinator over TCP
//! sockets; so do ours. Frames are `u32 LE length || tag u8 || payload`,
//! encoded with the same little-endian primitives as the image format.
//!
//! The checkpoint barrier is the classic DMTCP five-phase protocol; every
//! phase is a full round (coordinator broadcasts `Phase`, every client acks)
//! so a checkpoint is *all-or-nothing* across the computation:
//!
//! ```text
//! SUSPEND    park all user threads at their next ckpt-point
//! DRAIN      flush in-flight channel/socket data
//! CHECKPOINT serialize memory segments + metadata to the image file
//! REFILL     re-prime drained channels
//! RESUME     release user threads
//! ```

use std::io::{Read, Write};
use std::net::TcpStream;

use crate::error::{Error, Result};
use crate::util::bytes::{ByteReader, PutBytes};

/// Barrier phases, in order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// Park every user thread at its safe-point gate.
    Suspend = 0,
    /// Drain in-flight messages so the cut is consistent.
    Drain = 1,
    /// Write the checkpoint image.
    Checkpoint = 2,
    /// Re-inject drained messages.
    Refill = 3,
    /// Release the gates; user threads continue.
    Resume = 4,
}

impl Phase {
    /// Every phase, in barrier order.
    pub const ALL: [Phase; 5] = [
        Phase::Suspend,
        Phase::Drain,
        Phase::Checkpoint,
        Phase::Refill,
        Phase::Resume,
    ];

    /// Decode a wire phase byte (inverse of `phase as u8`).
    pub fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            0 => Phase::Suspend,
            1 => Phase::Drain,
            2 => Phase::Checkpoint,
            3 => Phase::Refill,
            4 => Phase::Resume,
            _ => return Err(Error::Protocol(format!("bad phase {v}"))),
        })
    }
}

/// Messages from a checkpoint thread (or command client) to the coordinator.
#[derive(Debug, Clone, PartialEq)]
pub enum ToCoordinator {
    /// Register a process. `restored_vpid` re-attaches a restarted process
    /// under its original virtual pid. `rank` identifies the process's
    /// position in a gang computation (`None` for independent processes);
    /// the coordinator uses it to assemble per-rank image sets into one
    /// gang manifest. `job` scopes the client to one job's state machine on
    /// a multi-tenant coordinator daemon: a tagged Hello is routed to
    /// exactly that job (unknown tags are rejected with a typed error), an
    /// untagged Hello is only accepted when the daemon hosts a single job.
    Hello {
        /// The registering process's real (host) pid.
        real_pid: u64,
        /// Process name (image discovery is scoped by it).
        name: String,
        /// Worker threads the barrier must gate.
        n_threads: u32,
        /// Original virtual pid to re-adopt (restart path).
        restored_vpid: Option<u64>,
        /// Gang rank of the process, if any.
        rank: Option<u32>,
        /// Job tag for multi-tenant daemon routing.
        job: Option<String>,
    },
    /// Ack for one barrier phase of one checkpoint round.
    PhaseAck {
        /// The acking process's virtual pid.
        vpid: u64,
        /// Checkpoint round being acked.
        ckpt_id: u64,
        /// Phase being acked.
        phase: Phase,
    },
    /// Checkpoint phase completion detail (image written).
    CkptDone {
        /// The writing process's virtual pid.
        vpid: u64,
        /// Checkpoint round the image belongs to.
        ckpt_id: u64,
        /// Image path, relative to the checkpoint directory.
        path: String,
        /// Bytes actually stored (compressed / deduplicated).
        stored_bytes: u64,
        /// Raw (logical, uncompressed) segment bytes.
        raw_bytes: u64,
        /// Wall seconds spent writing the image.
        write_secs: f64,
        /// Chunks newly written to the content-addressed store (0 for
        /// full images).
        chunks_written: u64,
        /// Chunks reused instead of rewritten (0 for full images).
        chunks_deduped: u64,
    },
    /// Graceful detach.
    Goodbye {
        /// The departing process's virtual pid.
        vpid: u64,
    },
    /// One-off command-client request: trigger a checkpoint round
    /// (`dmtcp_command --checkpoint` analog).
    CommandCheckpoint,
    /// One-off command-client request: status snapshot.
    CommandStatus,
    /// One-off command-client request: shut the coordinator down.
    CommandQuit,
}

/// Messages from the coordinator to a checkpoint thread / command client.
#[derive(Debug, Clone, PartialEq)]
pub enum FromCoordinator {
    /// Registration reply: assigned (or re-adopted) virtual pid.
    Welcome {
        /// The virtual pid the coordinator assigned.
        vpid: u64,
        /// Coordinator epoch (bumps on coordinator restart).
        epoch: u64,
    },
    /// Enter a barrier phase of checkpoint round `ckpt_id`. `dir` is the
    /// destination directory during the `Checkpoint` phase.
    Phase {
        /// Checkpoint round the phase belongs to.
        ckpt_id: u64,
        /// Which barrier phase to enter.
        phase: Phase,
        /// Image destination directory (Checkpoint phase only).
        dir: String,
    },
    /// Terminate the user process (preemption path).
    Kill,
    /// Status snapshot (command-client reply).
    Status {
        /// Registered checkpoint threads.
        clients: u32,
        /// Highest completed checkpoint round.
        last_ckpt_id: u64,
        /// Coordinator epoch.
        epoch: u64,
    },
    /// Checkpoint round completed (command-client reply).
    CkptComplete {
        /// The completed round's id.
        ckpt_id: u64,
        /// Images written in the round.
        images: u32,
        /// Bytes stored across those images.
        total_stored_bytes: u64,
    },
    /// Generic error reply.
    Error {
        /// Human-readable cause.
        message: String,
    },
}

// ---- encoding ------------------------------------------------------------

/// Encode a client→coordinator message body (tag byte + payload, no
/// frame). Public so the protocol torture suite can corrupt known-good
/// encodings byte-by-byte.
pub fn encode_to_coordinator(msg: &ToCoordinator) -> Vec<u8> {
    let mut b = Vec::new();
    match msg {
        ToCoordinator::Hello {
            real_pid,
            name,
            n_threads,
            restored_vpid,
            rank,
            job,
        } => {
            b.put_u8(0);
            b.put_u64(*real_pid);
            b.put_lp_str(name);
            b.put_u32(*n_threads);
            match restored_vpid {
                Some(v) => {
                    b.put_u8(1);
                    b.put_u64(*v);
                }
                None => b.put_u8(0),
            }
            match rank {
                Some(r) => {
                    b.put_u8(1);
                    b.put_u32(*r);
                }
                None => b.put_u8(0),
            }
            match job {
                Some(j) => {
                    b.put_u8(1);
                    b.put_lp_str(j);
                }
                None => b.put_u8(0),
            }
        }
        ToCoordinator::PhaseAck { vpid, ckpt_id, phase } => {
            b.put_u8(1);
            b.put_u64(*vpid);
            b.put_u64(*ckpt_id);
            b.put_u8(*phase as u8);
        }
        ToCoordinator::CkptDone {
            vpid,
            ckpt_id,
            path,
            stored_bytes,
            raw_bytes,
            write_secs,
            chunks_written,
            chunks_deduped,
        } => {
            b.put_u8(2);
            b.put_u64(*vpid);
            b.put_u64(*ckpt_id);
            b.put_lp_str(path);
            b.put_u64(*stored_bytes);
            b.put_u64(*raw_bytes);
            b.put_f64(*write_secs);
            b.put_u64(*chunks_written);
            b.put_u64(*chunks_deduped);
        }
        ToCoordinator::Goodbye { vpid } => {
            b.put_u8(3);
            b.put_u64(*vpid);
        }
        ToCoordinator::CommandCheckpoint => b.put_u8(4),
        ToCoordinator::CommandStatus => b.put_u8(5),
        ToCoordinator::CommandQuit => b.put_u8(6),
    }
    b
}

/// Decode the presence byte of an optional field strictly: anything other
/// than 0 or 1 is a protocol error, not a silent `None` — a bit-flipped
/// flag must not quietly drop a restart's virtual pid or a gang rank.
fn get_opt_flag(r: &mut ByteReader<'_>, what: &str) -> Result<bool> {
    match r.get_u8().map_err(|e| Error::Protocol(e.to_string()))? {
        0 => Ok(false),
        1 => Ok(true),
        v => Err(Error::Protocol(format!("bad {what} presence byte {v}"))),
    }
}

/// Decode a client→coordinator message body (inverse of
/// [`encode_to_coordinator`]). Public for the protocol torture suite.
pub fn decode_to_coordinator(buf: &[u8]) -> Result<ToCoordinator> {
    let mut r = ByteReader::new(buf);
    let tag = r.get_u8()?;
    Ok(match tag {
        0 => ToCoordinator::Hello {
            real_pid: r.get_u64()?,
            name: r.get_lp_str()?,
            n_threads: r.get_u32()?,
            restored_vpid: if get_opt_flag(&mut r, "restored_vpid")? {
                Some(r.get_u64()?)
            } else {
                None
            },
            rank: if get_opt_flag(&mut r, "rank")? {
                Some(r.get_u32()?)
            } else {
                None
            },
            job: if get_opt_flag(&mut r, "job")? {
                Some(r.get_lp_str()?)
            } else {
                None
            },
        },
        1 => ToCoordinator::PhaseAck {
            vpid: r.get_u64()?,
            ckpt_id: r.get_u64()?,
            phase: Phase::from_u8(r.get_u8()?)?,
        },
        2 => ToCoordinator::CkptDone {
            vpid: r.get_u64()?,
            ckpt_id: r.get_u64()?,
            path: r.get_lp_str()?,
            stored_bytes: r.get_u64()?,
            raw_bytes: r.get_u64()?,
            write_secs: r.get_f64()?,
            chunks_written: r.get_u64()?,
            chunks_deduped: r.get_u64()?,
        },
        3 => ToCoordinator::Goodbye { vpid: r.get_u64()? },
        4 => ToCoordinator::CommandCheckpoint,
        5 => ToCoordinator::CommandStatus,
        6 => ToCoordinator::CommandQuit,
        _ => return Err(Error::Protocol(format!("bad ToCoordinator tag {tag}"))),
    })
    .and_then(|m| reject_trailing(&r, m))
}

/// A frame longer than its message is as malformed as one shorter: reject
/// trailing bytes so corruption in a length prefix cannot smuggle extra
/// payload past the decoder.
fn reject_trailing<T>(r: &ByteReader<'_>, msg: T) -> Result<T> {
    if r.remaining() != 0 {
        return Err(Error::Protocol(format!(
            "{} trailing bytes after message",
            r.remaining()
        )));
    }
    Ok(msg)
}

/// Encode a coordinator→client message body (tag byte + payload, no
/// frame). Public for the protocol torture suite.
pub fn encode_from_coordinator(msg: &FromCoordinator) -> Vec<u8> {
    let mut b = Vec::new();
    match msg {
        FromCoordinator::Welcome { vpid, epoch } => {
            b.put_u8(0);
            b.put_u64(*vpid);
            b.put_u64(*epoch);
        }
        FromCoordinator::Phase { ckpt_id, phase, dir } => {
            b.put_u8(1);
            b.put_u64(*ckpt_id);
            b.put_u8(*phase as u8);
            b.put_lp_str(dir);
        }
        FromCoordinator::Kill => b.put_u8(2),
        FromCoordinator::Status {
            clients,
            last_ckpt_id,
            epoch,
        } => {
            b.put_u8(3);
            b.put_u32(*clients);
            b.put_u64(*last_ckpt_id);
            b.put_u64(*epoch);
        }
        FromCoordinator::CkptComplete {
            ckpt_id,
            images,
            total_stored_bytes,
        } => {
            b.put_u8(4);
            b.put_u64(*ckpt_id);
            b.put_u32(*images);
            b.put_u64(*total_stored_bytes);
        }
        FromCoordinator::Error { message } => {
            b.put_u8(5);
            b.put_lp_str(message);
        }
    }
    b
}

/// Decode a coordinator→client message body (inverse of
/// [`encode_from_coordinator`]). Public for the protocol torture suite.
pub fn decode_from_coordinator(buf: &[u8]) -> Result<FromCoordinator> {
    let mut r = ByteReader::new(buf);
    let tag = r.get_u8()?;
    Ok(match tag {
        0 => FromCoordinator::Welcome {
            vpid: r.get_u64()?,
            epoch: r.get_u64()?,
        },
        1 => FromCoordinator::Phase {
            ckpt_id: r.get_u64()?,
            phase: Phase::from_u8(r.get_u8()?)?,
            dir: r.get_lp_str()?,
        },
        2 => FromCoordinator::Kill,
        3 => FromCoordinator::Status {
            clients: r.get_u32()?,
            last_ckpt_id: r.get_u64()?,
            epoch: r.get_u64()?,
        },
        4 => FromCoordinator::CkptComplete {
            ckpt_id: r.get_u64()?,
            images: r.get_u32()?,
            total_stored_bytes: r.get_u64()?,
        },
        5 => FromCoordinator::Error {
            message: r.get_lp_str()?,
        },
        _ => return Err(Error::Protocol(format!("bad FromCoordinator tag {tag}"))),
    })
    .and_then(|m| reject_trailing(&r, m))
}

// ---- framing ---------------------------------------------------------------

/// Upper bound on one frame's payload; an oversized length prefix is
/// rejected before any allocation or read happens.
pub const MAX_FRAME: u32 = 16 * 1024 * 1024;

fn write_frame(stream: &mut TcpStream, payload: &[u8]) -> Result<()> {
    let len = payload.len() as u32;
    if len > MAX_FRAME {
        return Err(Error::Protocol(format!("frame too large: {len}")));
    }
    stream.write_all(&len.to_le_bytes())?;
    stream.write_all(payload)?;
    stream.flush()?;
    Ok(())
}

fn read_frame(stream: &mut TcpStream) -> Result<Vec<u8>> {
    let mut lenb = [0u8; 4];
    stream.read_exact(&mut lenb)?;
    let len = u32::from_le_bytes(lenb);
    if len > MAX_FRAME {
        return Err(Error::Protocol(format!("frame too large: {len}")));
    }
    let mut buf = vec![0u8; len as usize];
    stream.read_exact(&mut buf)?;
    Ok(buf)
}

/// Send a client→coordinator message.
pub fn send_to_coordinator(stream: &mut TcpStream, msg: &ToCoordinator) -> Result<()> {
    write_frame(stream, &encode_to_coordinator(msg))
}

/// Receive a client→coordinator message.
pub fn recv_to_coordinator(stream: &mut TcpStream) -> Result<ToCoordinator> {
    decode_to_coordinator(&read_frame(stream)?)
}

/// Send a coordinator→client message.
pub fn send_from_coordinator(stream: &mut TcpStream, msg: &FromCoordinator) -> Result<()> {
    write_frame(stream, &encode_from_coordinator(msg))
}

/// Receive a coordinator→client message.
pub fn recv_from_coordinator(stream: &mut TcpStream) -> Result<FromCoordinator> {
    decode_from_coordinator(&read_frame(stream)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn to_coordinator_roundtrip() {
        let msgs = vec![
            ToCoordinator::Hello {
                real_pid: 123,
                name: "worker-0".into(),
                n_threads: 4,
                restored_vpid: None,
                rank: None,
                job: None,
            },
            ToCoordinator::Hello {
                real_pid: 9,
                name: "w".into(),
                n_threads: 1,
                restored_vpid: Some(40_001),
                rank: Some(3),
                job: Some("cr-719g41i00".into()),
            },
            ToCoordinator::PhaseAck {
                vpid: 40_001,
                ckpt_id: 3,
                phase: Phase::Drain,
            },
            ToCoordinator::CkptDone {
                vpid: 40_001,
                ckpt_id: 3,
                path: "/ckpt/p.dmtcp".into(),
                stored_bytes: 1_000,
                raw_bytes: 4_000,
                write_secs: 0.25,
                chunks_written: 3,
                chunks_deduped: 61,
            },
            ToCoordinator::Goodbye { vpid: 40_001 },
            ToCoordinator::CommandCheckpoint,
            ToCoordinator::CommandStatus,
            ToCoordinator::CommandQuit,
        ];
        for m in msgs {
            let enc = encode_to_coordinator(&m);
            assert_eq!(decode_to_coordinator(&enc).unwrap(), m);
        }
    }

    #[test]
    fn from_coordinator_roundtrip() {
        let msgs = vec![
            FromCoordinator::Welcome { vpid: 40_000, epoch: 2 },
            FromCoordinator::Phase {
                ckpt_id: 9,
                phase: Phase::Checkpoint,
                dir: "/ckpt".into(),
            },
            FromCoordinator::Kill,
            FromCoordinator::Status {
                clients: 3,
                last_ckpt_id: 9,
                epoch: 2,
            },
            FromCoordinator::CkptComplete {
                ckpt_id: 9,
                images: 3,
                total_stored_bytes: 12_345,
            },
            FromCoordinator::Error { message: "nope".into() },
        ];
        for m in msgs {
            let enc = encode_from_coordinator(&m);
            assert_eq!(decode_from_coordinator(&enc).unwrap(), m);
        }
    }

    #[test]
    fn phase_order_and_codes() {
        for (i, p) in Phase::ALL.iter().enumerate() {
            assert_eq!(*p as u8, i as u8);
            assert_eq!(Phase::from_u8(i as u8).unwrap(), *p);
        }
        assert!(Phase::from_u8(9).is_err());
    }

    #[test]
    fn garbage_rejected() {
        assert!(decode_to_coordinator(&[99]).is_err());
        assert!(decode_from_coordinator(&[77, 1, 2]).is_err());
        assert!(decode_to_coordinator(&[]).is_err());
    }

    #[test]
    fn strict_option_flags_and_trailing_bytes_rejected() {
        let good = encode_to_coordinator(&ToCoordinator::Hello {
            real_pid: 1,
            name: "w".into(),
            n_threads: 1,
            restored_vpid: None,
            rank: None,
            job: None,
        });
        // A bit-flipped presence byte must be an error, not a silent None
        // — for every optional field, including the job routing tag.
        for back in 1..=3 {
            // [.., restored_vpid flag, rank flag, job flag]
            let mut bad_flag = good.clone();
            let flag_at = bad_flag.len() - back;
            bad_flag[flag_at] = 7;
            assert!(decode_to_coordinator(&bad_flag).is_err(), "flag -{back}");
        }
        // Trailing bytes beyond the message are rejected in both directions.
        let mut trailing = good;
        trailing.push(0);
        assert!(decode_to_coordinator(&trailing).is_err());
        let mut trailing = encode_from_coordinator(&FromCoordinator::Kill);
        trailing.push(9);
        assert!(decode_from_coordinator(&trailing).is_err());
    }

    #[test]
    fn framing_over_real_sockets() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let m = recv_to_coordinator(&mut s).unwrap();
            assert_eq!(
                m,
                ToCoordinator::PhaseAck {
                    vpid: 1,
                    ckpt_id: 2,
                    phase: Phase::Resume
                }
            );
            send_from_coordinator(&mut s, &FromCoordinator::Kill).unwrap();
        });
        let mut c = TcpStream::connect(addr).unwrap();
        send_to_coordinator(
            &mut c,
            &ToCoordinator::PhaseAck {
                vpid: 1,
                ckpt_id: 2,
                phase: Phase::Resume,
            },
        )
        .unwrap();
        assert_eq!(recv_from_coordinator(&mut c).unwrap(), FromCoordinator::Kill);
        t.join().unwrap();
    }
}
