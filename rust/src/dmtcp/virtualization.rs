//! Process-level virtualization tables (DMTCP's pid/fd translation layer).
//!
//! DMTCP wraps system calls so applications only ever see *virtual*
//! identifiers; after a restart the real pids/fds differ but the virtual
//! ones — the only ones the application stored — still resolve. This module
//! is that translation layer for the simulated processes: a bijective
//! virtual↔real pid table and a virtual fd table that records how to
//! re-materialize each descriptor.

use std::collections::BTreeMap;

use crate::dmtcp::image::FdEntry;
use crate::error::{Error, Result};

/// Bijective virtual-pid ↔ real-pid table.
///
/// Invariants (property-tested): each virtual pid maps to exactly one real
/// pid and vice versa; `rebind` preserves the virtual set while replacing
/// real ids (what happens at restart).
#[derive(Debug, Clone, Default)]
pub struct PidTable {
    v2r: BTreeMap<u64, u64>,
    r2v: BTreeMap<u64, u64>,
    next_vpid: u64,
}

impl PidTable {
    /// An empty table; virtual pids start in the reserved high band.
    pub fn new() -> Self {
        Self {
            v2r: BTreeMap::new(),
            r2v: BTreeMap::new(),
            // DMTCP starts virtual pids in a reserved high band.
            next_vpid: 40_000,
        }
    }

    /// Register a fresh process: allocates and returns its virtual pid.
    pub fn register(&mut self, real_pid: u64) -> Result<u64> {
        if self.r2v.contains_key(&real_pid) {
            return Err(Error::Protocol(format!(
                "real pid {real_pid} already registered"
            )));
        }
        let vpid = self.next_vpid;
        self.next_vpid += 1;
        self.v2r.insert(vpid, real_pid);
        self.r2v.insert(real_pid, vpid);
        Ok(vpid)
    }

    /// Rebind an existing virtual pid to a new real pid (restart path).
    pub fn rebind(&mut self, vpid: u64, new_real: u64) -> Result<()> {
        let old_real = *self
            .v2r
            .get(&vpid)
            .ok_or_else(|| Error::Protocol(format!("unknown virtual pid {vpid}")))?;
        if let Some(&owner) = self.r2v.get(&new_real) {
            if owner != vpid {
                return Err(Error::Protocol(format!(
                    "real pid {new_real} already bound to vpid {owner}"
                )));
            }
        }
        self.r2v.remove(&old_real);
        self.v2r.insert(vpid, new_real);
        self.r2v.insert(new_real, vpid);
        Ok(())
    }

    /// Re-insert a virtual pid restored from an image (keeps its old vpid).
    pub fn adopt(&mut self, vpid: u64, real_pid: u64) -> Result<()> {
        if self.v2r.contains_key(&vpid) {
            return Err(Error::Protocol(format!("vpid {vpid} already present")));
        }
        if self.r2v.contains_key(&real_pid) {
            return Err(Error::Protocol(format!(
                "real pid {real_pid} already registered"
            )));
        }
        self.v2r.insert(vpid, real_pid);
        self.r2v.insert(real_pid, vpid);
        self.next_vpid = self.next_vpid.max(vpid + 1);
        Ok(())
    }

    /// Drop a virtual pid (and its real mapping).
    pub fn unregister(&mut self, vpid: u64) -> Result<()> {
        let real = self
            .v2r
            .remove(&vpid)
            .ok_or_else(|| Error::Protocol(format!("unknown virtual pid {vpid}")))?;
        self.r2v.remove(&real);
        Ok(())
    }

    /// The real pid behind `vpid`, if registered.
    pub fn real_of(&self, vpid: u64) -> Option<u64> {
        self.v2r.get(&vpid).copied()
    }

    /// The virtual pid assigned to `real`, if registered.
    pub fn virtual_of(&self, real: u64) -> Option<u64> {
        self.r2v.get(&real).copied()
    }

    /// Registered pid pairs.
    pub fn len(&self) -> usize {
        self.v2r.len()
    }

    /// Whether no pid is registered.
    pub fn is_empty(&self) -> bool {
        self.v2r.is_empty()
    }

    /// Every registered virtual pid, ascending.
    pub fn virtual_pids(&self) -> impl Iterator<Item = u64> + '_ {
        self.v2r.keys().copied()
    }

    /// Check the bijection invariant (used by property tests).
    pub fn check_bijection(&self) -> bool {
        self.v2r.len() == self.r2v.len()
            && self
                .v2r
                .iter()
                .all(|(v, r)| self.r2v.get(r) == Some(v))
    }
}

/// What a virtual descriptor points at (how to re-materialize it).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FdKind {
    /// Regular file; `append` selects open mode on restore.
    File { path: String, append: bool },
    /// A socket to the coordinator (re-established, not restored).
    CoordinatorSocket,
    /// Standard output/error routed to the batch system's log.
    BatchLog { path: String },
}

/// Virtual fd table: application-visible fds that survive restart.
#[derive(Debug, Clone, Default)]
pub struct FdTable {
    entries: BTreeMap<u32, FdKind>,
    next_vfd: u32,
}

impl FdTable {
    /// An empty table; virtual fds start above the std streams.
    pub fn new() -> Self {
        Self {
            entries: BTreeMap::new(),
            next_vfd: 3, // 0..2 conventionally std streams
        }
    }

    /// Open a new virtual descriptor.
    pub fn open(&mut self, kind: FdKind) -> u32 {
        let vfd = self.next_vfd;
        self.next_vfd += 1;
        self.entries.insert(vfd, kind);
        vfd
    }

    /// Close a virtual descriptor.
    pub fn close(&mut self, vfd: u32) -> Result<()> {
        self.entries
            .remove(&vfd)
            .map(|_| ())
            .ok_or_else(|| Error::Protocol(format!("close of unknown vfd {vfd}")))
    }

    /// Look a virtual descriptor up.
    pub fn get(&self, vfd: u32) -> Option<&FdKind> {
        self.entries.get(&vfd)
    }

    /// Open virtual descriptors.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether no descriptor is open.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Capture into image entries. Coordinator sockets are *not* captured:
    /// they are re-established by the restart protocol (DMTCP does the
    /// same — the socket plugin drains and recreates connections).
    pub fn capture(&self) -> Vec<FdEntry> {
        self.entries
            .iter()
            .filter_map(|(&vfd, kind)| match kind {
                FdKind::File { path, append } => Some(FdEntry {
                    vfd,
                    path: path.clone(),
                    append: *append,
                }),
                FdKind::BatchLog { path } => Some(FdEntry {
                    vfd,
                    path: format!("batchlog:{path}"),
                    append: true,
                }),
                FdKind::CoordinatorSocket => None,
            })
            .collect()
    }

    /// Restore from image entries (restart path). Existing entries are
    /// replaced; the coordinator socket slot is re-created by the caller.
    pub fn restore(entries: &[FdEntry]) -> Self {
        let mut t = Self::new();
        for e in entries {
            let kind = match e.path.strip_prefix("batchlog:") {
                Some(p) => FdKind::BatchLog { path: p.to_string() },
                None => FdKind::File {
                    path: e.path.clone(),
                    append: e.append,
                },
            };
            t.entries.insert(e.vfd, kind);
            t.next_vfd = t.next_vfd.max(e.vfd + 1);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pid_register_and_lookup() {
        let mut t = PidTable::new();
        let v1 = t.register(101).unwrap();
        let v2 = t.register(102).unwrap();
        assert_ne!(v1, v2);
        assert_eq!(t.real_of(v1), Some(101));
        assert_eq!(t.virtual_of(102), Some(v2));
        assert!(t.check_bijection());
    }

    #[test]
    fn duplicate_real_pid_rejected() {
        let mut t = PidTable::new();
        t.register(7).unwrap();
        assert!(t.register(7).is_err());
    }

    #[test]
    fn rebind_keeps_virtual_identity() {
        let mut t = PidTable::new();
        let v = t.register(100).unwrap();
        t.rebind(v, 200).unwrap();
        assert_eq!(t.real_of(v), Some(200));
        assert_eq!(t.virtual_of(100), None);
        assert!(t.check_bijection());
        // rebinding to a real pid owned by someone else fails
        let v2 = t.register(300).unwrap();
        assert!(t.rebind(v2, 200).is_err());
    }

    #[test]
    fn adopt_after_restart() {
        let mut t = PidTable::new();
        t.adopt(40_123, 555).unwrap();
        assert_eq!(t.real_of(40_123), Some(555));
        // allocator must not re-issue the adopted vpid
        let fresh = t.register(556).unwrap();
        assert!(fresh > 40_123);
        assert!(t.adopt(40_123, 700).is_err());
    }

    #[test]
    fn unregister() {
        let mut t = PidTable::new();
        let v = t.register(1).unwrap();
        t.unregister(v).unwrap();
        assert!(t.is_empty());
        assert!(t.unregister(v).is_err());
    }

    #[test]
    fn fd_capture_restore_roundtrip() {
        let mut t = FdTable::new();
        let f1 = t.open(FdKind::File { path: "/d/geom.bin".into(), append: false });
        let _s = t.open(FdKind::CoordinatorSocket);
        let f2 = t.open(FdKind::BatchLog { path: "/out/job-1.out".into() });
        let captured = t.capture();
        // coordinator socket excluded
        assert_eq!(captured.len(), 2);
        let restored = FdTable::restore(&captured);
        assert_eq!(
            restored.get(f1),
            Some(&FdKind::File { path: "/d/geom.bin".into(), append: false })
        );
        assert_eq!(
            restored.get(f2),
            Some(&FdKind::BatchLog { path: "/out/job-1.out".into() })
        );
        // new fds allocated after restore don't collide
        let mut restored = restored;
        let f3 = restored.open(FdKind::CoordinatorSocket);
        assert!(f3 > f2);
    }

    #[test]
    fn fd_close_unknown_rejected() {
        let mut t = FdTable::new();
        assert!(t.close(99).is_err());
    }
}
