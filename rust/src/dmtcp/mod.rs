//! DMTCP-analog: transparent checkpoint-restart of multi-threaded
//! (simulated) processes via a central coordinator.
//!
//! Architecture (paper Fig 1): a central [`coordinator::Coordinator`]
//! manages N processes over TCP sockets; each process carries a
//! [`ckpt_thread`] checkpoint thread plus its user threads, which park at
//! [`process::WorkerCtx::ckpt_point`] safe-points during the five-phase
//! barrier ([`protocol::Phase`]). Checkpoints are [`image`] files
//! (gzip + CRC, atomically written) — either v1 full images or v2
//! manifests over the content-addressed incremental [`store`] — and
//! restart ([`restart::dmtcp_restart`]) rebuilds the process under its
//! original virtual pid ([`virtualization`]) with plugin records replayed
//! ([`plugin`]).

pub mod ckpt_thread;
pub mod command;
pub mod coordinator;
pub mod daemon;
pub mod image;
pub mod launch;
pub mod mana;
pub mod plugin;
pub mod process;
pub mod protocol;
pub mod restart;
pub mod store;
pub mod virtualization;

pub use command::{CkptResult, CoordStatus, DmtcpCommand};
pub use coordinator::{Coordinator, CoordinatorConfig, StoreTotals};
pub use daemon::{CoordinatorDaemon, DaemonConfig, JobSpec};
pub use image::{CheckpointImage, FdEntry, ImageHeader, ImageInfo};
pub use launch::{dmtcp_launch, LaunchSpec, LaunchedProcess};
pub use mana::{ManaState, LIB_PREFIX};
pub use plugin::{EnvPlugin, Event, Plugin, PluginCtx, PluginRegistry, TimerPlugin};
pub use process::{Checkpointable, GateVerdict, SuspendGate, UserProcess, WorkerCtx};
pub use restart::{
    dmtcp_restart, dmtcp_restart_with_env, inspect_gang, inspect_image, RestartedProcess,
};
pub use store::{
    gang_manifests, latest_gang_manifest, ChunkId, ChunkRef, ChunkerSpec, GangManifest,
    GangRankEntry, GcStats, ImageManifest, ImageStore, RestoreStats, SegmentManifest, StoreConfig,
    StoreWriteStats, DEFAULT_CHUNK_SIZE,
};
pub use virtualization::{FdKind, FdTable, PidTable};
