//! `dmtcp_launch` — start a fresh process under checkpoint control.

use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use crate::dmtcp::ckpt_thread::{self, CkptContext};
use crate::dmtcp::plugin::PluginRegistry;
use crate::dmtcp::process::{
    Checkpointable, ProcessStats, SuspendGate, TypedSource, UserProcess,
};
use crate::dmtcp::virtualization::FdTable;
use crate::error::{Error, Result};

/// Synthetic real-pid allocator (distinct per launched process instance;
/// the OS pid space is not consumed by simulated processes).
static NEXT_REAL_PID: AtomicU64 = AtomicU64::new(10_000);

pub(crate) fn alloc_real_pid() -> u64 {
    NEXT_REAL_PID.fetch_add(1, Ordering::Relaxed)
}

/// Launch parameters.
#[derive(Debug, Clone)]
pub struct LaunchSpec {
    /// Process name (shows in image filenames and coordinator listings).
    pub name: String,
    /// Coordinator to attach to.
    pub coordinator: SocketAddr,
    /// Initial environment (DMTCP_GZIP=0 disables image compression).
    pub env: BTreeMap<String, String>,
}

impl LaunchSpec {
    /// A launch spec for process `name` attaching to `coordinator`.
    pub fn new(name: impl Into<String>, coordinator: SocketAddr) -> Self {
        Self {
            name: name.into(),
            coordinator,
            env: BTreeMap::new(),
        }
    }

    /// Add one environment variable (builder style).
    pub fn env(mut self, k: impl Into<String>, v: impl Into<String>) -> Self {
        self.env.insert(k.into(), v.into());
        self
    }
}

/// A process running under checkpoint control.
pub struct LaunchedProcess {
    /// The simulated process under checkpoint control.
    pub process: UserProcess,
    ckpt_join: Option<std::thread::JoinHandle<()>>,
    attached_rx: mpsc::Receiver<Result<u64>>,
}

impl LaunchedProcess {
    /// Block until the coordinator has assigned a virtual pid.
    pub fn wait_attached(&self, timeout: Duration) -> Result<u64> {
        match self.attached_rx.recv_timeout(timeout) {
            Ok(r) => r,
            Err(_) => Err(Error::Protocol(format!(
                "{}: attach timed out",
                self.process.name
            ))),
        }
    }

    /// The assigned virtual pid (0 until attached).
    pub fn vpid(&self) -> u64 {
        self.process.vpid.load(Ordering::SeqCst)
    }

    /// Wait for user threads to finish, then reap the checkpoint thread if
    /// it has exited (it exits on kill or coordinator loss).
    pub fn join(mut self) -> UserProcess {
        self.process.join_user_threads();
        if let Some(j) = self.ckpt_join.take() {
            // The ckpt thread may still be waiting on the socket if the
            // process completed normally; don't block on it in that case.
            if j.is_finished() {
                let _ = j.join();
            }
        }
        self.process
    }
}

/// Build the shared process skeleton used by launch and restart.
pub(crate) fn build_process(
    name: &str,
    env: BTreeMap<String, String>,
    fds: FdTable,
    plugins: PluginRegistry,
    generation: u32,
) -> UserProcess {
    UserProcess {
        name: name.to_string(),
        real_pid: alloc_real_pid(),
        vpid: Arc::new(AtomicU64::new(0)),
        generation,
        gate: Arc::new(SuspendGate::new()),
        stats: Arc::new(ProcessStats::default()),
        env: Arc::new(Mutex::new(env)),
        fds: Arc::new(Mutex::new(fds)),
        plugins: Arc::new(Mutex::new(plugins)),
        threads: Vec::new(),
    }
}

/// Attach `process` to the coordinator (spawns the checkpoint thread).
pub(crate) fn attach<S: Checkpointable + 'static>(
    coordinator: SocketAddr,
    process: UserProcess,
    state: Arc<Mutex<S>>,
    records: BTreeMap<String, Vec<u8>>,
    restored_vpid: Option<u64>,
) -> LaunchedProcess {
    let (tx, rx) = mpsc::channel();
    let ctx = CkptContext {
        name: process.name.clone(),
        real_pid: process.real_pid,
        generation: process.generation,
        gate: Arc::clone(&process.gate),
        stats: Arc::clone(&process.stats),
        env: Arc::clone(&process.env),
        fds: Arc::clone(&process.fds),
        plugins: Arc::clone(&process.plugins),
        source: Box::new(TypedSource(state)),
        records,
        restored_vpid,
        vpid_out: Arc::clone(&process.vpid),
        prev_manifest: BTreeMap::new(),
    };
    let join = ckpt_thread::spawn(coordinator, ctx, tx);
    LaunchedProcess {
        process,
        ckpt_join: Some(join),
        attached_rx: rx,
    }
}

/// Launch a fresh process under checkpoint control.
///
/// The caller keeps the typed `state` handle for its worker threads and
/// spawns them via [`UserProcess::spawn_user_thread`] on the returned
/// process. The checkpoint thread is already attached when this returns
/// (use [`LaunchedProcess::wait_attached`] to synchronize).
pub fn dmtcp_launch<S: Checkpointable + 'static>(
    spec: LaunchSpec,
    state: Arc<Mutex<S>>,
    plugins: PluginRegistry,
) -> LaunchedProcess {
    let process = build_process(&spec.name, spec.env, FdTable::new(), plugins, 0);
    attach(spec.coordinator, process, state, BTreeMap::new(), None)
}
