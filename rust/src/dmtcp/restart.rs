//! `dmtcp_restart` — reconstruct a process from a checkpoint image.
//!
//! The restart path is where the virtualization layers pay off: the new
//! incarnation gets a fresh *real* pid and a fresh coordinator socket, but
//! re-registers under its original *virtual* pid, reopens its virtual fds
//! (append-mode so logs continue rather than truncate), restores its memory
//! segments bit-for-bit, and replays plugin records (timer, env) so the
//! runtime context matches the checkpointed one.

use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::dmtcp::image::ImageHeader;
use crate::dmtcp::launch::{attach, build_process, LaunchedProcess};
use crate::dmtcp::plugin::{Event, PluginCtx, PluginRegistry};
use crate::dmtcp::process::Checkpointable;
use crate::dmtcp::virtualization::FdTable;
use crate::error::Result;

/// Outcome of a restart: the re-attached process plus the image header it
/// was reconstructed from (for logging / verification).
pub struct RestartedProcess {
    /// The re-attached process (threads parked, coordinator registered).
    pub launched: LaunchedProcess,
    /// Header of the image the process was reconstructed from.
    pub header: ImageHeader,
    /// Per-phase restore-pipeline stats for v2 manifest images; `None`
    /// when the image was a v1 full image (decoded inline, no store).
    pub restore: Option<crate::dmtcp::store::RestoreStats>,
}

/// Restart a process from `image_path`, attaching to `coordinator`.
///
/// `state` is the application's (freshly constructed) state object; its
/// contents are overwritten from the image segments before any user thread
/// runs. Worker threads are then spawned by the caller exactly as on first
/// launch — the application code cannot tell the difference except through
/// `generation`/plugin records (by design: transparency).
pub fn dmtcp_restart<S: Checkpointable + 'static>(
    image_path: &Path,
    coordinator: SocketAddr,
    state: Arc<Mutex<S>>,
    plugins: PluginRegistry,
) -> Result<RestartedProcess> {
    dmtcp_restart_with_env(image_path, coordinator, state, plugins, &BTreeMap::new())
}

/// [`dmtcp_restart`] with environment overrides applied on top of the
/// image's environment. The session/gang layers use this to stamp the new
/// incarnation's coordinator routing (`DMTCP_JOB`) into the restarted
/// process: the image carries the *previous* incarnation's job id, which a
/// multi-tenant daemon would rightly reject as unknown.
pub fn dmtcp_restart_with_env<S: Checkpointable + 'static>(
    image_path: &Path,
    coordinator: SocketAddr,
    state: Arc<Mutex<S>>,
    mut plugins: PluginRegistry,
    env_overrides: &BTreeMap<String, String>,
) -> Result<RestartedProcess> {
    // Reads v1 full images and v2 incremental manifests alike; v2 segments
    // reassemble — in parallel, each distinct chunk fetched and verified
    // once — from the chunk store next to the image, with per-chunk CRC
    // verification. A damaged store surfaces as `Error::Corrupt` before
    // any state is touched.
    let mut sp = crate::trace::span(crate::trace::names::RESTART_IMAGE)
        .with("image", || image_path.display().to_string());
    let (image, restore) = match crate::dmtcp::store::read_image_file_with_stats(image_path) {
        Ok(pair) => pair,
        Err(e) => {
            sp.fail(&e.to_string());
            return Err(e);
        }
    };
    let header = image.header.clone();
    if sp.is_active() {
        sp.note("name", || header.name.clone());
        sp.note_u64("vpid", header.vpid);
        sp.note_u64("generation", header.generation + 1);
        if let Some(env_job) = env_overrides.get("DMTCP_JOB") {
            sp.note("job", || env_job.clone());
        }
    }

    // Rebuild process metadata from the image.
    let generation = header.generation + 1;
    let mut env = header.env.clone();
    // The image's job tag scoped the *previous* incarnation; a restart may
    // attach anywhere (a different coordinator, a shared daemon under a
    // new job id), so the stale tag is dropped and the caller's overrides
    // supply the current one when there is one.
    env.remove("DMTCP_JOB");
    env.insert("DMTCP_RESTART".into(), "1".into());
    env.insert("DMTCP_COORD_HOST".into(), coordinator.ip().to_string());
    env.insert("DMTCP_COORD_PORT".into(), coordinator.port().to_string());
    env.extend(env_overrides.iter().map(|(k, v)| (k.clone(), v.clone())));
    let fds = FdTable::restore(&header.fds);

    // PostRestart plugin barrier first (reverse registration order), with
    // the image's records available for replay: plugins re-virtualize
    // resources (paths, timers, env) that the memory restore below depends
    // on — the same ordering as DMTCP's restart barriers.
    let mut records = header.plugin_records.clone();
    {
        let mut pctx = PluginCtx {
            records: &mut records,
            env: &mut env,
            generation,
        };
        plugins.fire(Event::PostRestart, &mut pctx)?;
    }

    // Then the memory segments, into the plugin-prepared context.
    state
        .lock()
        .expect("state poisoned")
        .restore(&image.segments)?;

    let process = build_process(&header.name, env, fds, plugins, generation);
    let launched = attach(
        coordinator,
        process,
        state,
        records,
        Some(header.vpid),
    );
    log::info!(
        "restarted {} from {} (vpid {}, gen {} -> {}, {} steps done)",
        header.name,
        image_path.display(),
        header.vpid,
        header.generation,
        generation,
        header.steps_done
    );
    Ok(RestartedProcess {
        launched,
        header,
        restore,
    })
}

/// Peek at an image without restoring it (`dmtcp_restart --inspect`).
/// Header-only: v2 manifests are inspectable even when their chunk store
/// is unavailable or damaged.
pub fn inspect_image(image_path: &Path) -> Result<ImageHeader> {
    crate::dmtcp::store::inspect_image_file(image_path)
}

/// Peek at a gang checkpoint without restoring it: read the consistent-cut
/// manifest and the header of every rank image it references. Any missing,
/// truncated, or damaged piece is a typed error — exactly what a gang
/// restart would hit — so tooling (and the phase-kill torture suite) can
/// prove an image set is restartable without booting ranks.
pub fn inspect_gang(
    manifest_path: &Path,
) -> Result<(crate::dmtcp::store::GangManifest, Vec<ImageHeader>)> {
    let manifest = crate::dmtcp::store::GangManifest::read_file(manifest_path)?;
    let dir = manifest_path.parent().unwrap_or(Path::new("."));
    let mut headers = Vec::with_capacity(manifest.ranks.len());
    for entry in &manifest.ranks {
        let h = inspect_image(&dir.join(&entry.image))?;
        if h.vpid != entry.vpid {
            return Err(crate::error::Error::Image(format!(
                "gang rank {}: image {} holds vpid {}, manifest says {}",
                entry.rank, entry.image, h.vpid, entry.vpid
            )));
        }
        headers.push(h);
    }
    Ok((manifest, headers))
}
