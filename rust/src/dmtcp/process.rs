//! The simulated "user process": user threads, checkpointable state,
//! a suspend gate, and resource accounting.
//!
//! Real DMTCP interposes on an unmodified binary: its checkpoint thread
//! signals user threads (SIGUSR2) which park in a signal handler while the
//! memory image is written. Here the "process" is a set of OS threads inside
//! the simulator; parking happens at explicit [`WorkerCtx::ckpt_point`]
//! calls (the moral equivalent of being interrupted at a safe point), and
//! "memory regions" are the application's [`Checkpointable`] state. The
//! coordination protocol, image format, and restart semantics are the same
//! as the real system — see DESIGN.md §1 for the substitution argument.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::error::Result;

/// Application state that can be captured into / restored from a
/// checkpoint image. Implemented by the workload layer.
pub trait Checkpointable: Send {
    /// Capture named memory segments (raw bytes).
    fn segments(&self) -> Vec<(String, Vec<u8>)>;
    /// Restore from captured segments (restart path).
    fn restore(&mut self, segments: &[(String, Vec<u8>)]) -> Result<()>;
    /// Progress hint stored in the image header.
    fn steps_done(&self) -> u64 {
        0
    }
    /// Resident byte estimate for metrics.
    fn size_bytes(&self) -> usize {
        0
    }
}

/// Type-erased access to an `Arc<Mutex<S: Checkpointable>>` for the
/// checkpoint thread (apps keep their typed handle).
pub trait SegmentSource: Send {
    fn capture(&self) -> (Vec<(String, Vec<u8>)>, u64);
    fn restore(&self, segments: &[(String, Vec<u8>)]) -> Result<()>;
    fn size_bytes(&self) -> usize;
}

/// Blanket adapter from a shared, typed state.
pub struct TypedSource<S: Checkpointable>(pub Arc<Mutex<S>>);

impl<S: Checkpointable> SegmentSource for TypedSource<S> {
    fn capture(&self) -> (Vec<(String, Vec<u8>)>, u64) {
        let s = self.0.lock().expect("state poisoned");
        (s.segments(), s.steps_done())
    }

    fn restore(&self, segments: &[(String, Vec<u8>)]) -> Result<()> {
        self.0.lock().expect("state poisoned").restore(segments)
    }

    fn size_bytes(&self) -> usize {
        self.0.lock().expect("state poisoned").size_bytes()
    }
}

/// What a user thread should do after a checkpoint point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GateVerdict {
    /// Keep computing.
    Continue,
    /// The process was killed (preemption): unwind and exit cleanly.
    Exit,
}

#[derive(Debug, Default)]
struct GateInner {
    suspending: bool,
    parked: usize,
    killed: bool,
}

/// The suspend gate: DMTCP's SIGUSR2-park, as a condvar barrier.
///
/// User threads call [`SuspendGate::ckpt_point`] between work quanta; the
/// checkpoint thread calls `request_suspend` → `wait_parked(n)` →
/// (image write) → `resume`.
#[derive(Debug, Default)]
pub struct SuspendGate {
    inner: Mutex<GateInner>,
    cv: Condvar,
}

impl SuspendGate {
    /// A fresh gate with no suspend requested.
    pub fn new() -> Self {
        Self::default()
    }

    /// Ask all user threads to park at their next checkpoint point.
    pub fn request_suspend(&self) {
        let mut g = self.inner.lock().unwrap();
        g.suspending = true;
        self.cv.notify_all();
    }

    /// Block until `n` user threads are parked (or the gate is killed).
    pub fn wait_parked(&self, n: usize) {
        let mut g = self.inner.lock().unwrap();
        while g.parked < n && !g.killed {
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Release parked threads.
    pub fn resume(&self) {
        let mut g = self.inner.lock().unwrap();
        g.suspending = false;
        self.cv.notify_all();
    }

    /// Kill the process: parked and running threads exit at the gate.
    pub fn kill(&self) {
        let mut g = self.inner.lock().unwrap();
        g.killed = true;
        g.suspending = false;
        self.cv.notify_all();
    }

    /// True once `kill` has been called.
    pub fn killed(&self) -> bool {
        self.inner.lock().unwrap().killed
    }

    /// Currently parked thread count (metrics).
    pub fn parked_count(&self) -> usize {
        self.inner.lock().unwrap().parked
    }

    /// Called by user threads between work quanta; blocks while a
    /// checkpoint is in progress.
    pub fn ckpt_point(&self) -> GateVerdict {
        let mut g = self.inner.lock().unwrap();
        if g.killed {
            return GateVerdict::Exit;
        }
        if g.suspending {
            g.parked += 1;
            self.cv.notify_all();
            while g.suspending && !g.killed {
                g = self.cv.wait(g).unwrap();
            }
            g.parked -= 1;
            self.cv.notify_all();
            if g.killed {
                return GateVerdict::Exit;
            }
        }
        GateVerdict::Continue
    }
}

/// Live resource counters sampled by the LDMS-analog (Fig 4 substrate).
#[derive(Debug, Default)]
pub struct ProcessStats {
    /// Total user threads.
    pub n_threads: AtomicUsize,
    /// Threads currently parked at the gate.
    pub parked: AtomicUsize,
    /// Application state resident bytes.
    pub state_bytes: AtomicU64,
    /// Transient allocation during image encode/write (the paper's
    /// checkpoint-time memory spikes).
    pub transient_bytes: AtomicU64,
    /// Steps completed.
    pub steps_done: AtomicU64,
    /// Process liveness.
    pub alive: AtomicBool,
    /// Cumulative busy nanoseconds across user threads.
    pub busy_nanos: AtomicU64,
    /// Checkpoints taken by this process instance.
    pub checkpoints: AtomicU64,
    /// Cumulative checkpoint bytes actually stored (manifest + new chunks
    /// for incremental images; whole file for full images), sampled by the
    /// LDMS-analog. Chunk-level counts travel over the coordinator
    /// protocol (`CkptDone`) instead — they are round accounting, not a
    /// sampled time series.
    pub ckpt_stored_bytes: AtomicU64,
}

impl ProcessStats {
    /// CPU utilization proxy in `[0,1]`: fraction of unparked user threads
    /// while alive.
    pub fn cpu_fraction(&self) -> f64 {
        if !self.alive.load(Ordering::Relaxed) {
            return 0.0;
        }
        let n = self.n_threads.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        let parked = self.parked.load(Ordering::Relaxed).min(n);
        (n - parked) as f64 / n as f64
    }

    /// Memory footprint proxy in bytes: state + transient + fixed overhead.
    pub fn memory_bytes(&self, base_overhead: u64) -> u64 {
        if !self.alive.load(Ordering::Relaxed) {
            return 0;
        }
        base_overhead
            + self.state_bytes.load(Ordering::Relaxed)
            + self.transient_bytes.load(Ordering::Relaxed)
    }
}

/// Handle given to each user thread.
#[derive(Clone)]
pub struct WorkerCtx {
    gate: Arc<SuspendGate>,
    stats: Arc<ProcessStats>,
    thread_idx: usize,
}

impl WorkerCtx {
    /// Checkpoint safe-point. (The checkpoint thread publishes the parked
    /// population to `stats` over the whole Suspend→Resume window; the
    /// worker only needs to pass through the gate here.)
    pub fn ckpt_point(&self) -> GateVerdict {
        self.gate.ckpt_point()
    }

    /// Record `nanos` of useful work (CPU accounting).
    pub fn record_busy(&self, nanos: u64) {
        self.stats.busy_nanos.fetch_add(nanos, Ordering::Relaxed);
    }

    /// Update the resident-state estimate after a work quantum.
    pub fn record_state_bytes(&self, bytes: u64) {
        self.stats.state_bytes.store(bytes, Ordering::Relaxed);
    }

    /// Record progress.
    pub fn record_steps(&self, steps_done: u64) {
        self.stats.steps_done.store(steps_done, Ordering::Relaxed);
    }

    /// This worker's index within the process.
    pub fn thread_idx(&self) -> usize {
        self.thread_idx
    }

    /// Whether the process has been torn down (preemption or quit).
    pub fn killed(&self) -> bool {
        self.gate.killed()
    }
}

/// A running simulated process: gate + stats + threads + metadata.
///
/// Constructed by [`crate::dmtcp::launch::dmtcp_launch`] /
/// [`crate::dmtcp::restart::dmtcp_restart`]; most fields are shared with the
/// checkpoint thread.
pub struct UserProcess {
    /// Process name (images are discovered by it).
    pub name: String,
    /// Real (host) pid.
    pub real_pid: u64,
    /// Virtual pid (assigned by the coordinator at Hello/Welcome).
    pub vpid: Arc<AtomicU64>,
    /// Restart generation (0 = first incarnation).
    pub generation: u32,
    /// The safe-point gate user threads park at during barriers.
    pub gate: Arc<SuspendGate>,
    /// Shared process counters (steps, bytes, checkpoint totals).
    pub stats: Arc<ProcessStats>,
    /// The process's (virtualized) environment.
    pub env: Arc<Mutex<BTreeMap<String, String>>>,
    /// The process's virtual fd table.
    pub fds: Arc<Mutex<crate::dmtcp::virtualization::FdTable>>,
    /// Plugin registry fired at each barrier event.
    pub plugins: Arc<Mutex<crate::dmtcp::plugin::PluginRegistry>>,
    pub(crate) threads: Vec<std::thread::JoinHandle<()>>,
}

impl UserProcess {
    /// Spawn a user thread running `body(thread_idx, ctx)`.
    pub fn spawn_user_thread<F>(&mut self, body: F)
    where
        F: FnOnce(WorkerCtx) + Send + 'static,
    {
        let idx = self.threads.len();
        let ctx = WorkerCtx {
            gate: Arc::clone(&self.gate),
            stats: Arc::clone(&self.stats),
            thread_idx: idx,
        };
        self.stats.n_threads.fetch_add(1, Ordering::Relaxed);
        let name = format!("{}-u{}", self.name, idx);
        let stats = Arc::clone(&self.stats);
        let h = std::thread::Builder::new()
            .name(name)
            .spawn(move || {
                body(ctx);
                // A finished thread leaves the suspend-barrier population —
                // otherwise a checkpoint racing with completion would wait
                // forever for it to park.
                stats.n_threads.fetch_sub(1, Ordering::Relaxed);
            })
            .expect("spawn user thread");
        self.threads.push(h);
    }

    /// Wait for all user threads to finish (normal completion or kill).
    pub fn join_user_threads(&mut self) {
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
        self.stats.alive.store(false, Ordering::Relaxed);
    }

    /// Number of user threads spawned.
    pub fn n_threads(&self) -> usize {
        self.stats.n_threads.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn gate_suspend_park_resume() {
        let gate = Arc::new(SuspendGate::new());
        let n = 4;
        let counter = Arc::new(AtomicU64::new(0));
        let mut joins = Vec::new();
        for _ in 0..n {
            let g = Arc::clone(&gate);
            let c = Arc::clone(&counter);
            joins.push(std::thread::spawn(move || loop {
                match g.ckpt_point() {
                    GateVerdict::Exit => break,
                    GateVerdict::Continue => {
                        c.fetch_add(1, Ordering::Relaxed);
                        std::thread::sleep(Duration::from_micros(100));
                    }
                }
            }));
        }
        gate.request_suspend();
        gate.wait_parked(n);
        assert_eq!(gate.parked_count(), n);
        let frozen = counter.load(Ordering::Relaxed);
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(
            counter.load(Ordering::Relaxed),
            frozen,
            "threads progressed while parked"
        );
        gate.resume();
        std::thread::sleep(Duration::from_millis(10));
        assert!(counter.load(Ordering::Relaxed) > frozen, "threads did not resume");
        gate.kill();
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn gate_kill_releases_parked_threads() {
        let gate = Arc::new(SuspendGate::new());
        let g = Arc::clone(&gate);
        let j = std::thread::spawn(move || loop {
            if g.ckpt_point() == GateVerdict::Exit {
                break;
            }
        });
        gate.request_suspend();
        gate.wait_parked(1);
        gate.kill();
        j.join().unwrap(); // must not hang
        assert!(gate.killed());
    }

    #[test]
    fn double_suspend_cycle() {
        let gate = Arc::new(SuspendGate::new());
        let g = Arc::clone(&gate);
        let j = std::thread::spawn(move || loop {
            if g.ckpt_point() == GateVerdict::Exit {
                break;
            }
            std::thread::yield_now();
        });
        for _ in 0..3 {
            gate.request_suspend();
            gate.wait_parked(1);
            gate.resume();
        }
        gate.kill();
        j.join().unwrap();
    }

    #[test]
    fn stats_cpu_fraction() {
        let s = ProcessStats::default();
        s.alive.store(true, Ordering::Relaxed);
        s.n_threads.store(4, Ordering::Relaxed);
        assert_eq!(s.cpu_fraction(), 1.0);
        s.parked.store(3, Ordering::Relaxed);
        assert_eq!(s.cpu_fraction(), 0.25);
        s.alive.store(false, Ordering::Relaxed);
        assert_eq!(s.cpu_fraction(), 0.0);
    }

    #[test]
    fn stats_memory_accounting() {
        let s = ProcessStats::default();
        s.alive.store(true, Ordering::Relaxed);
        s.state_bytes.store(1_000, Ordering::Relaxed);
        s.transient_bytes.store(500, Ordering::Relaxed);
        assert_eq!(s.memory_bytes(100), 1_600);
        s.alive.store(false, Ordering::Relaxed);
        assert_eq!(s.memory_bytes(100), 0);
    }
}
