//! Content-addressed incremental checkpoint store (image format v2).
//!
//! The paper's dominant C/R cost is rewriting the whole image every
//! checkpoint (gzip of every segment, every time — the NERSC `--gzip`
//! default). CRIU's incremental pre-dump and MANA's segment exclusion both
//! show the same lever: *don't rewrite unchanged memory*. This module
//! applies it to the image format:
//!
//! * Segments are split into chunks — fixed-size, or content-defined via
//!   a gear rolling hash ([`ChunkerSpec`]) so an insert shifts only the
//!   boundaries near it instead of every later chunk; each chunk is
//!   addressed by a CRC-seeded 128-bit content hash ([`ChunkId`]).
//! * Chunks live in a per-workdir store (`<ckpt_dir>/store/<aa>/<hex>.chunk`,
//!   atomically published), so a checkpoint after a small state delta only
//!   compresses and writes chunks whose content actually changed — across
//!   generations, processes, and even restarts (content addressing dedups
//!   against everything already on disk).
//! * The image file itself becomes a small v2 *manifest* of chunk
//!   references (same outer frame and header encoding as v1; see
//!   [`crate::dmtcp::image`]); v1 full images remain readable through the
//!   same entry points as the fallback.
//! * Chunk compression fans out across a small worker pool — the gzip
//!   stage, serial in the v1 writer, parallelizes per chunk.
//! * Reads verify every chunk's CRC and length before any state is
//!   restored; a missing or damaged chunk surfaces as the typed
//!   [`Error::Corrupt`] — never a panic or silent zero-fill.
//! * Restore fans chunk fetch → decompress → CRC verify over the same
//!   worker pool the write path uses, decompressing each *distinct* chunk
//!   exactly once even when many segment references share a hash;
//!   [`RestoreStats`] reports the per-phase timings
//!   ([`ImageStore::assemble_with_stats`]).
//!
//! Dirty-segment tracking lives one level up (the checkpoint thread keeps
//! the previous generation's [`SegmentManifest`]s and skips re-chunking
//! segments whose raw CRC is unchanged); [`ImageStore::gc`] reclaims
//! chunks no manifest references (sessions run it on teardown).

use std::collections::BTreeMap;
use std::collections::BTreeSet;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

use flate2::read::GzDecoder;
use flate2::write::GzEncoder;
use flate2::Compression;

use crate::dmtcp::image::{
    self, atomic_write, CheckpointImage, ImageHeader, VERSION_FULL, VERSION_GANG,
    VERSION_MANIFEST,
};
use crate::error::{Error, Result};
use crate::util::bytes::{ByteReader, PutBytes};

/// Default chunk size: 64 KiB balances dedup granularity (small deltas
/// re-store little) against per-chunk overhead (hashing, one file each).
pub const DEFAULT_CHUNK_SIZE: usize = 64 * 1024;

/// Default CDC minimum chunk size (boundaries are suppressed below this).
pub const DEFAULT_CDC_MIN: usize = 16 * 1024;
/// Default CDC target average chunk size (the boundary-mask width; must
/// be a power of two).
pub const DEFAULT_CDC_AVG: usize = 64 * 1024;
/// Default CDC maximum chunk size (a boundary is forced at this length).
pub const DEFAULT_CDC_MAX: usize = 256 * 1024;

/// The store directory name under a checkpoint directory.
pub const STORE_DIR_NAME: &str = "store";

/// Chunk-file magic (`NCRCHNK` + format version byte).
const CHUNK_MAGIC: &[u8; 8] = b"NCRCHNK1";
const CHUNK_FLAG_GZIP: u8 = 1;

/// 128-bit content address of a chunk.
///
/// CRC-seeded: the chunk's CRC-32 (the integrity primitive the image
/// format already standardizes on) seeds two independently-mixed 64-bit
/// streaming hashes over the content, so equal content always maps to the
/// same address and 2^-128-scale collisions are not a practical concern
/// for checkpoint dedup.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ChunkId {
    /// High 64 bits of the address.
    pub hi: u64,
    /// Low 64 bits of the address.
    pub lo: u64,
}

impl ChunkId {
    /// Content address of `data`.
    pub fn of(data: &[u8]) -> Self {
        Self::of_with_crc(data, crc32fast::hash(data))
    }

    /// Content address of `data` with its CRC-32 already computed — the
    /// write path CRCs each chunk exactly once and seeds the address from
    /// that same pass.
    pub fn of_with_crc(data: &[u8], crc: u32) -> Self {
        let crc = crc as u64;
        Self {
            hi: hash64(data, crc ^ 0x9E37_79B9_7F4A_7C15),
            lo: hash64(data, crc.wrapping_mul(0xBF58_476D_1CE4_E5B9) ^ 0x94D0_49BB_1331_11EB),
        }
    }

    /// 32-hex-digit form (chunk file names).
    pub fn hex(&self) -> String {
        format!("{:016x}{:016x}", self.hi, self.lo)
    }

    /// Parse the 32-hex-digit form back (GC scans file names).
    pub fn from_hex(s: &str) -> Option<Self> {
        if s.len() != 32 || !s.is_ascii() {
            return None;
        }
        let hi = u64::from_str_radix(&s[..16], 16).ok()?;
        let lo = u64::from_str_radix(&s[16..], 16).ok()?;
        Some(Self { hi, lo })
    }
}

/// SplitMix64 finalizer (also the mixer behind `util::rng`).
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Seeded 64-bit streaming hash over 8-byte words (zero-padded tail),
/// length-mixed so prefixes don't collide.
fn hash64(data: &[u8], seed: u64) -> u64 {
    let mut h = seed;
    let mut it = data.chunks_exact(8);
    for w in &mut it {
        h = mix64(h ^ u64::from_le_bytes(w.try_into().expect("8-byte word")));
    }
    let rem = it.remainder();
    if !rem.is_empty() {
        let mut buf = [0u8; 8];
        buf[..rem.len()].copy_from_slice(rem);
        h = mix64(h ^ u64::from_le_bytes(buf));
    }
    mix64(h ^ data.len() as u64)
}

/// One chunk reference inside a segment manifest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkRef {
    /// Content address (names the chunk file in the store).
    pub id: ChunkId,
    /// Raw (uncompressed) chunk length.
    pub raw_len: u32,
    /// CRC-32 of the raw chunk bytes (verified on every read).
    pub raw_crc: u32,
}

impl ChunkRef {
    /// Reference for `data`: one CRC pass seeds both the integrity field
    /// and the content address.
    fn of(data: &[u8]) -> Self {
        let raw_crc = crc32fast::hash(data);
        Self {
            id: ChunkId::of_with_crc(data, raw_crc),
            raw_len: data.len() as u32,
            raw_crc,
        }
    }
}

/// Atomic publish with a *writer-unique* tmp name: concurrent writers of
/// the same content-addressed path (two pool workers, two ranks, two
/// sessions) each stage their own tmp file and race only on the final
/// rename — which is harmless, since the bytes are identical. A shared
/// deterministic tmp name would let one writer rename away (or truncate)
/// another's in-flight staging file.
fn atomic_publish(path: &Path, bytes: &[u8]) -> Result<()> {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let mut os = path.as_os_str().to_owned();
    os.push(format!(
        ".tmp.{}.{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let tmp = PathBuf::from(os);
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(&tmp, bytes)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// The chunked form of one named memory segment.
#[derive(Debug, Clone, PartialEq)]
pub struct SegmentManifest {
    /// Segment name (matches the v1 segment name).
    pub name: String,
    /// Total raw segment length.
    pub raw_len: u64,
    /// CRC-32 of the whole raw segment (second integrity level).
    pub raw_crc: u32,
    /// Chunk references, in segment order.
    pub chunks: Vec<ChunkRef>,
}

/// A v2 image: the v1 header plus chunk manifests instead of inline bytes.
#[derive(Debug, Clone, PartialEq)]
pub struct ImageManifest {
    /// Same header as v1 images (vpid, env, fds, plugin records, ...).
    pub header: ImageHeader,
    /// One manifest per memory segment.
    pub segments: Vec<SegmentManifest>,
}

impl ImageManifest {
    /// Total raw (logical) segment bytes the manifest describes.
    pub fn raw_bytes(&self) -> u64 {
        self.segments.iter().map(|s| s.raw_len).sum()
    }

    /// Total chunk references across all segments.
    pub fn n_chunks(&self) -> usize {
        self.segments.iter().map(|s| s.chunks.len()).sum()
    }

    fn encode(&self) -> Vec<u8> {
        let mut b = Vec::new();
        image::encode_header(&self.header, &mut b);
        b.put_u32(self.segments.len() as u32);
        for s in &self.segments {
            b.put_lp_str(&s.name);
            b.put_u64(s.raw_len);
            b.put_u32(s.raw_crc);
            b.put_u32(s.chunks.len() as u32);
            for c in &s.chunks {
                b.put_u64(c.id.hi);
                b.put_u64(c.id.lo);
                b.put_u32(c.raw_len);
                b.put_u32(c.raw_crc);
            }
        }
        b
    }

    fn decode(body: &[u8]) -> Result<Self> {
        let mut r = ByteReader::new(body);
        let header = image::decode_header(&mut r)?;
        let n_seg = r.get_u32()?;
        let mut segments = Vec::with_capacity(n_seg as usize);
        for _ in 0..n_seg {
            let name = r.get_lp_str()?;
            let raw_len = r.get_u64()?;
            let raw_crc = r.get_u32()?;
            let n_chunks = r.get_u32()?;
            let mut chunks = Vec::with_capacity(n_chunks as usize);
            let mut covered = 0u64;
            for _ in 0..n_chunks {
                let c = ChunkRef {
                    id: ChunkId {
                        hi: r.get_u64()?,
                        lo: r.get_u64()?,
                    },
                    raw_len: r.get_u32()?,
                    raw_crc: r.get_u32()?,
                };
                covered += c.raw_len as u64;
                chunks.push(c);
            }
            if covered != raw_len {
                return Err(Error::Image(format!(
                    "segment {name:?} manifest covers {covered} of {raw_len} bytes"
                )));
            }
            segments.push(SegmentManifest {
                name,
                raw_len,
                raw_crc,
                chunks,
            });
        }
        if r.remaining() != 0 {
            return Err(Error::Image(format!(
                "{} trailing bytes after last segment manifest",
                r.remaining()
            )));
        }
        Ok(Self { header, segments })
    }
}

/// How segment bytes are split into chunks before content addressing.
///
/// The chunker only decides *boundaries*; chunk files, manifests and the
/// restore path are identical for every variant (invariant 10, DESIGN
/// §13): an image written with any chunker restores bit-identical on any
/// reader, because readers never consult the chunker at all — they follow
/// the manifest's explicit `(id, raw_len, raw_crc)` references.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChunkerSpec {
    /// Fixed-size split at `chunk_size` boundaries (the last chunk of a
    /// segment is shorter). Cheapest, but a single inserted byte shifts
    /// every later boundary and defeats dedup for the rest of the segment.
    Fixed,
    /// Content-defined chunking: a gear rolling hash
    /// (`h = (h << 1) + GEAR[byte]`, ~64-byte effective window) cuts a
    /// boundary where `h & (avg - 1) == 0`, suppressed below `min` bytes
    /// and forced at `max`. Boundaries depend on content, not offsets, so
    /// chunks re-synchronize shortly after an insert and dedup survives.
    Cdc {
        /// Minimum chunk size in bytes (≥ 1; boundaries suppressed below).
        min: usize,
        /// Target average chunk size (the boundary mask; a power of two,
        /// `min ≤ avg ≤ max`).
        avg: usize,
        /// Maximum chunk size in bytes (a boundary is forced here).
        max: usize,
    },
}

impl ChunkerSpec {
    /// The default content-defined chunker:
    /// `cdc:DEFAULT_CDC_MIN:DEFAULT_CDC_AVG:DEFAULT_CDC_MAX`.
    pub fn cdc_default() -> Self {
        Self::Cdc {
            min: DEFAULT_CDC_MIN,
            avg: DEFAULT_CDC_AVG,
            max: DEFAULT_CDC_MAX,
        }
    }

    /// Validate the bounds; every constructor path (spec key, env var,
    /// CLI flag, builder) funnels through this before the chunker is used.
    pub fn validate(&self) -> Result<()> {
        match *self {
            Self::Fixed => Ok(()),
            Self::Cdc { min, avg, max } => {
                if min == 0 {
                    return Err(Error::Usage("cdc min chunk size must be >= 1".into()));
                }
                if !(min <= avg && avg <= max) {
                    return Err(Error::Usage(format!(
                        "cdc chunk sizes must satisfy min <= avg <= max, got \
                         {min}:{avg}:{max}"
                    )));
                }
                if !avg.is_power_of_two() {
                    return Err(Error::Usage(format!(
                        "cdc avg chunk size must be a power of two, got {avg}"
                    )));
                }
                Ok(())
            }
        }
    }
}

impl Default for ChunkerSpec {
    fn default() -> Self {
        Self::Fixed
    }
}

impl std::fmt::Display for ChunkerSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            Self::Fixed => write!(f, "fixed"),
            Self::Cdc { min, avg, max } => write!(f, "cdc:{min}:{avg}:{max}"),
        }
    }
}

impl std::str::FromStr for ChunkerSpec {
    type Err = Error;

    /// Parse `fixed`, `cdc` (default bounds), or `cdc:<min>:<avg>:<max>`
    /// (bytes). The exact strings [`Display`](std::fmt::Display) emits
    /// round-trip.
    fn from_str(s: &str) -> Result<Self> {
        let s = s.trim();
        let spec = match s {
            "fixed" => Self::Fixed,
            "cdc" => Self::cdc_default(),
            _ => {
                let Some(rest) = s.strip_prefix("cdc:") else {
                    return Err(Error::Usage(format!(
                        "unknown chunker {s:?} (expected fixed, cdc, or \
                         cdc:<min>:<avg>:<max>)"
                    )));
                };
                let parts: Vec<&str> = rest.split(':').collect();
                if parts.len() != 3 {
                    return Err(Error::Usage(format!(
                        "cdc chunker takes min:avg:max, got {s:?}"
                    )));
                }
                let parse = |what: &str, p: &str| -> Result<usize> {
                    p.trim().parse::<usize>().map_err(|_| {
                        Error::Usage(format!("cdc {what} chunk size {p:?} is not a byte count"))
                    })
                };
                Self::Cdc {
                    min: parse("min", parts[0])?,
                    avg: parse("avg", parts[1])?,
                    max: parse("max", parts[2])?,
                }
            }
        };
        spec.validate()?;
        Ok(spec)
    }
}

/// The gear table: 256 pseudo-random u64s (SplitMix64 over a fixed seed),
/// one per byte value. Process-independent and version-pinned — boundary
/// placement is part of what makes dedup work *across* sessions, so the
/// table must never vary.
fn gear_table() -> &'static [u64; 256] {
    static TABLE: OnceLock<[u64; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u64; 256];
        let mut s = 0x4E43_5243_4443_5631u64; // "NCRCDCV1"
        for e in t.iter_mut() {
            s = s.wrapping_add(0x9E37_79B9_7F4A_7C15);
            *e = mix64(s);
        }
        t
    })
}

/// Chunk boundaries for `data` under `chunker`: `(start, end)` ranges that
/// cover `data` exactly, in order. `chunk_size` is the [`ChunkerSpec::Fixed`]
/// width. Empty data yields no ranges (an empty segment has no chunks).
fn chunk_ranges(data: &[u8], chunk_size: usize, chunker: ChunkerSpec) -> Vec<(usize, usize)> {
    if data.is_empty() {
        return Vec::new();
    }
    match chunker {
        ChunkerSpec::Fixed => {
            let sz = chunk_size.max(1);
            (0..data.len())
                .step_by(sz)
                .map(|s| (s, (s + sz).min(data.len())))
                .collect()
        }
        ChunkerSpec::Cdc { min, avg, max } => {
            let gear = gear_table();
            let mask = avg as u64 - 1;
            let mut out = Vec::new();
            let mut start = 0usize;
            let mut h = 0u64;
            for (pos, &b) in data.iter().enumerate() {
                h = (h << 1).wrapping_add(gear[b as usize]);
                let len = pos + 1 - start;
                if (len >= min && h & mask == 0) || len >= max {
                    out.push((start, pos + 1));
                    start = pos + 1;
                    h = 0;
                }
            }
            if start < data.len() {
                out.push((start, data.len()));
            }
            out
        }
    }
}

/// Knobs for the incremental write and parallel restore pipelines.
#[derive(Debug, Clone)]
pub struct StoreConfig {
    /// Chunk size in bytes for [`ChunkerSpec::Fixed`] (the last chunk of
    /// a segment is shorter).
    pub chunk_size: usize,
    /// Worker threads, shared by the parallel compress stage on write and
    /// the fetch → decompress → verify stage on restore.
    pub workers: usize,
    /// gzip chunk payloads (DMTCP `--gzip`; chunk files self-describe, so
    /// mixed-mode stores read fine).
    pub gzip: bool,
    /// How segment bytes are split into chunks.
    pub chunker: ChunkerSpec,
}

impl Default for StoreConfig {
    fn default() -> Self {
        Self {
            chunk_size: DEFAULT_CHUNK_SIZE,
            workers: default_workers(),
            gzip: true,
            chunker: ChunkerSpec::Fixed,
        }
    }
}

/// Small default pool: enough to overlap gzip with file IO without
/// oversubscribing nodes that run many ranks per host.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(2)
        .clamp(1, 4)
}

/// Counters from one incremental image write.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreWriteStats {
    /// Chunks newly written to the store this write.
    pub chunks_written: u64,
    /// Chunks already present (content-addressed dedup) or carried over
    /// from an unchanged segment (dirty tracking).
    pub chunks_deduped: u64,
    /// Raw segment bytes the image describes (what a full image would
    /// serialize).
    pub logical_bytes: u64,
    /// Bytes actually written to disk: new chunk files + the manifest.
    pub stored_bytes: u64,
}

/// Per-phase counters and timings from one parallel manifest restore
/// ([`ImageStore::assemble_with_stats`]). Phase seconds are summed across
/// pool workers, so they can exceed `wall_secs` when the pool overlaps
/// work — compare phases to each other, and `wall_secs` to the clock.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RestoreStats {
    /// Distinct chunk files fetched from the store.
    pub chunk_reads: u64,
    /// Manifest chunk references served from the per-restore memo instead
    /// of a second fetch (dedup-heavy images: `total refs - chunk_reads`).
    pub chunks_memoized: u64,
    /// Seconds spent reading chunk files (summed across workers).
    pub read_secs: f64,
    /// Seconds spent decompressing chunk payloads (summed across workers).
    pub decompress_secs: f64,
    /// Seconds spent CRC-verifying raw bytes, chunk- and segment-level
    /// (summed across workers).
    pub verify_secs: f64,
    /// Wall-clock seconds for the whole assemble.
    pub wall_secs: f64,
    /// Workers the restore pool actually ran.
    pub workers: usize,
}

/// Stats from one [`ImageStore::gc`] pass.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GcStats {
    /// Chunk files examined.
    pub scanned: u64,
    /// Distinct chunks referenced by at least one manifest.
    pub live: u64,
    /// Unreferenced chunk files deleted.
    pub deleted: u64,
    /// Bytes reclaimed.
    pub deleted_bytes: u64,
}

/// A per-workdir content-addressed chunk store.
#[derive(Debug, Clone)]
pub struct ImageStore {
    root: PathBuf,
}

impl ImageStore {
    /// The store serving the images in `ckpt_dir` (lives at
    /// `<ckpt_dir>/store/`). Nothing is created until a chunk is written.
    pub fn for_images(ckpt_dir: &Path) -> Self {
        Self {
            root: ckpt_dir.join(STORE_DIR_NAME),
        }
    }

    /// Open a store at an explicit root directory.
    pub fn open(root: impl Into<PathBuf>) -> Self {
        Self { root: root.into() }
    }

    /// The store's root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    fn chunk_path(&self, id: ChunkId) -> PathBuf {
        let hex = id.hex();
        self.root.join(&hex[..2]).join(format!("{hex}.chunk"))
    }

    /// Write an image incrementally: chunk + hash the segments, store only
    /// chunks not already present, and publish a v2 manifest at `path`.
    ///
    /// `prev` is the previous generation's per-segment manifests (dirty
    /// tracking): a segment whose name, length and raw CRC are unchanged —
    /// and whose chunks are all still on disk (their mtimes are refreshed,
    /// re-arming the GC grace window) — reuses its manifest without
    /// re-chunking, content-hashing or re-storing anything. (The one CRC
    /// pass that decides cleanliness is the floor cost per segment.)
    pub fn write_incremental(
        &self,
        img: &CheckpointImage,
        path: &Path,
        prev: Option<&BTreeMap<String, SegmentManifest>>,
        opts: &StoreConfig,
    ) -> Result<(ImageManifest, StoreWriteStats)> {
        opts.chunker.validate()?;
        let mut sp = crate::trace::span(crate::trace::names::STORE_WRITE)
            .with_u64("segments", img.segments.len() as u64);
        let mut stats = StoreWriteStats::default();
        let chunk_size = opts.chunk_size.max(1);

        // Split segments into clean (manifest reuse) and dirty (re-chunk).
        let mut segments: Vec<Option<SegmentManifest>> = vec![None; img.segments.len()];
        let mut dirty: Vec<(usize, &str, &[u8], u32)> = Vec::new();
        for (i, (name, data)) in img.segments.iter().enumerate() {
            stats.logical_bytes += data.len() as u64;
            let crc = crc32fast::hash(data);
            if let Some(p) = prev.and_then(|m| m.get(name.as_str())) {
                if p.raw_len == data.len() as u64
                    && p.raw_crc == crc
                    && p.chunks.iter().all(|c| self.refresh_chunk(c.id))
                {
                    stats.chunks_deduped += p.chunks.len() as u64;
                    segments[i] = Some(p.clone());
                    continue;
                }
            }
            dirty.push((i, name.as_str(), data.as_slice(), crc));
        }

        // Fan the dirty chunks out over the compression pool.
        let jobs: Vec<(usize, usize, &[u8])> = dirty
            .iter()
            .flat_map(|&(si, _, data, _)| {
                chunk_ranges(data, chunk_size, opts.chunker)
                    .into_iter()
                    .enumerate()
                    .map(move |(ci, (s, e))| (si, ci, &data[s..e]))
            })
            .collect();
        // Degenerate but legal: an empty segment still needs a manifest.
        let results: Vec<(usize, usize, ChunkRef, u64, bool)> = if jobs.is_empty() {
            Vec::new()
        } else {
            let _pool_sp = crate::trace::span(crate::trace::names::STORE_COMPRESS)
                .with_u64("chunks", jobs.len() as u64)
                .with_u64("workers", opts.workers.clamp(1, jobs.len().max(1)) as u64);
            self.run_pool(&jobs, opts)?
        };
        let mut per_segment: BTreeMap<usize, Vec<(usize, ChunkRef)>> = BTreeMap::new();
        for (si, ci, cref, written, was_new) in results {
            stats.stored_bytes += written;
            if was_new {
                stats.chunks_written += 1;
            } else {
                stats.chunks_deduped += 1;
            }
            per_segment.entry(si).or_default().push((ci, cref));
        }
        for &(si, name, data, crc) in &dirty {
            let mut chunks = per_segment.remove(&si).unwrap_or_default();
            chunks.sort_by_key(|&(ci, _)| ci);
            segments[si] = Some(SegmentManifest {
                name: name.to_string(),
                raw_len: data.len() as u64,
                raw_crc: crc,
                chunks: chunks.into_iter().map(|(_, c)| c).collect(),
            });
        }

        let manifest = ImageManifest {
            header: img.header.clone(),
            segments: segments
                .into_iter()
                .map(|s| s.expect("every segment resolved"))
                .collect(),
        };
        let body = manifest.encode();
        let bytes = image::frame(VERSION_MANIFEST, 0, &body);
        atomic_write(path, &bytes)?;
        stats.stored_bytes += bytes.len() as u64;
        if sp.is_active() {
            sp.note_u64("chunks_written", stats.chunks_written);
            sp.note_u64("chunks_deduped", stats.chunks_deduped);
            sp.note_u64("logical_bytes", stats.logical_bytes);
            sp.note_u64("stored_bytes", stats.stored_bytes);
        }
        Ok((manifest, stats))
    }

    /// The parallel gzip stage: workers pull `(segment, chunk, bytes)`
    /// jobs off a shared cursor, hash + compress + publish each chunk, and
    /// report `(refs, bytes written, newly written)`.
    #[allow(clippy::type_complexity)]
    fn run_pool(
        &self,
        jobs: &[(usize, usize, &[u8])],
        opts: &StoreConfig,
    ) -> Result<Vec<(usize, usize, ChunkRef, u64, bool)>> {
        let cursor = AtomicUsize::new(0);
        let out: Mutex<Vec<(usize, usize, ChunkRef, u64, bool)>> =
            Mutex::new(Vec::with_capacity(jobs.len()));
        let first_err: Mutex<Option<Error>> = Mutex::new(None);
        // Ids claimed within this write: repeated content (zero pages, a
        // replicated table) is compressed and stored once, and the other
        // occurrences just take the reference. The scope joins every
        // worker before the manifest is published, so a claim-skipped
        // occurrence never references a chunk still being written.
        let claimed: Mutex<BTreeSet<ChunkId>> = Mutex::new(BTreeSet::new());
        let workers = opts.workers.clamp(1, jobs.len().max(1));
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| {
                    let mut local = Vec::new();
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= jobs.len() {
                            break;
                        }
                        let (si, ci, data) = jobs[i];
                        let cref = ChunkRef::of(data);
                        let owner = claimed.lock().expect("claim set").insert(cref.id);
                        let stored = if owner {
                            self.store_chunk(&cref, data, opts.gzip)
                        } else {
                            Ok((0, false))
                        };
                        match stored {
                            Ok((written, was_new)) => {
                                local.push((si, ci, cref, written, was_new))
                            }
                            Err(e) => {
                                let mut g = first_err.lock().expect("pool error slot");
                                if g.is_none() {
                                    *g = Some(e);
                                }
                                break;
                            }
                        }
                    }
                    out.lock().expect("pool results").extend(local);
                });
            }
        });
        if let Some(e) = first_err.into_inner().expect("pool error slot") {
            return Err(e);
        }
        Ok(out.into_inner().expect("pool results"))
    }

    /// Freshen an existing chunk's mtime (best-effort) so the GC grace
    /// window protects *reused* chunks exactly like newly written ones — a
    /// concurrent session's teardown GC must not reap a chunk between this
    /// dedup decision and the manifest publish that re-references it.
    /// Sanity-checks the file's magic while it is open: a truncated or
    /// overwritten chunk file reads as absent, so the caller rewrites it
    /// instead of silently referencing garbage across every generation
    /// until the content changes. (Interior bit-rot is still caught at
    /// read time by the per-chunk CRC; `full_image_every` anchors bound
    /// how many generations one bad chunk can poison.)
    /// Returns false when the chunk file is absent or visibly damaged.
    fn refresh_chunk(&self, id: ChunkId) -> bool {
        use std::io::Read as _;
        let path = self.chunk_path(id);
        match std::fs::OpenOptions::new().read(true).write(true).open(&path) {
            Ok(mut f) => {
                let mut magic = [0u8; 8];
                if f.read_exact(&mut magic).is_err() || &magic != CHUNK_MAGIC {
                    return false;
                }
                let now = std::time::SystemTime::now();
                let _ = f.set_times(std::fs::FileTimes::new().set_modified(now));
                true
            }
            Err(_) => false,
        }
    }

    /// Store one chunk if absent (refreshing its mtime if present).
    /// Returns `(bytes written, newly written)`. Publication uses a
    /// writer-unique staging file, so cross-process writers of the same
    /// content race only on the final rename — harmlessly, the bytes are
    /// identical.
    fn store_chunk(&self, cref: &ChunkRef, data: &[u8], gzip: bool) -> Result<(u64, bool)> {
        if self.refresh_chunk(cref.id) {
            return Ok((0, false));
        }
        let path = self.chunk_path(cref.id);
        let mut file = Vec::with_capacity(data.len() / 2 + 16);
        file.extend_from_slice(CHUNK_MAGIC);
        if gzip {
            file.push(CHUNK_FLAG_GZIP);
            let mut enc = GzEncoder::new(file, Compression::fast());
            enc.write_all(data)?;
            file = enc.finish()?;
        } else {
            file.push(0);
            file.extend_from_slice(data);
        }
        atomic_publish(&path, &file)?;
        Ok((file.len() as u64, true))
    }

    /// Fetch and verify one chunk. Every failure mode — missing file, bad
    /// magic, gzip damage, length or CRC mismatch — is [`Error::Corrupt`].
    pub fn get_chunk(&self, cref: &ChunkRef) -> Result<Vec<u8>> {
        self.get_chunk_timed(cref).map(|(raw, _)| raw)
    }

    /// [`get_chunk`](Self::get_chunk) plus per-phase wall times
    /// `[read, decompress, verify]` in seconds — the restore pipeline's
    /// accounting primitive.
    fn get_chunk_timed(&self, cref: &ChunkRef) -> Result<(Vec<u8>, [f64; 3])> {
        let path = self.chunk_path(cref.id);
        let t_read = Instant::now();
        let bytes = std::fs::read(&path).map_err(|e| {
            Error::Corrupt(format!(
                "chunk {} missing from store {}: {e}",
                cref.id.hex(),
                self.root.display()
            ))
        })?;
        let read_secs = t_read.elapsed().as_secs_f64();
        if bytes.len() < CHUNK_MAGIC.len() + 1 || &bytes[..CHUNK_MAGIC.len()] != CHUNK_MAGIC {
            return Err(Error::Corrupt(format!(
                "chunk {}: bad chunk-file magic",
                cref.id.hex()
            )));
        }
        let flags = bytes[CHUNK_MAGIC.len()];
        let payload = &bytes[CHUNK_MAGIC.len() + 1..];
        let t_dec = Instant::now();
        let raw = if flags & CHUNK_FLAG_GZIP != 0 {
            let mut dec = GzDecoder::new(payload);
            let mut out = Vec::with_capacity(cref.raw_len as usize);
            dec.read_to_end(&mut out).map_err(|e| {
                Error::Corrupt(format!("chunk {}: gzip: {e}", cref.id.hex()))
            })?;
            out
        } else {
            payload.to_vec()
        };
        let decompress_secs = t_dec.elapsed().as_secs_f64();
        let t_ver = Instant::now();
        if raw.len() != cref.raw_len as usize {
            return Err(Error::Corrupt(format!(
                "chunk {}: length {} != manifest {}",
                cref.id.hex(),
                raw.len(),
                cref.raw_len
            )));
        }
        let got = crc32fast::hash(&raw);
        if got != cref.raw_crc {
            return Err(Error::Corrupt(format!(
                "chunk {}: CRC mismatch: stored {:08x}, computed {got:08x}",
                cref.id.hex(),
                cref.raw_crc
            )));
        }
        let verify_secs = t_ver.elapsed().as_secs_f64();
        Ok((raw, [read_secs, decompress_secs, verify_secs]))
    }

    /// Reassemble a full [`CheckpointImage`] from a manifest, verifying
    /// per-chunk and per-segment CRCs. Convenience wrapper over
    /// [`assemble_with_stats`](Self::assemble_with_stats) with the
    /// default worker pool.
    pub fn assemble(&self, manifest: &ImageManifest) -> Result<CheckpointImage> {
        self.assemble_with_stats(manifest, default_workers())
            .map(|(img, _)| img)
    }

    /// The parallel restore pipeline: fetch → decompress → CRC-verify
    /// every *distinct* chunk the manifest references over a worker pool
    /// (the write pool's twin), then stitch segments sequentially.
    ///
    /// Ordering guarantee (DESIGN §13): workers only populate a map keyed
    /// by [`ChunkId`] with fully verified raw bytes; segment assembly then
    /// walks the manifest in order on the calling thread. Output is
    /// therefore deterministic and bit-identical to a sequential restore
    /// regardless of worker count or interleaving. The per-restore memo
    /// means a chunk referenced by many segments (zero pages, replicated
    /// tables) is read, decompressed and verified exactly once; two
    /// references sharing a hash but disagreeing on length or CRC are
    /// typed corruption before any IO happens.
    pub fn assemble_with_stats(
        &self,
        manifest: &ImageManifest,
        workers: usize,
    ) -> Result<(CheckpointImage, RestoreStats)> {
        let mut sp = crate::trace::span(crate::trace::names::STORE_RESTORE)
            .with_u64("segments", manifest.segments.len() as u64);
        let t_wall = Instant::now();
        let mut unique: BTreeMap<ChunkId, ChunkRef> = BTreeMap::new();
        let mut total_refs = 0u64;
        for s in &manifest.segments {
            for c in &s.chunks {
                total_refs += 1;
                if let Some(prev) = unique.insert(c.id, *c) {
                    if prev.raw_len != c.raw_len || prev.raw_crc != c.raw_crc {
                        return Err(Error::Corrupt(format!(
                            "chunk {}: conflicting manifest references (len {} \
                             crc {:08x} vs len {} crc {:08x})",
                            c.id.hex(),
                            prev.raw_len,
                            prev.raw_crc,
                            c.raw_len,
                            c.raw_crc
                        )));
                    }
                }
            }
        }
        let refs: Vec<ChunkRef> = unique.into_values().collect();
        let mut stats = RestoreStats {
            chunk_reads: refs.len() as u64,
            chunks_memoized: total_refs - refs.len() as u64,
            workers: workers.clamp(1, refs.len().max(1)),
            ..RestoreStats::default()
        };

        let cursor = AtomicUsize::new(0);
        let fetched: Mutex<BTreeMap<ChunkId, Vec<u8>>> = Mutex::new(BTreeMap::new());
        let first_err: Mutex<Option<Error>> = Mutex::new(None);
        let phases: Mutex<[f64; 3]> = Mutex::new([0.0; 3]);
        std::thread::scope(|scope| {
            for _ in 0..stats.workers {
                scope.spawn(|| {
                    let mut local: Vec<(ChunkId, Vec<u8>)> = Vec::new();
                    let mut t = [0.0f64; 3];
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= refs.len() {
                            break;
                        }
                        let cref = refs[i];
                        match self.get_chunk_timed(&cref) {
                            Ok((raw, dt)) => {
                                for (a, d) in t.iter_mut().zip(dt) {
                                    *a += d;
                                }
                                local.push((cref.id, raw));
                            }
                            Err(e) => {
                                let mut g = first_err.lock().expect("pool error slot");
                                if g.is_none() {
                                    *g = Some(e);
                                }
                                break;
                            }
                        }
                    }
                    fetched.lock().expect("restore results").extend(local);
                    let mut g = phases.lock().expect("phase timings");
                    for (a, d) in g.iter_mut().zip(t) {
                        *a += d;
                    }
                });
            }
        });
        if let Some(e) = first_err.into_inner().expect("pool error slot") {
            return Err(e);
        }
        let fetched = fetched.into_inner().expect("restore results");
        let [r, d, v] = phases.into_inner().expect("phase timings");
        stats.read_secs = r;
        stats.decompress_secs = d;
        stats.verify_secs = v;

        // Sequential, deterministic stitch + per-segment CRC.
        let mut segments = Vec::with_capacity(manifest.segments.len());
        for s in &manifest.segments {
            let mut data = Vec::with_capacity(s.raw_len as usize);
            for c in &s.chunks {
                let raw = fetched.get(&c.id).ok_or_else(|| {
                    Error::Corrupt(format!("chunk {} vanished mid-restore", c.id.hex()))
                })?;
                data.extend_from_slice(raw);
            }
            let t_ver = Instant::now();
            let got = crc32fast::hash(&data);
            stats.verify_secs += t_ver.elapsed().as_secs_f64();
            if got != s.raw_crc {
                return Err(Error::Corrupt(format!(
                    "segment {:?}: CRC mismatch after reassembly: stored {:08x}, \
                     computed {got:08x}",
                    s.name, s.raw_crc
                )));
            }
            segments.push((s.name.clone(), data));
        }
        stats.wall_secs = t_wall.elapsed().as_secs_f64();
        if sp.is_active() {
            sp.note_u64("chunk_reads", stats.chunk_reads);
            sp.note_u64("chunks_memoized", stats.chunks_memoized);
            sp.note_u64("workers", stats.workers as u64);
            // Pool-summed phase times as backdated child spans: the
            // catapult view shows where a restore spent its time even
            // though the phases interleave inside `get_chunk_timed`.
            for (name, secs) in [
                (crate::trace::names::STORE_READ, stats.read_secs),
                (crate::trace::names::STORE_DECOMPRESS, stats.decompress_secs),
                (crate::trace::names::STORE_VERIFY, stats.verify_secs),
            ] {
                crate::trace::closed_span(name, Duration::from_secs_f64(secs.max(0.0)), |a| {
                    a.u64("chunks", stats.chunk_reads);
                    a.f64("pool_secs", secs);
                });
            }
        }
        Ok((
            CheckpointImage {
                header: manifest.header.clone(),
                segments,
            },
            stats,
        ))
    }

    /// Delete chunks referenced by no `*.dmtcp` manifest under `ckpt_dir`,
    /// skipping chunks younger than `min_age` (grace window for a
    /// concurrent writer that has stored — or refreshed, for dedup reuse —
    /// chunks but not yet published the manifest that references them).
    /// Unreadable images contribute no references (they cannot be restored
    /// either way).
    pub fn gc(&self, ckpt_dir: &Path, min_age: Duration) -> Result<GcStats> {
        let mut stats = GcStats::default();
        let mut live: BTreeSet<ChunkId> = BTreeSet::new();
        if let Ok(entries) = std::fs::read_dir(ckpt_dir) {
            for e in entries.flatten() {
                let p = e.path();
                if p.extension().map(|x| x == "dmtcp").unwrap_or(false) {
                    if let Ok(Some(m)) = read_manifest_file(&p) {
                        for s in &m.segments {
                            live.extend(s.chunks.iter().map(|c| c.id));
                        }
                    }
                }
            }
        }
        stats.live = live.len() as u64;
        let now = std::time::SystemTime::now();
        let Ok(buckets) = std::fs::read_dir(&self.root) else {
            return Ok(stats); // no store yet: nothing to reclaim
        };
        for bucket in buckets.flatten() {
            let Ok(files) = std::fs::read_dir(bucket.path()) else {
                continue;
            };
            for f in files.flatten() {
                let p = f.path();
                let Some(id) = p
                    .file_stem()
                    .and_then(|s| s.to_str())
                    .and_then(ChunkId::from_hex)
                else {
                    // Crash debris: a staging file whose writer died before
                    // the rename. Reap it once it is older than the grace
                    // window; anything else is a stranger we leave alone.
                    let stale_tmp = p
                        .file_name()
                        .and_then(|n| n.to_str())
                        .map(|n| n.contains(".chunk.tmp."))
                        .unwrap_or(false)
                        && f.metadata()
                            .ok()
                            .and_then(|m| m.modified().ok())
                            .and_then(|t| now.duration_since(t).ok())
                            .map(|age| age >= min_age)
                            .unwrap_or(false);
                    if stale_tmp {
                        let _ = std::fs::remove_file(&p);
                    }
                    continue;
                };
                stats.scanned += 1;
                if live.contains(&id) {
                    continue;
                }
                let meta = f.metadata().ok();
                let young = meta
                    .as_ref()
                    .and_then(|m| m.modified().ok())
                    .and_then(|t| now.duration_since(t).ok())
                    .map(|age| age < min_age)
                    .unwrap_or(true);
                if min_age > Duration::ZERO && young {
                    continue;
                }
                let len = meta.map(|m| m.len()).unwrap_or(0);
                if std::fs::remove_file(&p).is_ok() {
                    stats.deleted += 1;
                    stats.deleted_bytes += len;
                }
            }
        }
        Ok(stats)
    }
}

/// Parse an image file's manifest if it is v2; `Ok(None)` for v1 images.
fn read_manifest_file(path: &Path) -> Result<Option<ImageManifest>> {
    let bytes = std::fs::read(path)
        .map_err(|e| Error::Image(format!("{}: {e}", path.display())))?;
    let (version, _flags, body) = image::unframe(&bytes)?;
    match version {
        VERSION_MANIFEST => Ok(Some(ImageManifest::decode(body)?)),
        _ => Ok(None),
    }
}

/// Read a checkpoint image of either version: v1 full images decode
/// standalone; v2 manifests reassemble from `<dir>/store/` next to the
/// image file. This is what `CheckpointImage::read_file` and
/// `dmtcp_restart` call.
pub fn read_image_file(path: &Path) -> Result<CheckpointImage> {
    read_image_file_with_stats(path).map(|(img, _)| img)
}

/// [`read_image_file`] plus the restore pipeline's per-phase stats.
/// `None` for v1 full images — they decode inline with no chunk store, so
/// there are no restore phases to report.
pub fn read_image_file_with_stats(
    path: &Path,
) -> Result<(CheckpointImage, Option<RestoreStats>)> {
    let bytes = std::fs::read(path)
        .map_err(|e| Error::Image(format!("{}: {e}", path.display())))?;
    let (version, flags, body) = image::unframe(&bytes)?;
    match version {
        VERSION_FULL => Ok((CheckpointImage::from_unframed(flags, body)?, None)),
        VERSION_MANIFEST => {
            let manifest = ImageManifest::decode(body)?;
            let dir = path.parent().unwrap_or(Path::new("."));
            let (img, stats) =
                ImageStore::for_images(dir).assemble_with_stats(&manifest, default_workers())?;
            Ok((img, Some(stats)))
        }
        other => Err(Error::Image(format!("unsupported image version {other}"))),
    }
}

/// Read only the header of an image of either version (the
/// `dmtcp_restart --inspect` path) — v2 manifests need no chunk store for
/// this, so inspection works even when the store is damaged.
pub fn inspect_image_file(path: &Path) -> Result<ImageHeader> {
    let bytes = std::fs::read(path)
        .map_err(|e| Error::Image(format!("{}: {e}", path.display())))?;
    let (version, flags, body) = image::unframe(&bytes)?;
    match version {
        VERSION_FULL => Ok(CheckpointImage::from_unframed(flags, body)?.header),
        VERSION_MANIFEST => Ok(ImageManifest::decode(body)?.header),
        other => Err(Error::Image(format!("unsupported image version {other}"))),
    }
}

/// The image version (1 full, 2 manifest, 3 gang manifest) of an image
/// file, for tooling and tests.
pub fn image_version(path: &Path) -> Result<u32> {
    let bytes = std::fs::read(path)
        .map_err(|e| Error::Image(format!("{}: {e}", path.display())))?;
    Ok(image::unframe(&bytes)?.0)
}

// ---- gang manifests --------------------------------------------------------

/// One rank's image in a gang checkpoint.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GangRankEntry {
    /// Gang rank (0-based, contiguous).
    pub rank: u32,
    /// Virtual pid the rank runs (and restarts) under.
    pub vpid: u64,
    /// Image file name, relative to the gang manifest's directory (the
    /// set stays portable across substrates and volume mappings).
    pub image: String,
    /// Steps the rank had completed at the consistent cut.
    pub steps_done: u64,
    /// Bytes the rank's image stored (whole file for v1; manifest plus
    /// new chunks for v2 incremental images).
    pub stored_bytes: u64,
    /// Raw (logical) bytes the rank's image described.
    pub raw_bytes: u64,
}

/// The consistent-cut record of one gang checkpoint round: which rank
/// images belong together, written *atomically, once, after every rank
/// image of the round is durably published*. A gang restart trusts only
/// this file — per-rank images are round-stamped and immutable once a
/// manifest references them, so a torn or aborted round can never be
/// confused with a restartable one (invariant 7, DESIGN §10).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GangManifest {
    /// The gang's process-name base (session-nonce-scoped, like image
    /// names).
    pub gang: String,
    /// Restart generation of the incarnation that took the checkpoint.
    pub generation: u32,
    /// Coordinator round id — the generation stamp of the cut.
    pub ckpt_id: u64,
    /// Per-rank entries, rank order (contiguous from 0).
    pub ranks: Vec<GangRankEntry>,
}

impl GangManifest {
    /// Number of ranks in the gang.
    pub fn n_ranks(&self) -> u32 {
        self.ranks.len() as u32
    }

    /// Total stored bytes across the rank images.
    pub fn stored_bytes(&self) -> u64 {
        self.ranks.iter().map(|r| r.stored_bytes).sum()
    }

    /// The slowest rank's progress at the cut (a gang restart resumes the
    /// whole computation from a cut, so the gang's resume point is the
    /// minimum).
    pub fn cut_steps(&self) -> u64 {
        self.ranks.iter().map(|r| r.steps_done).min().unwrap_or(0)
    }

    /// The file name a gang manifest of `gang` for round `ckpt_id` is
    /// published under.
    pub fn file_name(gang: &str, ckpt_id: u64) -> String {
        format!("gang_{gang}_{ckpt_id:08}.gang")
    }

    fn encode(&self) -> Vec<u8> {
        let mut b = Vec::new();
        b.put_lp_str(&self.gang);
        b.put_u32(self.generation);
        b.put_u64(self.ckpt_id);
        b.put_u32(self.ranks.len() as u32);
        for r in &self.ranks {
            b.put_u32(r.rank);
            b.put_u64(r.vpid);
            b.put_lp_str(&r.image);
            b.put_u64(r.steps_done);
            b.put_u64(r.stored_bytes);
            b.put_u64(r.raw_bytes);
        }
        b
    }

    fn decode(body: &[u8]) -> Result<Self> {
        let mut r = ByteReader::new(body);
        let gang = r.get_lp_str()?;
        let generation = r.get_u32()?;
        let ckpt_id = r.get_u64()?;
        let n = r.get_u32()?;
        let mut ranks = Vec::with_capacity(n as usize);
        for _ in 0..n {
            ranks.push(GangRankEntry {
                rank: r.get_u32()?,
                vpid: r.get_u64()?,
                image: r.get_lp_str()?,
                steps_done: r.get_u64()?,
                stored_bytes: r.get_u64()?,
                raw_bytes: r.get_u64()?,
            });
        }
        if r.remaining() != 0 {
            return Err(Error::Image(format!(
                "{} trailing bytes after gang manifest",
                r.remaining()
            )));
        }
        let m = Self {
            gang,
            generation,
            ckpt_id,
            ranks,
        };
        m.validate()?;
        Ok(m)
    }

    /// Structural validation shared by the writer and the reader: a gang
    /// manifest describes a complete, contiguous, duplicate-free rank set.
    pub fn validate(&self) -> Result<()> {
        if self.ranks.is_empty() {
            return Err(Error::Image("gang manifest with zero ranks".into()));
        }
        for (i, e) in self.ranks.iter().enumerate() {
            if e.rank != i as u32 {
                return Err(Error::Image(format!(
                    "gang manifest ranks not contiguous: position {i} holds rank {}",
                    e.rank
                )));
            }
        }
        Ok(())
    }

    /// Atomically publish the manifest into `dir` under its canonical
    /// name; returns the path. Callers only invoke this once every rank
    /// image of the round is durably on disk — the rename is the commit
    /// point of the whole gang checkpoint.
    pub fn write_file(&self, dir: &Path) -> Result<PathBuf> {
        self.validate()?;
        let path = dir.join(Self::file_name(&self.gang, self.ckpt_id));
        let bytes = image::frame(VERSION_GANG, 0, &self.encode());
        atomic_write(&path, &bytes)?;
        Ok(path)
    }

    /// Read and verify a gang manifest (magic, version, body CRC,
    /// structural validity).
    pub fn read_file(path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .map_err(|e| Error::Image(format!("{}: {e}", path.display())))?;
        let (version, _flags, body) = image::unframe(&bytes)?;
        if version != VERSION_GANG {
            return Err(Error::Image(format!(
                "{}: image version {version} is not a gang manifest",
                path.display()
            )));
        }
        Self::decode(body)
    }
}

/// Find the newest restartable gang manifest for `gang` in `ckpt_dir`:
/// the highest `(generation, round id)` whose manifest reads back valid —
/// generation first, so even if round ids ever regressed across
/// incarnations a later generation's cut could not be shadowed by an
/// older one (the gang session additionally seeds each incarnation's
/// round ids above the restored cut's, keeping file names unique).
/// Unreadable or damaged manifests are skipped (an aborted writer or bit
/// rot must not mask an older good cut); `Ok(None)` when none exists.
pub fn latest_gang_manifest(ckpt_dir: &Path, gang: &str) -> Result<Option<(PathBuf, GangManifest)>> {
    Ok(gang_manifests(ckpt_dir, gang)?.into_iter().next())
}

/// All restartable gang manifests for `gang` in `ckpt_dir`, newest first
/// by `(generation, round id)`. The head is what [`latest_gang_manifest`]
/// returns; the tail is the fallback chain a restart walks when the
/// newest cut's *chunk store* turns out to be damaged — the manifest file
/// itself reads back valid (it has its own CRC) but a rank image it
/// references fails restore with a typed corruption error. Unreadable or
/// damaged manifest files are skipped as before.
pub fn gang_manifests(ckpt_dir: &Path, gang: &str) -> Result<Vec<(PathBuf, GangManifest)>> {
    let prefix = format!("gang_{gang}_");
    let mut found: Vec<((u32, u64), PathBuf, GangManifest)> = Vec::new();
    if let Ok(entries) = std::fs::read_dir(ckpt_dir) {
        for e in entries.flatten() {
            let p = e.path();
            let Some(name) = p.file_name().and_then(|n| n.to_str()) else {
                continue;
            };
            if !name.starts_with(&prefix) || !name.ends_with(".gang") {
                continue;
            }
            match GangManifest::read_file(&p) {
                Ok(m) if m.gang == gang => found.push(((m.generation, m.ckpt_id), p, m)),
                Ok(_) => {} // prefix collision with a longer gang name
                Err(e) => log::warn!("skipping unreadable gang manifest {name}: {e}"),
            }
        }
    }
    found.sort_by(|(a, _, _), (b, _, _)| b.cmp(a));
    Ok(found.into_iter().map(|(_, p, m)| (p, m)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "ncr_store_{tag}_{}_{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample_image(seed: u8) -> CheckpointImage {
        CheckpointImage {
            header: ImageHeader {
                vpid: 40001,
                name: "store_test".into(),
                ckpt_id: 1,
                ..Default::default()
            },
            segments: vec![
                ("a".into(), vec![seed; 200_000]),
                (
                    "b".into(),
                    (0..100_000u32).map(|i| (i % 251) as u8 ^ seed).collect(),
                ),
                ("empty".into(), Vec::new()),
            ],
        }
    }

    fn opts() -> StoreConfig {
        StoreConfig {
            chunk_size: 16 * 1024,
            workers: 3,
            gzip: true,
            chunker: ChunkerSpec::Fixed,
        }
    }

    /// SplitMix64 byte stream: CDC fixtures need real entropy — on
    /// near-periodic data every 13-byte gear window repeats and the
    /// boundary mask can simply never hit, degenerating CDC to max-size
    /// cuts.
    fn rand_bytes(n: usize, seed: u64) -> Vec<u8> {
        let mut s = seed;
        (0..n)
            .map(|_| {
                s = s.wrapping_add(0x9E37_79B9_7F4A_7C15);
                (mix64(s) >> 56) as u8
            })
            .collect()
    }

    fn cdc_opts() -> StoreConfig {
        StoreConfig {
            chunker: ChunkerSpec::Cdc {
                min: 2 * 1024,
                avg: 8 * 1024,
                max: 32 * 1024,
            },
            ..opts()
        }
    }

    #[test]
    fn chunk_id_deterministic_and_sensitive() {
        let a = ChunkId::of(b"hello world");
        assert_eq!(a, ChunkId::of(b"hello world"));
        assert_ne!(a, ChunkId::of(b"hello worle"));
        assert_ne!(ChunkId::of(b""), ChunkId::of(b"\0"));
        assert_eq!(ChunkId::from_hex(&a.hex()), Some(a));
        assert_eq!(ChunkId::from_hex("xyz"), None);
    }

    #[test]
    fn incremental_roundtrip_bitwise() {
        let d = dir("rt");
        let store = ImageStore::for_images(&d);
        let img = sample_image(7);
        let path = d.join("g1.dmtcp");
        let (manifest, stats) = store
            .write_incremental(&img, &path, None, &opts())
            .unwrap();
        assert_eq!(manifest.raw_bytes(), img.raw_segment_bytes());
        assert!(stats.chunks_written > 0);
        assert_eq!(stats.logical_bytes, img.raw_segment_bytes());
        let back = read_image_file(&path).unwrap();
        assert_eq!(img, back);
        assert_eq!(image_version(&path).unwrap(), VERSION_MANIFEST);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn second_generation_small_delta_dedups() {
        let d = dir("delta");
        let store = ImageStore::for_images(&d);
        let img1 = sample_image(7);
        let p1 = d.join("g1.dmtcp");
        let (m1, s1) = store.write_incremental(&img1, &p1, None, &opts()).unwrap();
        let prev: BTreeMap<String, SegmentManifest> = m1
            .segments
            .iter()
            .map(|s| (s.name.clone(), s.clone()))
            .collect();

        // Touch one chunk's worth of one segment.
        let mut img2 = img1.clone();
        img2.segments[1].1[5] ^= 0xFF;
        let p2 = d.join("g2.dmtcp");
        let (_, s2) = store
            .write_incremental(&img2, &p2, Some(&prev), &opts())
            .unwrap();
        assert!(
            s2.chunks_written <= 1,
            "one flipped byte should dirty at most one chunk, wrote {}",
            s2.chunks_written
        );
        assert!(
            s2.chunks_deduped > s2.chunks_written,
            "most chunks should be reused: {s2:?}"
        );
        assert!(
            s2.stored_bytes < s1.stored_bytes / 4,
            "delta write should be far smaller: {} vs {}",
            s2.stored_bytes,
            s1.stored_bytes
        );
        // Both generations restore bitwise.
        assert_eq!(read_image_file(&p1).unwrap(), img1);
        assert_eq!(read_image_file(&p2).unwrap(), img2);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn unchanged_segments_reuse_manifests_without_store_io() {
        let d = dir("clean");
        let store = ImageStore::for_images(&d);
        let img = sample_image(9);
        let p1 = d.join("g1.dmtcp");
        let (m1, _) = store.write_incremental(&img, &p1, None, &opts()).unwrap();
        let prev: BTreeMap<String, SegmentManifest> = m1
            .segments
            .iter()
            .map(|s| (s.name.clone(), s.clone()))
            .collect();
        let p2 = d.join("g2.dmtcp");
        let (m2, s2) = store
            .write_incremental(&img, &p2, Some(&prev), &opts())
            .unwrap();
        assert_eq!(s2.chunks_written, 0);
        assert_eq!(s2.chunks_deduped, m1.n_chunks() as u64);
        assert_eq!(m1.segments, m2.segments);
        // Only the manifest file itself hit the disk.
        assert!(s2.stored_bytes < 4096, "{}", s2.stored_bytes);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn missing_chunk_is_typed_corruption() {
        let d = dir("missing");
        let store = ImageStore::for_images(&d);
        let img = sample_image(3);
        let path = d.join("g.dmtcp");
        let (manifest, _) = store.write_incremental(&img, &path, None, &opts()).unwrap();
        let victim = manifest.segments[0].chunks[0];
        std::fs::remove_file(store.chunk_path(victim.id)).unwrap();
        match read_image_file(&path) {
            Err(Error::Corrupt(msg)) => assert!(msg.contains("missing"), "{msg}"),
            other => panic!("expected Error::Corrupt, got {other:?}"),
        }
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn bit_flipped_chunk_is_typed_corruption() {
        let d = dir("flip");
        let store = ImageStore::for_images(&d);
        let img = sample_image(4);
        let path = d.join("g.dmtcp");
        let (manifest, _) = store.write_incremental(&img, &path, None, &opts()).unwrap();
        let victim = store.chunk_path(manifest.segments[1].chunks[0].id);
        let mut bytes = std::fs::read(&victim).unwrap();
        let n = bytes.len();
        bytes[n - 1] ^= 0x01; // trailer byte: survives gzip framing checks
        std::fs::write(&victim, &bytes).unwrap();
        match read_image_file(&path) {
            Err(Error::Corrupt(_)) => {}
            other => panic!("expected Error::Corrupt, got {other:?}"),
        }
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn gc_reclaims_only_unreferenced_chunks() {
        let d = dir("gc");
        let store = ImageStore::for_images(&d);
        let img1 = sample_image(1);
        let mut img2 = sample_image(1);
        img2.segments[0].1 = vec![0x55; 200_000]; // gen2 rewrites segment a
        let p1 = d.join("g1.dmtcp");
        let p2 = d.join("g2.dmtcp");
        store.write_incremental(&img1, &p1, None, &opts()).unwrap();
        store.write_incremental(&img2, &p2, None, &opts()).unwrap();

        // Both manifests present: nothing is unreferenced.
        let none = store.gc(&d, Duration::ZERO).unwrap();
        assert_eq!(none.deleted, 0);
        assert!(none.live > 0);

        // Drop gen1: its now-unique chunks become garbage; gen2 survives.
        std::fs::remove_file(&p1).unwrap();
        let swept = store.gc(&d, Duration::ZERO).unwrap();
        assert!(swept.deleted > 0, "{swept:?}");
        assert_eq!(read_image_file(&p2).unwrap(), img2);
        // A huge grace window protects freshly written chunks.
        std::fs::remove_file(&p2).unwrap();
        let grace = store.gc(&d, Duration::from_secs(3600)).unwrap();
        assert_eq!(grace.deleted, 0, "{grace:?}");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn v1_images_read_through_the_same_entry_points() {
        let d = dir("v1");
        let img = sample_image(2);
        let path = d.join("full.dmtcp");
        img.write_file(&path, true).unwrap();
        assert_eq!(image_version(&path).unwrap(), VERSION_FULL);
        assert_eq!(read_image_file(&path).unwrap(), img);
        assert_eq!(inspect_image_file(&path).unwrap(), img.header);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn inspect_does_not_need_the_store() {
        let d = dir("inspect");
        let store = ImageStore::for_images(&d);
        let img = sample_image(6);
        let path = d.join("g.dmtcp");
        store.write_incremental(&img, &path, None, &opts()).unwrap();
        std::fs::remove_dir_all(store.root()).unwrap();
        assert_eq!(inspect_image_file(&path).unwrap(), img.header);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn uncompressed_chunks_roundtrip() {
        let d = dir("nogz");
        let store = ImageStore::for_images(&d);
        let img = sample_image(8);
        let path = d.join("g.dmtcp");
        let o = StoreConfig {
            gzip: false,
            ..opts()
        };
        store.write_incremental(&img, &path, None, &o).unwrap();
        assert_eq!(read_image_file(&path).unwrap(), img);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn chunker_spec_parses_and_round_trips() {
        use std::str::FromStr as _;
        for (s, want) in [
            ("fixed", ChunkerSpec::Fixed),
            ("cdc", ChunkerSpec::cdc_default()),
            (
                "cdc:1024:4096:16384",
                ChunkerSpec::Cdc {
                    min: 1024,
                    avg: 4096,
                    max: 16384,
                },
            ),
        ] {
            let got = ChunkerSpec::from_str(s).unwrap();
            assert_eq!(got, want, "{s}");
            // Display round-trips through FromStr.
            assert_eq!(ChunkerSpec::from_str(&got.to_string()).unwrap(), got);
        }
        for bad in [
            "",
            "nope",
            "cdc:1:2",
            "cdc:1:2:3:4",
            "cdc:0:4096:16384",     // min must be >= 1
            "cdc:8192:4096:16384",  // min > avg
            "cdc:1024:5000:16384",  // avg not a power of two
            "cdc:1024:16384:4096",  // avg > max
            "cdc:a:4096:16384",     // not a byte count
        ] {
            match ChunkerSpec::from_str(bad) {
                Err(Error::Usage(_)) => {}
                other => panic!("{bad:?} should be Error::Usage, got {other:?}"),
            }
        }
    }

    #[test]
    fn chunk_ranges_cover_exactly_and_respect_bounds() {
        let data = rand_bytes(200_000, 5);
        for (cfg, min, max) in [
            (ChunkerSpec::Fixed, 1, 16 * 1024),
            (
                ChunkerSpec::Cdc {
                    min: 2 * 1024,
                    avg: 8 * 1024,
                    max: 32 * 1024,
                },
                2 * 1024,
                32 * 1024,
            ),
        ] {
            let ranges = chunk_ranges(&data, 16 * 1024, cfg);
            assert_eq!(ranges.first().unwrap().0, 0);
            assert_eq!(ranges.last().unwrap().1, data.len());
            for w in ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0, "{cfg:?}: ranges must tile");
            }
            for (i, &(s, e)) in ranges.iter().enumerate() {
                assert!(e > s, "{cfg:?}: empty range");
                assert!(e - s <= max, "{cfg:?}: range {i} too long: {}", e - s);
                if i + 1 < ranges.len() {
                    assert!(e - s >= min, "{cfg:?}: interior range {i} too short");
                }
            }
        }
        assert!(chunk_ranges(&[], 1024, ChunkerSpec::cdc_default()).is_empty());
    }

    #[test]
    fn cdc_images_restore_bit_identical() {
        let d = dir("cdc_rt");
        let store = ImageStore::for_images(&d);
        let img = sample_image(12);
        let path = d.join("g.dmtcp");
        let (manifest, _) = store
            .write_incremental(&img, &path, None, &cdc_opts())
            .unwrap();
        assert_eq!(manifest.raw_bytes(), img.raw_segment_bytes());
        assert_eq!(read_image_file(&path).unwrap(), img);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn cdc_survives_insert_shift_where_fixed_does_not() {
        // Insert a few bytes near the front of a big random segment:
        // fixed chunking shifts every later boundary and rewrites nearly
        // everything; CDC boundaries re-synchronize after the insert.
        let seg = rand_bytes(1_000_000, 77);
        let mut shifted = seg.clone();
        for (k, b) in [7u8, 33, 99].iter().enumerate() {
            shifted.insert(1000 + k, *b);
        }
        let mk = |data: &[u8]| CheckpointImage {
            header: ImageHeader {
                vpid: 40002,
                name: "cdc_shift".into(),
                ckpt_id: 1,
                ..Default::default()
            },
            segments: vec![("seg".into(), data.to_vec())],
        };
        let mut written = BTreeMap::new();
        for (tag, cfg) in [("fixed", opts()), ("cdc", cdc_opts())] {
            let d = dir(&format!("shift_{tag}"));
            let store = ImageStore::for_images(&d);
            let p1 = d.join("g1.dmtcp");
            let p2 = d.join("g2.dmtcp");
            store
                .write_incremental(&mk(&seg), &p1, None, &cfg)
                .unwrap();
            let (_, s2) = store
                .write_incremental(&mk(&shifted), &p2, None, &cfg)
                .unwrap();
            assert_eq!(read_image_file(&p2).unwrap(), mk(&shifted));
            written.insert(tag, s2.chunks_written);
            std::fs::remove_dir_all(&d).ok();
        }
        assert!(
            written["cdc"] * 4 < written["fixed"],
            "CDC should rewrite far fewer chunks after an insert: {written:?}"
        );
    }

    #[test]
    fn restore_memo_reads_each_distinct_chunk_once() {
        // Dedup-heavy image: many segments of identical content reference
        // the same chunks; the restore memo must fetch each distinct
        // chunk once and serve the other references from memory.
        let d = dir("memo");
        let store = ImageStore::for_images(&d);
        let body = vec![0xA5u8; 64 * 1024];
        let img = CheckpointImage {
            header: ImageHeader {
                vpid: 40003,
                name: "memo".into(),
                ckpt_id: 1,
                ..Default::default()
            },
            segments: (0..6)
                .map(|i| (format!("seg{i}"), body.clone()))
                .collect(),
        };
        let path = d.join("g.dmtcp");
        let (manifest, _) = store.write_incremental(&img, &path, None, &opts()).unwrap();
        let total_refs = manifest.n_chunks() as u64;
        let (back, stats) = store.assemble_with_stats(&manifest, 4).unwrap();
        assert_eq!(back, img);
        assert!(
            stats.chunk_reads < total_refs,
            "memo should cut chunk-file reads: {} reads for {total_refs} refs",
            stats.chunk_reads
        );
        assert_eq!(stats.chunk_reads + stats.chunks_memoized, total_refs);
        // Exactly the distinct-id set hits the disk (here a single chunk:
        // every 16 KiB slice of the constant segment has the same id).
        let distinct: BTreeSet<ChunkId> = manifest
            .segments
            .iter()
            .flat_map(|s| s.chunks.iter().map(|c| c.id))
            .collect();
        assert_eq!(stats.chunk_reads, distinct.len() as u64);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn parallel_restore_matches_sequential_bitwise() {
        let d = dir("par_rt");
        let store = ImageStore::for_images(&d);
        let img = sample_image(13);
        let path = d.join("g.dmtcp");
        let (manifest, _) = store
            .write_incremental(&img, &path, None, &cdc_opts())
            .unwrap();
        let (seq, s1) = store.assemble_with_stats(&manifest, 1).unwrap();
        for w in [2, 4, 8] {
            let (par, sw) = store.assemble_with_stats(&manifest, w).unwrap();
            assert_eq!(seq, par, "workers={w}");
            assert_eq!(sw.chunk_reads, s1.chunk_reads);
        }
        assert_eq!(seq, img);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn truncated_manifest_rejected() {
        let d = dir("trunc");
        let store = ImageStore::for_images(&d);
        let img = sample_image(5);
        let path = d.join("g.dmtcp");
        store.write_incremental(&img, &path, None, &opts()).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        for cut in [0, 7, 12, bytes.len() / 2, bytes.len() - 1] {
            std::fs::write(&path, &bytes[..cut]).unwrap();
            assert!(read_image_file(&path).is_err(), "cut={cut} accepted");
        }
        std::fs::remove_dir_all(&d).ok();
    }
}
