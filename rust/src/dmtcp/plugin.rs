//! Plugin / event-hook architecture.
//!
//! DMTCP extends itself through plugins that receive event callbacks around
//! the checkpoint lifecycle and can persist named records inside the image
//! (the paper: "a plugin architecture, which facilitates event hooks and
//! function wrappers for process virtualization"). This module reproduces
//! that: a [`Plugin`] trait with lifecycle [`Event`]s, a [`PluginRegistry`]
//! per process, and image-carried records written at `PreCheckpoint` and
//! replayed at `PostRestart`.
//!
//! Built-ins:
//! * [`TimerPlugin`] — virtualizes elapsed runtime across restarts (the job
//!   script's "converting execution time into a human-readable format and
//!   calculating the remaining time" needs total-runtime-so-far, which a
//!   fresh incarnation cannot know without this record).
//! * [`EnvPlugin`] — captures environment variables and re-exports them on
//!   restart ("applications can resume ... with the same runtime context,
//!   including ... modifiable environment settings").

use std::collections::BTreeMap;
use std::time::Instant;

use crate::error::Result;
#[cfg(test)]
use crate::error::Error;
use crate::util::bytes::{ByteReader, PutBytes};

/// Checkpoint-lifecycle events delivered to plugins, in protocol order.
///
/// The five barrier phases each have a hook: `Suspend` (threads parked),
/// `Drain` (quiesce in-flight channel data — the gang C/R drain plugins
/// move undelivered rank-to-rank messages into the checkpointable state
/// here, so the image set is a consistent cut), `PreCheckpoint` (about to
/// serialize), `Refill` (re-prime drained channels), and `PostCheckpoint`
/// (resuming). `PostRestart` and `Kill` are the out-of-barrier events.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// All user threads parked at their safe-points (SUSPEND phase).
    Suspend,
    /// Flush in-flight channel/socket data into the checkpointable state
    /// (DRAIN phase). All processes of the computation are suspended when
    /// this fires — the global barrier orders SUSPEND before any DRAIN.
    Drain,
    /// All user threads parked; about to serialize.
    PreCheckpoint,
    /// Re-prime drained channels (REFILL phase).
    Refill,
    /// Image written; process continuing (checkpoint-only path).
    PostCheckpoint,
    /// Process reconstructed from an image; records available.
    PostRestart,
    /// Process received a kill/preemption request.
    Kill,
}

/// Mutable context handed to plugins at each event.
pub struct PluginCtx<'a> {
    /// Named records carried inside the checkpoint image. Plugins write
    /// these at `PreCheckpoint` and read them at `PostRestart`.
    pub records: &'a mut BTreeMap<String, Vec<u8>>,
    /// The process's environment map.
    pub env: &'a mut BTreeMap<String, String>,
    /// Restart generation of the running incarnation.
    pub generation: u32,
}

/// A checkpoint-lifecycle plugin.
pub trait Plugin: Send {
    fn name(&self) -> &'static str;
    fn on_event(&mut self, event: Event, ctx: &mut PluginCtx<'_>) -> Result<()>;
}

/// Ordered plugin collection for one process.
#[derive(Default)]
pub struct PluginRegistry {
    plugins: Vec<Box<dyn Plugin>>,
}

impl PluginRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register(&mut self, p: Box<dyn Plugin>) {
        self.plugins.push(p);
    }

    pub fn len(&self) -> usize {
        self.plugins.len()
    }

    pub fn is_empty(&self) -> bool {
        self.plugins.is_empty()
    }

    /// Deliver `event` to all plugins in registration order
    /// (`PostRestart`/`Kill` in reverse order, mirroring DMTCP's barriers).
    pub fn fire(&mut self, event: Event, ctx: &mut PluginCtx<'_>) -> Result<()> {
        match event {
            Event::PostRestart | Event::Kill => {
                for p in self.plugins.iter_mut().rev() {
                    p.on_event(event, ctx)?;
                }
            }
            _ => {
                for p in self.plugins.iter_mut() {
                    p.on_event(event, ctx)?;
                }
            }
        }
        Ok(())
    }
}

/// Virtualizes total elapsed runtime across restarts.
///
/// Record format: `u64 accumulated_nanos || u32 incarnations`.
pub struct TimerPlugin {
    started: Instant,
    accumulated_nanos: u64,
    incarnations: u32,
}

impl TimerPlugin {
    pub fn new() -> Self {
        Self {
            started: Instant::now(),
            accumulated_nanos: 0,
            incarnations: 1,
        }
    }

    /// Total virtual runtime: prior incarnations + this one.
    pub fn total_runtime_nanos(&self) -> u64 {
        self.accumulated_nanos + self.started.elapsed().as_nanos() as u64
    }

    pub fn incarnations(&self) -> u32 {
        self.incarnations
    }

    const KEY: &'static str = "timer";

    fn encode(&self) -> Vec<u8> {
        let mut b = Vec::new();
        b.put_u64(self.total_runtime_nanos());
        b.put_u32(self.incarnations);
        b
    }

    fn decode(buf: &[u8]) -> Result<(u64, u32)> {
        let mut r = ByteReader::new(buf);
        Ok((r.get_u64()?, r.get_u32()?))
    }
}

impl Default for TimerPlugin {
    fn default() -> Self {
        Self::new()
    }
}

impl Plugin for TimerPlugin {
    fn name(&self) -> &'static str {
        "timer"
    }

    fn on_event(&mut self, event: Event, ctx: &mut PluginCtx<'_>) -> Result<()> {
        match event {
            Event::PreCheckpoint => {
                ctx.records.insert(Self::KEY.into(), self.encode());
            }
            Event::PostRestart => {
                if let Some(rec) = ctx.records.get(Self::KEY) {
                    let (nanos, inc) = Self::decode(rec)?;
                    self.accumulated_nanos = nanos;
                    self.incarnations = inc + 1;
                    self.started = Instant::now();
                }
            }
            _ => {}
        }
        Ok(())
    }
}

/// Captures the environment at checkpoint and re-exports it on restart.
///
/// Record format: `u32 count || (lp_str key, lp_str val)*`.
#[derive(Default)]
pub struct EnvPlugin;

impl EnvPlugin {
    const KEY: &'static str = "env";
}

impl Plugin for EnvPlugin {
    fn name(&self) -> &'static str {
        "env"
    }

    fn on_event(&mut self, event: Event, ctx: &mut PluginCtx<'_>) -> Result<()> {
        match event {
            Event::PreCheckpoint => {
                let mut b = Vec::new();
                b.put_u32(ctx.env.len() as u32);
                for (k, v) in ctx.env.iter() {
                    b.put_lp_str(k);
                    b.put_lp_str(v);
                }
                ctx.records.insert(Self::KEY.into(), b);
            }
            Event::PostRestart => {
                if let Some(rec) = ctx.records.get(Self::KEY).cloned() {
                    let mut r = ByteReader::new(&rec);
                    let n = r.get_u32()?;
                    for _ in 0..n {
                        let k = r.get_lp_str()?;
                        let v = r.get_lp_str()?;
                        // Restored records win over incarnation defaults,
                        // except the coordinator address, which the restart
                        // path sets for the *new* coordinator.
                        if k != "DMTCP_COORD_HOST" && k != "DMTCP_COORD_PORT" {
                            ctx.env.insert(k, v);
                        }
                    }
                }
            }
            _ => {}
        }
        Ok(())
    }
}

/// Test plugin: counts events and can be told to fail.
#[cfg(test)]
pub struct ProbePlugin {
    pub log: std::sync::Arc<std::sync::Mutex<Vec<(String, Event)>>>,
    pub tag: String,
    pub fail_on: Option<Event>,
}

#[cfg(test)]
impl Plugin for ProbePlugin {
    fn name(&self) -> &'static str {
        "probe"
    }

    fn on_event(&mut self, event: Event, _ctx: &mut PluginCtx<'_>) -> Result<()> {
        self.log.lock().unwrap().push((self.tag.clone(), event));
        if self.fail_on == Some(event) {
            return Err(Error::Protocol(format!("probe {0} failing", self.tag)));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    fn ctx_parts() -> (BTreeMap<String, Vec<u8>>, BTreeMap<String, String>) {
        (BTreeMap::new(), BTreeMap::new())
    }

    #[test]
    fn fire_order_forward_and_reverse() {
        let log = Arc::new(Mutex::new(Vec::new()));
        let mut reg = PluginRegistry::new();
        for tag in ["a", "b"] {
            reg.register(Box::new(ProbePlugin {
                log: Arc::clone(&log),
                tag: tag.into(),
                fail_on: None,
            }));
        }
        let (mut recs, mut env) = ctx_parts();
        let mut ctx = PluginCtx {
            records: &mut recs,
            env: &mut env,
            generation: 0,
        };
        reg.fire(Event::PreCheckpoint, &mut ctx).unwrap();
        reg.fire(Event::PostRestart, &mut ctx).unwrap();
        let got: Vec<(String, Event)> = log.lock().unwrap().clone();
        assert_eq!(
            got,
            vec![
                ("a".into(), Event::PreCheckpoint),
                ("b".into(), Event::PreCheckpoint),
                ("b".into(), Event::PostRestart),
                ("a".into(), Event::PostRestart),
            ]
        );
    }

    #[test]
    fn plugin_failure_propagates() {
        let log = Arc::new(Mutex::new(Vec::new()));
        let mut reg = PluginRegistry::new();
        reg.register(Box::new(ProbePlugin {
            log,
            tag: "x".into(),
            fail_on: Some(Event::PreCheckpoint),
        }));
        let (mut recs, mut env) = ctx_parts();
        let mut ctx = PluginCtx {
            records: &mut recs,
            env: &mut env,
            generation: 0,
        };
        assert!(reg.fire(Event::PreCheckpoint, &mut ctx).is_err());
    }

    #[test]
    fn timer_plugin_accumulates_across_restart() {
        let mut t = TimerPlugin::new();
        std::thread::sleep(std::time::Duration::from_millis(5));
        let (mut recs, mut env) = ctx_parts();
        let mut ctx = PluginCtx {
            records: &mut recs,
            env: &mut env,
            generation: 0,
        };
        t.on_event(Event::PreCheckpoint, &mut ctx).unwrap();
        let stored = recs.get("timer").cloned().unwrap();
        let (nanos, inc) = TimerPlugin::decode(&stored).unwrap();
        assert!(nanos >= 5_000_000);
        assert_eq!(inc, 1);

        // Fresh incarnation restores and keeps counting from the record.
        let mut t2 = TimerPlugin::new();
        let mut ctx2 = PluginCtx {
            records: &mut recs,
            env: &mut env,
            generation: 1,
        };
        t2.on_event(Event::PostRestart, &mut ctx2).unwrap();
        assert_eq!(t2.incarnations(), 2);
        assert!(t2.total_runtime_nanos() >= nanos);
    }

    #[test]
    fn env_plugin_roundtrip_excludes_coordinator_addr() {
        let mut p = EnvPlugin;
        let mut recs = BTreeMap::new();
        let mut env = BTreeMap::new();
        env.insert("G4VERSION".to_string(), "10.7".to_string());
        env.insert("DMTCP_COORD_HOST".to_string(), "old-node".to_string());
        let mut ctx = PluginCtx {
            records: &mut recs,
            env: &mut env,
            generation: 0,
        };
        p.on_event(Event::PreCheckpoint, &mut ctx).unwrap();

        let mut env2 = BTreeMap::new();
        env2.insert("DMTCP_COORD_HOST".to_string(), "new-node".to_string());
        let mut ctx2 = PluginCtx {
            records: &mut recs,
            env: &mut env2,
            generation: 1,
        };
        p.on_event(Event::PostRestart, &mut ctx2).unwrap();
        assert_eq!(env2.get("G4VERSION").map(String::as_str), Some("10.7"));
        assert_eq!(
            env2.get("DMTCP_COORD_HOST").map(String::as_str),
            Some("new-node"),
            "restored env must not clobber the new coordinator address"
        );
    }
}
