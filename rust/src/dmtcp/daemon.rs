//! The multi-tenant coordinator daemon: one event-driven loop multiplexing
//! many jobs' checkpoint barriers over a single port.
//!
//! The classic deployment (and PRs 1–5 of this repo) ran one blocking
//! coordinator — accept thread plus a reader thread per client — per
//! session, so coordinator thread and port count scaled with fleet size.
//! This module replaces that with a single long-lived readiness loop:
//!
//! * **one** loop thread owns the listener and every client socket, all
//!   nonblocking; it accepts, reads, parses frames, routes, advances
//!   barriers, and drains write queues in bounded ticks;
//! * a `JobId`-keyed **routing table** gives every job its own state
//!   machine (clients, pid table, barrier round, store totals): frames are
//!   delivered to exactly the job the connection's `Hello { job }`
//!   handshake routed it into, never across jobs;
//! * **per-job rounds**: one gang stalling in `Drain` cannot delay another
//!   job's five-phase barrier, because rounds are advanced independently
//!   per routing-table entry;
//! * **bounded write queues**: a client that stops draining its socket is
//!   disconnected (failing only its own job's round) once its queue or a
//!   phase deadline overflows — backpressure never stalls the loop.
//!
//! [`super::coordinator::Coordinator`] is now a per-job *handle* over this
//! daemon: `Coordinator::start` boots a private daemon (the default, used
//! by every single-session path), `Coordinator::attach` registers one more
//! job on a shared daemon (the fleet path, `nersc-cr daemon`).

use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::dmtcp::image::ImageInfo;
use crate::dmtcp::protocol::{
    decode_to_coordinator, encode_from_coordinator, FromCoordinator, Phase, ToCoordinator,
    MAX_FRAME,
};
use crate::dmtcp::virtualization::PidTable;
use crate::error::{Error, Result};

/// Frames a slow client may have queued before it is declared stalled and
/// disconnected. A healthy checkpoint client holds at most a handful of
/// outstanding frames (one phase broadcast at a time), so this bound only
/// trips for clients that stopped reading their socket.
const WQ_MAX_FRAMES: usize = 256;
/// Byte bound on one client's write queue (same backpressure semantic).
const WQ_MAX_BYTES: usize = 1 << 20;

/// How long a caller blocked on a round waits past the round's own phase
/// deadlines before declaring the daemon itself unresponsive.
const ROUND_GUARD_SLACK: Duration = Duration::from_secs(30);

/// Daemon configuration (the shared, fleet-facing knobs; per-job knobs
/// arrive with [`JobSpec`]).
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    /// Bind address; port 0 picks an ephemeral port.
    pub bind: String,
    /// Fall back to an ephemeral port when `bind`'s port is taken.
    pub retry_ephemeral: bool,
    /// Auto-register unknown jobs named in `Hello { job }` handshakes
    /// (the `nersc-cr daemon` CLI mode; library embedders register jobs
    /// explicitly and leave this off so typos surface as typed errors).
    pub auto_register_jobs: bool,
    /// Checkpoint directory for auto-registered jobs (per-job subdirs).
    pub auto_ckpt_dir: PathBuf,
    /// Phase timeout for auto-registered jobs.
    pub auto_phase_timeout: Duration,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        Self {
            bind: "127.0.0.1:0".into(),
            retry_ephemeral: true,
            auto_register_jobs: false,
            auto_ckpt_dir: std::env::temp_dir().join("nersc_cr_daemon_ckpt"),
            auto_phase_timeout: Duration::from_secs(30),
        }
    }
}

/// One job's registration on the daemon.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Routing key carried by `Hello { job }` handshakes.
    pub job: String,
    /// Directory this job's checkpoint images are written into.
    pub ckpt_dir: PathBuf,
    /// Barrier timeout per phase; a phase that misses it disconnects the
    /// stalled clients and fails (only) this job's round.
    pub phase_timeout: Duration,
}

/// Per-client record inside one job's routing-table entry.
struct ClientMeta {
    conn: u64,
    name: String,
    real_pid: u64,
    n_threads: u32,
    rank: Option<u32>,
}

/// One in-flight barrier round of one job.
struct Round {
    ckpt_id: u64,
    phase: Phase,
    pending: HashSet<u64>,
    images: Vec<ImageInfo>,
    failed: Option<String>,
    deadline: Instant,
    /// vpid → gang rank map captured (and validated) at round start; empty
    /// for non-gang rounds.
    rank_map: BTreeMap<u64, u32>,
    /// Command connection awaiting a `CkptComplete` reply, if the round
    /// was started by a `dmtcp_command` client rather than a handle.
    reply_conn: Option<u64>,
    /// Whether a handle thread is blocked on this round's result.
    waited: bool,
    /// Which fault domain felled the round, when an injector (rather
    /// than an organic stall) did — tags the flight dump.
    failed_domain: Option<&'static str>,
}

/// One entry of the routing table.
struct JobState {
    ckpt_dir: PathBuf,
    phase_timeout: Duration,
    clients: HashMap<u64, ClientMeta>,
    pid_table: PidTable,
    round: Option<Round>,
    /// Completed-round result parked for the waiting handle thread.
    round_result: Option<Result<(Vec<ImageInfo>, BTreeMap<u64, u32>)>>,
    /// One-shot armed fabric partition: when the next broadcast of the
    /// given phase goes out, these gang ranks become unreachable
    /// mid-barrier (see [`CoordinatorDaemon::inject_partition`]).
    armed_partition: Option<(Phase, Vec<u32>)>,
    next_ckpt_id: u64,
    last_ckpt_id: u64,
    images_written: u64,
    total_stored_bytes: u64,
    total_raw_bytes: u64,
    total_chunks_written: u64,
    total_chunks_deduped: u64,
}

impl JobState {
    fn new(spec: &JobSpec) -> Self {
        Self {
            ckpt_dir: spec.ckpt_dir.clone(),
            phase_timeout: spec.phase_timeout,
            clients: HashMap::new(),
            pid_table: PidTable::new(),
            round: None,
            round_result: None,
            armed_partition: None,
            next_ckpt_id: 1,
            last_ckpt_id: 0,
            images_written: 0,
            total_stored_bytes: 0,
            total_raw_bytes: 0,
            total_chunks_written: 0,
            total_chunks_deduped: 0,
        }
    }
}

/// One nonblocking connection owned by the loop.
struct Conn {
    stream: TcpStream,
    /// Unparsed inbound bytes (grown by reads, drained by frame parsing).
    rdbuf: Vec<u8>,
    /// Outbound frames (each already length-prefixed), drained nonblocking.
    wq: VecDeque<Vec<u8>>,
    /// Bytes of `wq.front()` already written.
    wq_front_off: usize,
    wq_bytes: usize,
    /// Routed job (set by the `Hello` handshake).
    job: Option<String>,
    /// Assigned virtual pid (set by the `Hello` handshake).
    vpid: Option<u64>,
    /// Flush the write queue, then close (error replies, kills).
    close_after_flush: bool,
    dead: bool,
}

struct DaemonState {
    jobs: HashMap<String, JobState>,
    conns: HashMap<u64, Conn>,
    next_conn_id: u64,
    jobs_registered_total: u64,
}

struct Shared {
    state: Mutex<DaemonState>,
    cv: Condvar,
    epoch: u64,
    shutdown: AtomicBool,
    config: DaemonConfig,
}

/// The multi-tenant coordinator daemon. One loop thread, one port, any
/// number of jobs. Cheap to share: handles hold an `Arc`.
pub struct CoordinatorDaemon {
    shared: Arc<Shared>,
    addr: SocketAddr,
    loop_join: Mutex<Option<std::thread::JoinHandle<()>>>,
    io_threads: AtomicUsize,
}

impl CoordinatorDaemon {
    /// Boot the daemon: bind (with the same ephemeral-port fallback the
    /// per-session coordinator always had) and start the readiness loop.
    pub fn start(config: DaemonConfig) -> Result<Arc<Self>> {
        let listener = match TcpListener::bind(&config.bind) {
            Ok(l) => l,
            Err(e) if e.kind() == std::io::ErrorKind::AddrInUse && config.retry_ephemeral => {
                let host = config
                    .bind
                    .rsplit_once(':')
                    .map(|(h, _)| h)
                    .unwrap_or("127.0.0.1");
                log::warn!(
                    "daemon bind {} in use; retrying on an ephemeral port",
                    config.bind
                );
                TcpListener::bind(format!("{host}:0"))?
            }
            Err(e) => return Err(e.into()),
        };
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let shared = Arc::new(Shared {
            state: Mutex::new(DaemonState {
                jobs: HashMap::new(),
                conns: HashMap::new(),
                next_conn_id: 1,
                jobs_registered_total: 0,
            }),
            cv: Condvar::new(),
            epoch: 1,
            shutdown: AtomicBool::new(false),
            config,
        });

        let loop_shared = Arc::clone(&shared);
        let loop_join = std::thread::Builder::new()
            .name("dmtcp-daemon-loop".into())
            .spawn(move || event_loop(loop_shared, listener))
            .expect("spawn daemon loop thread");

        let daemon = Arc::new(Self {
            shared,
            addr,
            loop_join: Mutex::new(Some(loop_join)),
            io_threads: AtomicUsize::new(1),
        });
        Ok(daemon)
    }

    /// The single socket address every job's clients connect to.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Register a job in the routing table (its ckpt dir is created).
    /// Duplicate keys are rejected: two live jobs must never share a
    /// routing-table entry.
    pub fn register_job(&self, spec: &JobSpec) -> Result<()> {
        std::fs::create_dir_all(&spec.ckpt_dir)?;
        let mut st = self.shared.state.lock().unwrap();
        if st.jobs.contains_key(&spec.job) {
            return Err(Error::Protocol(format!(
                "job {:?} already registered on this daemon",
                spec.job
            )));
        }
        st.jobs.insert(spec.job.clone(), JobState::new(spec));
        st.jobs_registered_total += 1;
        self.shared.cv.notify_all();
        Ok(())
    }

    /// Tear one job out of the routing table: fail its in-flight round,
    /// disconnect its clients, drop its state. Other jobs are untouched.
    pub fn close_job(&self, job: &str) {
        let mut st = self.shared.state.lock().unwrap();
        if let Some(mut j) = st.jobs.remove(job) {
            if let Some(round) = j.round.take() {
                if round.waited {
                    j.round_result = Some(Err(Error::Protocol(format!(
                        "job {job:?} closed during round {}",
                        round.ckpt_id
                    ))));
                }
            }
            for (_, c) in j.clients.drain() {
                if let Some(conn) = st.conns.get_mut(&c.conn) {
                    conn.dead = true;
                }
            }
        }
        self.shared.cv.notify_all();
    }

    /// Broadcast `Kill` to every client of `job` and wait (bounded) until
    /// the frames have been flushed and the connections reaped, so callers
    /// that join their worker processes right after cannot race the
    /// delivery of the kill.
    pub fn kill_job(&self, job: &str) {
        let mut st = self.shared.state.lock().unwrap();
        let conn_ids: Vec<u64> = match st.jobs.get(job) {
            Some(j) => j.clients.values().map(|c| c.conn).collect(),
            None => return,
        };
        for cid in &conn_ids {
            if let Some(conn) = st.conns.get_mut(cid) {
                enqueue_frame(conn, &FromCoordinator::Kill);
                conn.close_after_flush = true;
            }
        }
        let deadline = Instant::now() + Duration::from_secs(5);
        while conn_ids.iter().any(|cid| st.conns.contains_key(cid)) {
            if Instant::now() >= deadline || self.shutdown_flag() {
                break;
            }
            let (g, _) = self
                .shared
                .cv
                .wait_timeout(st, Duration::from_millis(20))
                .unwrap();
            st = g;
        }
    }

    /// Drive one five-phase barrier for `job`. With `expected_ranks` the
    /// round is an all-or-nothing gang round: ranks are validated at round
    /// start and the returned map carries vpid → rank. The calling thread
    /// blocks; the loop thread advances the phases.
    pub fn checkpoint_job(
        &self,
        job: &str,
        expected_ranks: Option<u32>,
    ) -> Result<(Vec<ImageInfo>, BTreeMap<u64, u32>)> {
        let mut st = self.shared.state.lock().unwrap();
        let now = Instant::now();
        let phase_timeout = st
            .jobs
            .get(job)
            .map(|j| j.phase_timeout)
            .unwrap_or(Duration::from_secs(30));
        start_round(&mut st, job, expected_ranks, None, true, now)?;
        let guard = now + phase_timeout * (Phase::ALL.len() as u32) + ROUND_GUARD_SLACK;
        loop {
            match st.jobs.get_mut(job) {
                None => {
                    return Err(Error::Protocol(format!(
                        "job {job:?} closed during checkpoint"
                    )))
                }
                Some(j) => {
                    if let Some(result) = j.round_result.take() {
                        return result;
                    }
                }
            }
            if self.shutdown_flag() {
                return Err(Error::Protocol("daemon shut down mid-round".into()));
            }
            if Instant::now() >= guard {
                // The loop enforces per-phase deadlines itself; reaching
                // this guard means the loop is gone. Unwedge the job.
                if let Some(j) = st.jobs.get_mut(job) {
                    j.round = None;
                }
                return Err(Error::Protocol("coordinator daemon unresponsive".into()));
            }
            let (g, _) = self
                .shared
                .cv
                .wait_timeout(st, Duration::from_millis(50))
                .unwrap();
            st = g;
        }
    }

    /// Arm a one-shot fabric partition for `job`: the moment the next
    /// barrier broadcast of `phase` goes out, the given gang `ranks`
    /// become unreachable mid-phase (their links are severed before any
    /// of them can ack), the round fails with a per-victim `PHASE_FAIL`
    /// pin, and survivors are resumed. The previous committed manifest
    /// stays restorable — that is exactly the invariant the partition
    /// torture suites assert. Unknown jobs are a typed error.
    pub fn inject_partition(&self, job: &str, phase: Phase, ranks: &[u32]) -> Result<()> {
        let mut st = self.shared.state.lock().unwrap();
        let j = st.jobs.get_mut(job).ok_or_else(|| {
            Error::Protocol(format!("inject_partition: unknown job {job:?}"))
        })?;
        j.armed_partition = Some((phase, ranks.to_vec()));
        Ok(())
    }

    /// Ensure `job`'s future round ids start at or above `min`.
    pub fn bump_ckpt_id(&self, job: &str, min: u64) {
        let mut st = self.shared.state.lock().unwrap();
        if let Some(j) = st.jobs.get_mut(job) {
            j.next_ckpt_id = j.next_ckpt_id.max(min);
        }
    }

    /// Block until `job` has `n` attached clients.
    pub fn wait_for_clients(&self, job: &str, n: usize, timeout: Duration) -> Result<()> {
        let deadline = Instant::now() + timeout;
        let mut st = self.shared.state.lock().unwrap();
        loop {
            let have = st.jobs.get(job).map(|j| j.clients.len()).unwrap_or(0);
            if have >= n {
                return Ok(());
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Err(Error::Protocol(format!(
                    "timeout waiting for {n} clients (have {have})"
                )));
            }
            let (g, _) = self
                .shared
                .cv
                .wait_timeout(st, left.min(Duration::from_millis(50)))
                .unwrap();
            st = g;
        }
    }

    /// Attached client count of one job (0 for unknown jobs).
    pub fn num_clients(&self, job: &str) -> usize {
        let st = self.shared.state.lock().unwrap();
        st.jobs.get(job).map(|j| j.clients.len()).unwrap_or(0)
    }

    /// `(clients, last completed checkpoint id, epoch)` of one job.
    pub fn job_status(&self, job: &str) -> (usize, u64, u64) {
        let st = self.shared.state.lock().unwrap();
        match st.jobs.get(job) {
            Some(j) => (j.clients.len(), j.last_ckpt_id, self.shared.epoch),
            None => (0, 0, self.shared.epoch),
        }
    }

    /// Lifetime `(images_written, stored_bytes)` of one job.
    pub fn job_totals(&self, job: &str) -> (u64, u64) {
        let st = self.shared.state.lock().unwrap();
        match st.jobs.get(job) {
            Some(j) => (j.images_written, j.total_stored_bytes),
            None => (0, 0),
        }
    }

    /// Lifetime store accounting of one job.
    pub fn job_store_totals(&self, job: &str) -> super::coordinator::StoreTotals {
        let st = self.shared.state.lock().unwrap();
        match st.jobs.get(job) {
            Some(j) => super::coordinator::StoreTotals {
                images_written: j.images_written,
                stored_bytes: j.total_stored_bytes,
                logical_bytes: j.total_raw_bytes,
                chunks_written: j.total_chunks_written,
                chunks_deduped: j.total_chunks_deduped,
            },
            None => super::coordinator::StoreTotals::default(),
        }
    }

    /// Client metadata snapshot of one job (vpid → name, real pid,
    /// threads).
    pub fn job_client_table(&self, job: &str) -> BTreeMap<u64, (String, u64, u32)> {
        let st = self.shared.state.lock().unwrap();
        match st.jobs.get(job) {
            Some(j) => j
                .clients
                .iter()
                .map(|(&v, c)| (v, (c.name.clone(), c.real_pid, c.n_threads)))
                .collect(),
            None => BTreeMap::new(),
        }
    }

    /// Currently registered jobs.
    pub fn num_jobs(&self) -> usize {
        self.shared.state.lock().unwrap().jobs.len()
    }

    /// Currently open connections (clients + command clients).
    pub fn num_connections(&self) -> usize {
        self.shared.state.lock().unwrap().conns.len()
    }

    /// Jobs ever registered (restart incarnations each count once).
    pub fn jobs_registered_total(&self) -> u64 {
        self.shared.state.lock().unwrap().jobs_registered_total
    }

    /// I/O threads this daemon runs — the O(1) the mux bench asserts while
    /// session count scales. Always 1: the readiness loop owns every
    /// socket.
    pub fn io_threads(&self) -> usize {
        self.io_threads.load(Ordering::Relaxed)
    }

    /// True once shutdown was requested (e.g. a `CommandQuit` arrived).
    pub fn shutdown_flag(&self) -> bool {
        self.shared.shutdown.load(Ordering::Relaxed)
    }

    /// Stop the loop and drop every connection and job.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Relaxed);
        self.shared.cv.notify_all();
        if let Some(j) = self.loop_join.lock().unwrap().take() {
            let _ = j.join();
        }
        let mut st = self.shared.state.lock().unwrap();
        st.conns.clear();
        st.jobs.clear();
    }
}

impl Drop for CoordinatorDaemon {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---- the readiness loop ----------------------------------------------------

fn event_loop(shared: Arc<Shared>, listener: TcpListener) {
    while !shared.shutdown.load(Ordering::Relaxed) {
        let progress = {
            let mut st = shared.state.lock().unwrap();
            let mut progress = false;
            progress |= accept_new(&mut st, &listener);
            progress |= pump_connections(&mut st, &shared);
            progress |= reap_dead(&mut st);
            progress |= advance_rounds(&mut st, Instant::now());
            progress |= flush_writes(&mut st);
            progress |= reap_dead(&mut st);
            if progress {
                shared.cv.notify_all();
            }
            progress
        };
        if progress {
            std::thread::yield_now();
        } else {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    // Last chance for queued frames (kills) to reach their sockets.
    let mut st = shared.state.lock().unwrap();
    flush_writes(&mut st);
    shared.cv.notify_all();
}

fn accept_new(st: &mut DaemonState, listener: &TcpListener) -> bool {
    let mut progress = false;
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                stream.set_nodelay(true).ok();
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                let cid = st.next_conn_id;
                st.next_conn_id += 1;
                st.conns.insert(
                    cid,
                    Conn {
                        stream,
                        rdbuf: Vec::new(),
                        wq: VecDeque::new(),
                        wq_front_off: 0,
                        wq_bytes: 0,
                        job: None,
                        vpid: None,
                        close_after_flush: false,
                        dead: false,
                    },
                );
                progress = true;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(_) => break,
        }
    }
    progress
}

/// Queue one message on a connection. Overflow marks the connection dead —
/// the bounded-queue backpressure semantic — and returns `false`.
fn enqueue_frame(conn: &mut Conn, msg: &FromCoordinator) -> bool {
    if conn.dead {
        return false;
    }
    let body = encode_from_coordinator(msg);
    let mut frame = (body.len() as u32).to_le_bytes().to_vec();
    frame.extend_from_slice(&body);
    if conn.wq.len() >= WQ_MAX_FRAMES || conn.wq_bytes + frame.len() > WQ_MAX_BYTES {
        log::warn!(
            "write queue overflow ({} frames, {} bytes): disconnecting stalled client",
            conn.wq.len(),
            conn.wq_bytes
        );
        conn.dead = true;
        return false;
    }
    conn.wq_bytes += frame.len();
    conn.wq.push_back(frame);
    true
}

/// Drain one connection's write queue as far as the socket accepts.
fn drain_writes(conn: &mut Conn) -> bool {
    let mut progress = false;
    while let Some(front) = conn.wq.front() {
        match conn.stream.write(&front[conn.wq_front_off..]) {
            Ok(0) => {
                conn.dead = true;
                break;
            }
            Ok(n) => {
                progress = true;
                conn.wq_front_off += n;
                if conn.wq_front_off >= front.len() {
                    conn.wq_bytes -= front.len();
                    conn.wq_front_off = 0;
                    conn.wq.pop_front();
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                break;
            }
        }
    }
    if conn.close_after_flush && conn.wq.is_empty() {
        conn.dead = true;
    }
    progress
}

fn flush_writes(st: &mut DaemonState) -> bool {
    let mut progress = false;
    for conn in st.conns.values_mut() {
        progress |= drain_writes(conn);
    }
    progress
}

/// Per-connection I/O and dispatch: drain writes, read what's available,
/// parse complete frames, route each message.
fn pump_connections(st: &mut DaemonState, shared: &Shared) -> bool {
    let mut progress = false;
    let cids: Vec<u64> = st.conns.keys().copied().collect();
    for cid in cids {
        let msgs = {
            let Some(conn) = st.conns.get_mut(&cid) else {
                continue;
            };
            if conn.dead {
                continue;
            }
            progress |= drain_writes(conn);
            progress |= read_available(conn);
            parse_frames(conn)
        };
        for msg in msgs {
            progress = true;
            match msg {
                Ok(m) => dispatch(st, shared, cid, m),
                Err(e) => {
                    // Malformed frame: typed error reply, then close. The
                    // decoder rejected it — nothing was routed anywhere.
                    if let Some(conn) = st.conns.get_mut(&cid) {
                        enqueue_frame(
                            conn,
                            &FromCoordinator::Error {
                                message: e.to_string(),
                            },
                        );
                        conn.close_after_flush = true;
                    }
                }
            }
        }
    }
    progress
}

fn read_available(conn: &mut Conn) -> bool {
    let mut progress = false;
    let mut buf = [0u8; 8192];
    loop {
        match conn.stream.read(&mut buf) {
            Ok(0) => {
                conn.dead = true;
                break;
            }
            Ok(n) => {
                progress = true;
                conn.rdbuf.extend_from_slice(&buf[..n]);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                break;
            }
        }
    }
    progress
}

/// Split complete frames out of the read buffer and decode them. Stops at
/// the first malformed frame (oversized length prefix or decode error):
/// everything after it on the stream is untrusted.
fn parse_frames(conn: &mut Conn) -> Vec<Result<ToCoordinator>> {
    let mut out = Vec::new();
    let mut consumed = 0usize;
    loop {
        let buf = &conn.rdbuf[consumed..];
        if buf.len() < 4 {
            break;
        }
        let len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]);
        if len > MAX_FRAME {
            out.push(Err(Error::Protocol(format!("frame too large: {len}"))));
            consumed = conn.rdbuf.len();
            break;
        }
        let total = 4 + len as usize;
        if buf.len() < total {
            break;
        }
        let decoded = decode_to_coordinator(&buf[4..total]);
        consumed += total;
        let bad = decoded.is_err();
        out.push(decoded);
        if bad {
            consumed = conn.rdbuf.len();
            break;
        }
    }
    conn.rdbuf.drain(..consumed);
    out
}

/// Route one decoded message. Routing is connection-scoped: a connection
/// belongs to at most one job (bound by its `Hello`), so no frame can ever
/// act on another job's state machine.
fn dispatch(st: &mut DaemonState, shared: &Shared, cid: u64, msg: ToCoordinator) {
    match msg {
        ToCoordinator::Hello {
            real_pid,
            name,
            n_threads,
            restored_vpid,
            rank,
            job,
        } => handle_hello(st, shared, cid, real_pid, name, n_threads, restored_vpid, rank, job),
        ToCoordinator::PhaseAck {
            vpid,
            ckpt_id,
            phase,
        } => with_conn_job(st, cid, |j| {
            if let Some(round) = j.round.as_mut() {
                if round.ckpt_id == ckpt_id && round.phase == phase {
                    round.pending.remove(&vpid);
                } else {
                    log::warn!(
                        "stale ack from vpid {vpid}: round {ckpt_id}/{phase:?} vs {}/{:?}",
                        round.ckpt_id,
                        round.phase
                    );
                }
            }
        }),
        ToCoordinator::CkptDone {
            vpid,
            ckpt_id,
            path,
            stored_bytes,
            raw_bytes,
            write_secs,
            chunks_written,
            chunks_deduped,
        } => with_conn_job(st, cid, |j| {
            if let Some(round) = j.round.as_mut() {
                if round.ckpt_id == ckpt_id {
                    round.images.push(ImageInfo {
                        vpid,
                        ckpt_id,
                        path: PathBuf::from(path),
                        stored_bytes,
                        raw_bytes,
                        write_secs,
                        chunks_written,
                        chunks_deduped,
                    });
                }
            }
        }),
        ToCoordinator::Goodbye { vpid } => {
            let job = st.conns.get(&cid).and_then(|c| c.job.clone());
            if let Some(job_key) = job {
                detach_client(st, &job_key, vpid, "left");
            }
            if let Some(conn) = st.conns.get_mut(&cid) {
                conn.dead = true;
            }
        }
        ToCoordinator::CommandCheckpoint => {
            // Command connections carry no handshake, so the request is
            // only routable when the daemon hosts exactly one job.
            let reply_err = match sole_job(st) {
                Ok(job_key) => {
                    match start_round(st, &job_key, None, Some(cid), false, Instant::now()) {
                        Ok(()) => None, // CkptComplete is sent at round end
                        Err(e) => Some(e.to_string()),
                    }
                }
                Err(e) => Some(e.to_string()),
            };
            if let (Some(message), Some(conn)) = (reply_err, st.conns.get_mut(&cid)) {
                enqueue_frame(conn, &FromCoordinator::Error { message });
            }
        }
        ToCoordinator::CommandStatus => {
            let clients: usize = st.jobs.values().map(|j| j.clients.len()).sum();
            let last = st.jobs.values().map(|j| j.last_ckpt_id).max().unwrap_or(0);
            let reply = FromCoordinator::Status {
                clients: clients as u32,
                last_ckpt_id: last,
                epoch: shared.epoch,
            };
            if let Some(conn) = st.conns.get_mut(&cid) {
                enqueue_frame(conn, &reply);
            }
        }
        ToCoordinator::CommandQuit => {
            let client_conns: Vec<u64> = st
                .jobs
                .values()
                .flat_map(|j| j.clients.values().map(|c| c.conn))
                .collect();
            for ccid in client_conns {
                if let Some(conn) = st.conns.get_mut(&ccid) {
                    enqueue_frame(conn, &FromCoordinator::Kill);
                    conn.close_after_flush = true;
                }
            }
            if let Some(conn) = st.conns.get_mut(&cid) {
                conn.close_after_flush = true;
            }
            shared.shutdown.store(true, Ordering::Relaxed);
        }
    }
}

/// Run `f` on the job the connection was routed into. Un-routed
/// connections sending job-scoped frames get a typed error and are
/// dropped — never a panic, never delivery into an arbitrary job.
fn with_conn_job(st: &mut DaemonState, cid: u64, f: impl FnOnce(&mut JobState)) {
    let job = st.conns.get(&cid).and_then(|c| c.job.clone());
    match job.and_then(|k| st.jobs.remove_entry(&k)) {
        Some((key, mut j)) => {
            f(&mut j);
            st.jobs.insert(key, j);
        }
        None => {
            if let Some(conn) = st.conns.get_mut(&cid) {
                enqueue_frame(
                    conn,
                    &FromCoordinator::Error {
                        message: "job-scoped frame on a connection with no Hello handshake".into(),
                    },
                );
                conn.close_after_flush = true;
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_hello(
    st: &mut DaemonState,
    shared: &Shared,
    cid: u64,
    real_pid: u64,
    name: String,
    n_threads: u32,
    restored_vpid: Option<u64>,
    rank: Option<u32>,
    job: Option<String>,
) {
    let reject = |st: &mut DaemonState, cid: u64, message: String| {
        if let Some(conn) = st.conns.get_mut(&cid) {
            enqueue_frame(conn, &FromCoordinator::Error { message });
            conn.close_after_flush = true;
        }
    };
    let job_key = match job {
        Some(j) => {
            if !st.jobs.contains_key(&j) {
                if shared.config.auto_register_jobs {
                    let spec = JobSpec {
                        job: j.clone(),
                        ckpt_dir: shared.config.auto_ckpt_dir.join(&j),
                        phase_timeout: shared.config.auto_phase_timeout,
                    };
                    if let Err(e) = std::fs::create_dir_all(&spec.ckpt_dir) {
                        reject(st, cid, format!("auto-register job {j:?}: {e}"));
                        return;
                    }
                    st.jobs.insert(j.clone(), JobState::new(&spec));
                    st.jobs_registered_total += 1;
                } else {
                    // The router drops the handshake with a typed error;
                    // the frame is never delivered into another job.
                    reject(
                        st,
                        cid,
                        format!("unknown job {j:?}: Hello from {name:?} dropped"),
                    );
                    return;
                }
            }
            j
        }
        None => {
            // Back-compat single-tenant routing: an untagged Hello is only
            // unambiguous when exactly one job is registered.
            let mut keys = st.jobs.keys();
            match (keys.next().cloned(), keys.next()) {
                (Some(k), None) => k,
                (first, _) => {
                    reject(
                        st,
                        cid,
                        format!(
                            "Hello without a job tag needs exactly one registered job (have {})",
                            if first.is_none() { 0 } else { st.jobs.len() }
                        ),
                    );
                    return;
                }
            }
        }
    };

    let j = st.jobs.get_mut(&job_key).expect("job just resolved");
    let assigned = match restored_vpid {
        Some(v) => j.pid_table.adopt(v, real_pid).map(|()| v),
        None => j.pid_table.register(real_pid),
    };
    let assigned = match assigned {
        Ok(v) => v,
        Err(e) => {
            // Parity with the blocking coordinator: pid-table conflicts
            // reply with an error but keep the connection open.
            if let Some(conn) = st.conns.get_mut(&cid) {
                enqueue_frame(
                    conn,
                    &FromCoordinator::Error {
                        message: e.to_string(),
                    },
                );
            }
            return;
        }
    };
    j.clients.insert(
        assigned,
        ClientMeta {
            conn: cid,
            name: name.clone(),
            real_pid,
            n_threads,
            rank,
        },
    );
    if let Some(conn) = st.conns.get_mut(&cid) {
        conn.job = Some(job_key.clone());
        conn.vpid = Some(assigned);
        enqueue_frame(
            conn,
            &FromCoordinator::Welcome {
                vpid: assigned,
                epoch: shared.epoch,
            },
        );
    }
    log::debug!("client {name} attached to job {job_key:?} as vpid {assigned} (pid {real_pid})");
}

/// Remove a client from its job; a mid-round departure fails the round.
fn detach_client(st: &mut DaemonState, job_key: &str, vpid: u64, why: &str) {
    if let Some(j) = st.jobs.get_mut(job_key) {
        if j.clients.remove(&vpid).is_some() {
            let _ = j.pid_table.unregister(vpid);
            log::debug!("client vpid {vpid} {why} job {job_key:?}");
        }
        if let Some(round) = j.round.as_mut() {
            if round.pending.remove(&vpid) {
                let msg = format!(
                    "client vpid {vpid} {why} during {:?} of round {}",
                    round.phase, round.ckpt_id
                );
                // Daemon-side failure pin for the flight recorder: the
                // rank comes from the round's gang rank map (plain
                // rounds have no ranks and record only the vpid).
                crate::trace::event(crate::trace::names::PHASE_FAIL, |a| {
                    a.str("job", job_key.to_string());
                    if let Some(r) = round.rank_map.get(&vpid) {
                        a.u64("rank", *r as u64);
                    }
                    a.str("phase", format!("{:?}", round.phase));
                    a.u64("round", round.ckpt_id);
                    a.u64("vpid", vpid);
                    a.str("error", msg.clone());
                });
                round.failed = Some(msg);
            }
        }
    }
}

/// Remove dead connections and detach their clients.
fn reap_dead(st: &mut DaemonState) -> bool {
    let dead: Vec<u64> = st
        .conns
        .iter()
        .filter(|(_, c)| c.dead)
        .map(|(&cid, _)| cid)
        .collect();
    for cid in &dead {
        if let Some(conn) = st.conns.remove(cid) {
            if let (Some(job), Some(vpid)) = (conn.job, conn.vpid) {
                detach_client(st, &job, vpid, "disconnected");
            }
        }
    }
    !dead.is_empty()
}

/// The sole registered job, or a typed routing error.
fn sole_job(st: &DaemonState) -> Result<String> {
    let mut keys = st.jobs.keys();
    match (keys.next(), keys.next()) {
        (Some(k), None) => Ok(k.clone()),
        _ => Err(Error::Protocol(format!(
            "command needs exactly one registered job (have {})",
            st.jobs.len()
        ))),
    }
}

/// Validate and create a round for `job`, broadcasting `Suspend`.
fn start_round(
    st: &mut DaemonState,
    job_key: &str,
    expected_ranks: Option<u32>,
    reply_conn: Option<u64>,
    waited: bool,
    now: Instant,
) -> Result<()> {
    let j = st
        .jobs
        .get_mut(job_key)
        .ok_or_else(|| Error::Protocol(format!("unknown job {job_key:?}")))?;
    if j.round.is_some() || j.round_result.is_some() {
        return Err(Error::Protocol("checkpoint already in progress".into()));
    }
    if j.clients.is_empty() {
        return Err(Error::Protocol("no clients attached".into()));
    }
    let rank_map = match expected_ranks {
        None => BTreeMap::new(),
        Some(n) => {
            let mut by_vpid = BTreeMap::new();
            let mut seen = HashSet::new();
            for (&vpid, c) in &j.clients {
                let r = c.rank.ok_or_else(|| {
                    Error::Protocol(format!(
                        "gang checkpoint: client {:?} (vpid {vpid}) advertised no rank",
                        c.name
                    ))
                })?;
                if !seen.insert(r) {
                    return Err(Error::Protocol(format!(
                        "gang checkpoint: rank {r} attached twice"
                    )));
                }
                by_vpid.insert(vpid, r);
            }
            if by_vpid.len() != n as usize || (0..n).any(|r| !seen.contains(&r)) {
                return Err(Error::Protocol(format!(
                    "gang checkpoint: expected ranks 0..{n}, have {} clients",
                    by_vpid.len()
                )));
            }
            by_vpid
        }
    };
    let ckpt_id = j.next_ckpt_id;
    j.next_ckpt_id += 1;
    crate::trace::event(crate::trace::names::BARRIER_ROUND, |a| {
        a.str("job", job_key.to_string());
        a.u64("round", ckpt_id);
        a.u64("clients", j.clients.len() as u64);
        if let Some(n) = expected_ranks {
            a.u64("ranks", n as u64);
        }
    });
    let deadline = now + j.phase_timeout;
    j.round = Some(Round {
        ckpt_id,
        phase: Phase::Suspend,
        pending: HashSet::new(),
        images: Vec::new(),
        failed: None,
        deadline,
        rank_map,
        reply_conn,
        waited,
        failed_domain: None,
    });
    broadcast_phase(st, job_key, ckpt_id, Phase::Suspend);
    Ok(())
}

/// Broadcast one phase to every client of `job`, resetting the pending
/// set and the phase deadline. An unreachable client fails the round.
fn broadcast_phase(st: &mut DaemonState, job_key: &str, ckpt_id: u64, phase: Phase) {
    let Some((key, mut j)) = st.jobs.remove_entry(job_key) else {
        return;
    };
    let dir = j.ckpt_dir.to_string_lossy().to_string();
    let targets: Vec<(u64, u64)> = j.clients.iter().map(|(&v, c)| (v, c.conn)).collect();
    if let Some(round) = j.round.as_mut() {
        crate::trace::event(crate::trace::names::BARRIER_PHASE, |a| {
            a.str("job", job_key.to_string());
            a.u64("round", ckpt_id);
            a.str("phase", format!("{phase:?}"));
            a.u64("clients", targets.len() as u64);
        });
        round.phase = phase;
        round.deadline = Instant::now() + j.phase_timeout;
        round.pending = targets.iter().map(|(v, _)| *v).collect();
        if targets.is_empty() {
            round.failed = Some(format!("all clients vanished before {phase:?}"));
        }
        for (vpid, cid) in targets {
            let ok = match st.conns.get_mut(&cid) {
                Some(conn) => enqueue_frame(
                    conn,
                    &FromCoordinator::Phase {
                        ckpt_id,
                        phase,
                        dir: dir.clone(),
                    },
                ),
                None => false,
            };
            if !ok {
                log::warn!("phase {phase:?}: client {vpid} unreachable");
                round.pending.remove(&vpid);
                let msg = format!(
                    "client vpid {vpid} unreachable during {phase:?} of round {ckpt_id}"
                );
                // Same failure pin detach_client leaves: an unreachable
                // client must be explainable from the flight dump too.
                crate::trace::event(crate::trace::names::PHASE_FAIL, |a| {
                    a.str("job", job_key.to_string());
                    if let Some(r) = round.rank_map.get(&vpid) {
                        a.u64("rank", *r as u64);
                    }
                    a.str("phase", format!("{phase:?}"));
                    a.u64("round", ckpt_id);
                    a.u64("vpid", vpid);
                    a.str("error", msg.clone());
                });
                round.failed = Some(msg);
            }
        }
        // A partition armed for this phase fires now, after the phase
        // frames went out but before any victim can ack: the marked gang
        // ranks' links are severed mid-barrier. One-shot.
        if j.armed_partition.as_ref().is_some_and(|(p, _)| *p == phase) {
            let (_, cut_ranks) = j.armed_partition.take().expect("armed checked above");
            let mut hit: Vec<u32> = Vec::new();
            for (&vpid, &rank) in round.rank_map.iter() {
                if !cut_ranks.contains(&rank) {
                    continue;
                }
                if let Some(cid) = j.clients.get(&vpid).map(|c| c.conn) {
                    if let Some(conn) = st.conns.get_mut(&cid) {
                        conn.dead = true;
                    }
                }
                // Pre-removing from pending keeps the later reap-time
                // detach from double-pinning this vpid.
                round.pending.remove(&vpid);
                crate::trace::event(crate::trace::names::PHASE_FAIL, |a| {
                    a.str("job", job_key.to_string());
                    a.u64("rank", rank as u64);
                    a.str("phase", format!("{phase:?}"));
                    a.u64("round", ckpt_id);
                    a.u64("vpid", vpid);
                    a.str(
                        "error",
                        format!(
                            "fabric partition: rank {rank} unreachable during {phase:?} \
                             of round {ckpt_id}"
                        ),
                    );
                });
                hit.push(rank);
            }
            if !hit.is_empty() {
                crate::trace::event(crate::trace::names::FAULT_PARTITION, |a| {
                    a.str("job", job_key.to_string());
                    a.str("ranks", format!("{hit:?}"));
                    a.str("phase", format!("{phase:?}"));
                    a.u64("round", ckpt_id);
                });
                round.failed = Some(format!(
                    "fabric partition: ranks {hit:?} unreachable during {phase:?} of \
                     round {ckpt_id}"
                ));
                round.failed_domain = Some("fabric");
            }
        }
    }
    st.jobs.insert(key, j);
}

/// Advance every job's round independently: complete finished phases,
/// fail rounds whose clients vanished, disconnect clients that blew a
/// phase deadline. One job's stall never touches another's round.
fn advance_rounds(st: &mut DaemonState, now: Instant) -> bool {
    let mut progress = false;
    let job_keys: Vec<String> = st
        .jobs
        .iter()
        .filter(|(_, j)| j.round.is_some())
        .map(|(k, _)| k.clone())
        .collect();
    for key in job_keys {
        enum Action {
            Fail(String),
            NextPhase(u64, Phase),
            Complete,
            TimedOut(Vec<u64>),
            Wait,
        }
        let action = {
            let Some(j) = st.jobs.get(&key) else { continue };
            let Some(round) = j.round.as_ref() else {
                continue;
            };
            if let Some(why) = &round.failed {
                Action::Fail(why.clone())
            } else if round.pending.is_empty() {
                if round.phase == Phase::Resume {
                    Action::Complete
                } else {
                    let next = Phase::ALL[round.phase as usize + 1];
                    Action::NextPhase(round.ckpt_id, next)
                }
            } else if now >= round.deadline {
                // Stalled clients: everyone still pending is disconnected
                // and only this job's round fails.
                let stalled: Vec<u64> = round.pending.iter().copied().collect();
                Action::TimedOut(stalled)
            } else {
                Action::Wait
            }
        };
        match action {
            Action::Wait => {}
            Action::NextPhase(ckpt_id, next) => {
                broadcast_phase(st, &key, ckpt_id, next);
                progress = true;
            }
            Action::Complete => {
                let Some(j) = st.jobs.get_mut(&key) else {
                    continue;
                };
                let round = j.round.take().expect("round checked above");
                j.last_ckpt_id = round.ckpt_id;
                j.images_written += round.images.len() as u64;
                j.total_stored_bytes += round.images.iter().map(|i| i.stored_bytes).sum::<u64>();
                j.total_raw_bytes += round.images.iter().map(|i| i.raw_bytes).sum::<u64>();
                j.total_chunks_written +=
                    round.images.iter().map(|i| i.chunks_written).sum::<u64>();
                j.total_chunks_deduped +=
                    round.images.iter().map(|i| i.chunks_deduped).sum::<u64>();
                let reply = FromCoordinator::CkptComplete {
                    ckpt_id: round.ckpt_id,
                    images: round.images.len() as u32,
                    total_stored_bytes: round.images.iter().map(|i| i.stored_bytes).sum(),
                };
                if round.waited {
                    j.round_result = Some(Ok((round.images, round.rank_map)));
                }
                if let Some(rc) = round.reply_conn {
                    if let Some(conn) = st.conns.get_mut(&rc) {
                        enqueue_frame(conn, &reply);
                    }
                }
                progress = true;
            }
            Action::TimedOut(stalled) => {
                let phase;
                let ckpt_id;
                {
                    let Some(j) = st.jobs.get_mut(&key) else {
                        continue;
                    };
                    let round = j.round.as_mut().expect("round checked above");
                    phase = round.phase;
                    ckpt_id = round.ckpt_id;
                    round.failed = Some(format!(
                        "phase {phase:?} timed out with {} clients pending (round {ckpt_id}); \
                         stalled clients disconnected",
                        stalled.len()
                    ));
                }
                for vpid in stalled {
                    let cid = st
                        .jobs
                        .get(&key)
                        .and_then(|j| j.clients.get(&vpid))
                        .map(|c| c.conn);
                    if let Some(cid) = cid {
                        if let Some(conn) = st.conns.get_mut(&cid) {
                            conn.dead = true;
                        }
                    }
                    detach_client(st, &key, vpid, "stalled (backpressure disconnect)");
                }
                progress = true;
            }
            Action::Fail(why) => {
                let Some(j) = st.jobs.get_mut(&key) else {
                    continue;
                };
                let round = j.round.take().expect("round checked above");
                let ckpt_id = round.ckpt_id;
                // A failed round must be explainable after the fact
                // (invariant 11): persist the job's recent spans — the
                // PHASE_FAIL pin above names the rank and phase — next to
                // the images the round would have produced. No-op unless
                // a trace sink is installed. An injected fault names its
                // domain; organic stalls let the dump infer one.
                match round.failed_domain {
                    Some(d) => {
                        crate::trace::flight::dump_for_job_in_domain(&key, &why, &j.ckpt_dir, d)
                    }
                    None => crate::trace::flight::dump_for_job(&key, &why, &j.ckpt_dir),
                };
                if round.waited {
                    j.round_result = Some(Err(Error::Protocol(why.clone())));
                }
                let reply_conn = round.reply_conn;
                // Abort: release survivors parked mid-barrier so a failed
                // round costs nothing but the unpublished checkpoint.
                let dir = j.ckpt_dir.to_string_lossy().to_string();
                let survivors: Vec<u64> = j.clients.values().map(|c| c.conn).collect();
                for cid in survivors {
                    if let Some(conn) = st.conns.get_mut(&cid) {
                        enqueue_frame(
                            conn,
                            &FromCoordinator::Phase {
                                ckpt_id,
                                phase: Phase::Resume,
                                dir: dir.clone(),
                            },
                        );
                    }
                }
                if let Some(rc) = reply_conn {
                    if let Some(conn) = st.conns.get_mut(&rc) {
                        enqueue_frame(conn, &FromCoordinator::Error { message: why });
                    }
                }
                progress = true;
            }
        }
    }
    progress
}
