//! The per-process checkpoint thread.
//!
//! Every DMTCP-managed process carries one extra thread that talks to the
//! coordinator and drives the process through the barrier phases: it parks
//! the user threads (suspend), serializes the memory segments into the
//! image (checkpoint), and releases them (resume). This mirrors Fig 1 of
//! the paper: "Upon receiving a CKPT MSG from the central coordinator, the
//! checkpoint threads trigger a signal to user threads, and a checkpointing
//! action is initiated".

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::dmtcp::image::{CheckpointImage, ImageHeader};
use crate::dmtcp::plugin::{Event, PluginCtx, PluginRegistry};
use crate::dmtcp::process::{ProcessStats, SegmentSource, SuspendGate};
use crate::dmtcp::protocol::{
    recv_from_coordinator, send_to_coordinator, FromCoordinator, Phase, ToCoordinator,
};
use crate::dmtcp::store::{ChunkerSpec, ImageStore, SegmentManifest, StoreConfig};
use crate::dmtcp::virtualization::FdTable;
use crate::error::{Error, Result};

/// Everything the checkpoint thread needs about its process.
pub struct CkptContext {
    /// Process name (images are discovered by it).
    pub name: String,
    /// Real (host) pid, sent in the Hello handshake.
    pub real_pid: u64,
    /// Restart generation (0 = first incarnation).
    pub generation: u32,
    /// The safe-point gate user threads park at during barriers.
    pub gate: Arc<SuspendGate>,
    /// Shared process counters (steps, bytes, checkpoint totals).
    pub stats: Arc<ProcessStats>,
    /// The process's (virtualized) environment.
    pub env: Arc<Mutex<BTreeMap<String, String>>>,
    /// The process's virtual fd table (captured into images).
    pub fds: Arc<Mutex<FdTable>>,
    /// Plugin registry fired at each barrier event.
    pub plugins: Arc<Mutex<PluginRegistry>>,
    /// Type-erased handle to the application state.
    pub source: Box<dyn SegmentSource>,
    /// Records restored from the image (empty on first launch); plugins may
    /// rewrite them at each PreCheckpoint.
    pub records: BTreeMap<String, Vec<u8>>,
    /// Re-attach under this vpid (restart path).
    pub restored_vpid: Option<u64>,
    /// Published once the coordinator assigns it.
    pub vpid_out: Arc<AtomicU64>,
    /// Per-segment manifests of this process's previous checkpoint
    /// (dirty-segment tracking for the incremental pipeline). Empty before
    /// the first checkpoint of an incarnation; the store still dedups
    /// content-addressed chunks written by prior incarnations.
    pub prev_manifest: BTreeMap<String, SegmentManifest>,
}

/// One checkpoint write outcome (what `CkptDone` carries).
struct WriteOutcome {
    path: String,
    stored_bytes: u64,
    raw_bytes: u64,
    write_secs: f64,
    chunks_written: u64,
    chunks_deduped: u64,
}

/// Spawn the checkpoint thread; `attached_tx` fires once Welcome arrives.
pub fn spawn(
    coordinator: SocketAddr,
    mut ctx: CkptContext,
    attached_tx: mpsc::Sender<Result<u64>>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("{}-ckpt", ctx.name))
        .spawn(move || {
            let res = run(coordinator, &mut ctx, &attached_tx);
            if let Err(e) = res {
                log::warn!("ckpt thread for {} exiting: {e}", ctx.name);
                // A dead coordinator link means the computation can no
                // longer be checkpointed or resumed; treat as preemption.
                ctx.gate.kill();
            }
        })
        .expect("spawn ckpt thread")
}

fn run(
    coordinator: SocketAddr,
    ctx: &mut CkptContext,
    attached_tx: &mpsc::Sender<Result<u64>>,
) -> Result<()> {
    let mut stream = match TcpStream::connect(coordinator) {
        Ok(s) => s,
        Err(e) => {
            let _ = attached_tx.send(Err(Error::Io(e)));
            return Err(Error::Protocol("cannot reach coordinator".into()));
        }
    };
    stream.set_nodelay(true).ok();

    // Gang rank, if any: set by the gang session on launch and preserved in
    // the image env across restarts, so a restarted rank re-advertises the
    // same position in the computation. The job tag routes this client to
    // its own job's state machine on a multi-tenant coordinator daemon
    // (untagged clients only attach when the daemon hosts a single job).
    let (rank, job) = {
        let env = ctx.env.lock().expect("env poisoned");
        (
            env.get("DMTCP_RANK").and_then(|v| v.parse::<u32>().ok()),
            env.get("DMTCP_JOB").cloned(),
        )
    };
    // Span attribution: sessions always export DMTCP_JOB (cr::module), so
    // the process name fallback only covers bare-protocol tests.
    let job_tag = job.clone().unwrap_or_else(|| ctx.name.clone());
    send_to_coordinator(
        &mut stream,
        &ToCoordinator::Hello {
            real_pid: ctx.real_pid,
            name: ctx.name.clone(),
            n_threads: ctx.stats.n_threads.load(Ordering::Relaxed) as u32,
            restored_vpid: ctx.restored_vpid,
            rank,
            job,
        },
    )?;
    let vpid = match recv_from_coordinator(&mut stream)? {
        FromCoordinator::Welcome { vpid, .. } => vpid,
        FromCoordinator::Error { message } => {
            let _ = attached_tx.send(Err(Error::Protocol(message.clone())));
            return Err(Error::Protocol(message));
        }
        other => {
            let msg = format!("expected Welcome, got {other:?}");
            let _ = attached_tx.send(Err(Error::Protocol(msg.clone())));
            return Err(Error::Protocol(msg));
        }
    };
    ctx.vpid_out.store(vpid, Ordering::SeqCst);
    ctx.stats.alive.store(true, Ordering::Relaxed);
    let _ = attached_tx.send(Ok(vpid));

    loop {
        let msg = recv_from_coordinator(&mut stream)?;
        match msg {
            FromCoordinator::Phase { ckpt_id, phase, dir } => {
                let mut sp = crate::trace::span(crate::trace::names::CLIENT_PHASE)
                    .with("job", || job_tag.clone())
                    .with_u64("round", ckpt_id)
                    .with("phase", || format!("{phase:?}"));
                if let Some(r) = rank {
                    sp.note_u64("rank", r as u64);
                }
                if let Err(e) = handle_phase(ctx, &mut stream, vpid, ckpt_id, phase, &dir) {
                    // The flight recorder pivots on this event: it names
                    // the rank and barrier phase a failed round died in
                    // (invariant 11).
                    sp.fail(&e.to_string());
                    drop(sp);
                    crate::trace::event(crate::trace::names::PHASE_FAIL, |a| {
                        a.str("job", job_tag.clone());
                        if let Some(r) = rank {
                            a.u64("rank", r as u64);
                        }
                        a.str("phase", format!("{phase:?}"));
                        a.u64("round", ckpt_id);
                        a.str("error", e.to_string());
                    });
                    return Err(e);
                }
            }
            FromCoordinator::Kill => {
                fire_plugins(ctx, Event::Kill)?;
                ctx.gate.kill();
                log::debug!("{} (vpid {vpid}) killed by coordinator", ctx.name);
                return Ok(());
            }
            other => {
                log::warn!("{}: unexpected message {other:?}", ctx.name);
            }
        }
        if ctx.gate.killed() {
            return Ok(());
        }
    }
}

fn handle_phase(
    ctx: &mut CkptContext,
    stream: &mut TcpStream,
    vpid: u64,
    ckpt_id: u64,
    phase: Phase,
    dir: &str,
) -> Result<()> {
    match phase {
        Phase::Suspend => {
            ctx.gate.request_suspend();
            wait_all_parked(ctx);
            // Publish the parked population for the LDMS sampler: the
            // process burns no user CPU from here until Resume (the
            // paper's Fig 4 CPU dips at checkpoint instants).
            ctx.stats
                .parked
                .store(ctx.gate.parked_count(), Ordering::Relaxed);
            fire_plugins(ctx, Event::Suspend)?;
        }
        Phase::Drain => {
            // User threads are parked everywhere (the barrier orders all
            // SUSPENDs before any DRAIN), so in-flight channel data is
            // final: drain plugins move undelivered rank-to-rank messages
            // into the checkpointable state here, making the image set a
            // consistent cut of the whole computation.
            fire_plugins(ctx, Event::Drain)?;
        }
        Phase::Checkpoint => {
            let out = write_image(ctx, vpid, ckpt_id, dir)?;
            send_to_coordinator(
                stream,
                &ToCoordinator::CkptDone {
                    vpid,
                    ckpt_id,
                    path: out.path,
                    stored_bytes: out.stored_bytes,
                    raw_bytes: out.raw_bytes,
                    write_secs: out.write_secs,
                    chunks_written: out.chunks_written,
                    chunks_deduped: out.chunks_deduped,
                },
            )?;
        }
        Phase::Refill => {
            // Re-prime drained channels. The gang drain plugins leave
            // drained messages in the state (workers consume state-held
            // messages before polling the fabric), so this is a plugin
            // hook rather than a rewind of the drain.
            fire_plugins(ctx, Event::Refill)?;
        }
        Phase::Resume => {
            fire_plugins(ctx, Event::PostCheckpoint)?;
            ctx.gate.resume();
            ctx.stats.parked.store(0, Ordering::Relaxed);
        }
    }
    send_to_coordinator(stream, &ToCoordinator::PhaseAck { vpid, ckpt_id, phase })
}

/// Wait until every *currently active* user thread is parked. Threads that
/// finish their work while we wait reduce the target, so completion racing
/// a checkpoint cannot deadlock the barrier.
fn wait_all_parked(ctx: &CkptContext) {
    loop {
        let active = ctx.stats.n_threads.load(Ordering::Relaxed);
        let parked = ctx.gate.parked_count();
        if parked >= active || ctx.gate.killed() {
            return;
        }
        std::thread::sleep(Duration::from_micros(200));
    }
}

fn fire_plugins(ctx: &mut CkptContext, event: Event) -> Result<()> {
    let mut env = ctx.env.lock().expect("env poisoned");
    let mut plugins = ctx.plugins.lock().expect("plugins poisoned");
    let mut pctx = PluginCtx {
        records: &mut ctx.records,
        env: &mut env,
        generation: ctx.generation,
    };
    plugins.fire(event, &mut pctx)
}

/// Serialize the process into its image file.
///
/// With `DMTCP_INCREMENTAL` set (and nonzero), the image is written as a
/// v2 manifest over the per-workdir content-addressed chunk store: only
/// chunks whose content changed since the previous generation are
/// compressed and stored, with compression fanned out over the store's
/// worker pool. `DMTCP_FULL_EVERY=N` forces every Nth checkpoint (counting
/// from the first of each incarnation) back to a self-contained v1 full
/// image — the store-independence anchor. Without `DMTCP_INCREMENTAL`,
/// every checkpoint is a v1 full image (the NERSC `--gzip` default).
fn write_image(ctx: &mut CkptContext, vpid: u64, ckpt_id: u64, dir: &str) -> Result<WriteOutcome> {
    fire_plugins(ctx, Event::PreCheckpoint)?;

    let mut sp = crate::trace::span(crate::trace::names::IMAGE_WRITE).with_u64("round", ckpt_id);
    if sp.is_active() {
        let env = ctx.env.lock().expect("env poisoned");
        if let Some(j) = env.get("DMTCP_JOB") {
            sp.note("job", || j.clone());
        }
        if let Some(r) = env.get("DMTCP_RANK") {
            sp.note("rank", || r.clone());
        }
    }

    let (segments, steps_done) = ctx.source.capture();
    let raw_bytes: u64 = segments.iter().map(|(_, d)| d.len() as u64).sum();
    // The transient allocation below is what produces the paper's Fig 4
    // memory spikes at checkpoint instants.
    ctx.stats.transient_bytes.store(raw_bytes, Ordering::Relaxed);

    let header = ImageHeader {
        vpid,
        name: ctx.name.clone(),
        ckpt_id,
        generation: ctx.generation,
        steps_done,
        env: ctx.env.lock().expect("env poisoned").clone(),
        fds: ctx.fds.lock().expect("fds poisoned").capture(),
        plugin_records: ctx.records.clone(),
    };
    let image = CheckpointImage { header, segments };

    let (gzip, incremental, full_every, per_round, chunker) = {
        let env = ctx.env.lock().expect("env poisoned");
        let flag = |k: &str| env.get(k).map(|v| v != "0").unwrap_or(false);
        (
            env.get("DMTCP_GZIP").map(|v| v != "0").unwrap_or(true),
            flag("DMTCP_INCREMENTAL"),
            env.get("DMTCP_FULL_EVERY")
                .and_then(|v| v.parse::<u64>().ok())
                .unwrap_or(0),
            flag("DMTCP_IMAGE_PER_ROUND"),
            // Malformed specs fail the checkpoint as a typed error rather
            // than silently changing the chunking of every later image.
            match env.get("DMTCP_CHUNKER") {
                Some(v) => v.parse::<ChunkerSpec>()?,
                None => ChunkerSpec::Fixed,
            },
        )
    };
    let ckpt_index = ctx.stats.checkpoints.load(Ordering::Relaxed);
    let force_full = full_every > 0 && ckpt_index % full_every == 0;

    // Default: one image path per process, atomically replaced each round.
    // `DMTCP_IMAGE_PER_ROUND` (the gang path) stamps the round id into the
    // name instead, so a *failed* gang round can never overwrite images a
    // published gang manifest still references — the manifest's image set
    // stays immutable once visible.
    let fname = if per_round {
        format!("ckpt_{}_{}_{:08}.dmtcp", ctx.name, vpid, ckpt_id)
    } else {
        format!("ckpt_{}_{}.dmtcp", ctx.name, vpid)
    };
    let path = std::path::Path::new(dir).join(fname);
    let t0 = Instant::now();
    let (stored, chunks_written, chunks_deduped) = if incremental && !force_full {
        let store = ImageStore::for_images(std::path::Path::new(dir));
        let opts = StoreConfig {
            gzip,
            chunker,
            ..Default::default()
        };
        let (manifest, stats) =
            store.write_incremental(&image, &path, Some(&ctx.prev_manifest), &opts)?;
        ctx.prev_manifest = manifest
            .segments
            .iter()
            .map(|s| (s.name.clone(), s.clone()))
            .collect();
        (stats.stored_bytes, stats.chunks_written, stats.chunks_deduped)
    } else {
        // Full image. The previous manifests stay valid for the *next*
        // incremental delta: their chunks remain in the store until GC.
        (image.write_file(&path, gzip)?, 0, 0)
    };
    let secs = t0.elapsed().as_secs_f64();
    sp.note_u64("raw_bytes", raw_bytes);
    sp.note_u64("stored_bytes", stored);
    drop(sp);

    ctx.stats.transient_bytes.store(0, Ordering::Relaxed);
    ctx.stats.checkpoints.fetch_add(1, Ordering::Relaxed);
    ctx.stats.ckpt_stored_bytes.fetch_add(stored, Ordering::Relaxed);
    log::debug!(
        "{} (vpid {vpid}) wrote ckpt {ckpt_id}: {} -> {} bytes in {:.3}s \
         ({} chunks new, {} reused)",
        ctx.name,
        raw_bytes,
        stored,
        secs,
        chunks_written,
        chunks_deduped
    );
    Ok(WriteOutcome {
        path: path.to_string_lossy().into_owned(),
        stored_bytes: stored,
        raw_bytes,
        write_secs: secs,
        chunks_written,
        chunks_deduped,
    })
}
