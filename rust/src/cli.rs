//! The `nersc-cr` command-line interface.
//!
//! Mirrors the operational commands of the paper's environment:
//!
//! ```text
//! nersc-cr coordinator --jobid 123 --workdir DIR      # dmtcp_coordinator
//! nersc-cr command --file dmtcp_command.123 status    # dmtcp_command
//! nersc-cr command --file dmtcp_command.123 checkpoint
//! nersc-cr command --file dmtcp_command.123 quit
//! nersc-cr inspect IMAGE.dmtcp                        # dmtcp_restart --inspect
//! nersc-cr sbatch SCRIPT [--cluster-nodes N]          # submit to the simulator
//! nersc-cr run --workload water-phantom --g4 10.7 --steps 640 [--preempt MS]
//! nersc-cr fig2 [--ranks 512]                         # startup-model table
//! nersc-cr version
//! ```
//!
//! (Hand-rolled parser: clap is not in the offline dependency closure.)

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Duration;

use crate::error::{Error, Result};

/// Parse `--key value` / `--flag` style options.
struct Opts {
    positional: Vec<String>,
    named: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Opts {
    fn parse(args: &[String], known_flags: &[&str]) -> Result<Self> {
        let mut positional = Vec::new();
        let mut named = BTreeMap::new();
        let mut flags = Vec::new();
        let mut it = args.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if known_flags.contains(&key) {
                    flags.push(key.to_string());
                } else if let Some((k, v)) = key.split_once('=') {
                    named.insert(k.to_string(), v.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| Error::Usage(format!("--{key} needs a value")))?;
                    named.insert(key.to_string(), v.clone());
                }
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Self {
            positional,
            named,
            flags,
        })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.named.get(key).map(String::as_str)
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn has_flag(&self, f: &str) -> bool {
        self.flags.iter().any(|x| x == f)
    }
}

const USAGE: &str = "\
nersc-cr — checkpoint-restart for HPC with a DMTCP-style coordinator

subcommands:
  coordinator --jobid ID [--workdir DIR] [--no-gzip]   start a coordinator (blocks)
  daemon [--bind HOST:PORT] [--ckpt-root DIR]
      [--phase-timeout-ms N]                           start a multi-tenant coordinator
                                                       daemon: many jobs, ONE port
                                                       (blocks; `command ... quit` stops it)
  command --file PATH (status|checkpoint|quit)         control a coordinator
  inspect IMAGE.dmtcp                                  show an image header
  sbatch SCRIPT [--cluster-nodes N]                    simulate a batch script
  run --workload NAME --g4 VER --steps N [--preempt MS] [--workdir DIR]
      [--incremental [--full-every N] [--chunker SPEC]] run a workload under auto C/R
                                                       (SPEC: fixed | cdc | cdc:MIN:AVG:MAX)
  run --ranks N [--workload halo-stencil] [--stencil-cells C] [--steps N]
      [--mana off] [--preempt MS] [--incremental]      run an N-rank gang under gang C/R
  campaign [--spec FILE] [--sessions N] [--seed S] [--workdir DIR]
      [--arrival static|poisson:RATE] [--scheduler fifo|ckpt-aware]
      [--admit-max N|off] [--preempt-signal SIG@OFFSET|off]
      [--chunker SPEC]
      [--json] [--print-spec]                          run a fleet campaign
                                                       (spec: ranks = N for gangs)
  fig2 [--ranks N]                                     container-startup table
  trace WORKDIR                                        list flight-recorder dumps under
                                                       a workdir (failed rounds: who
                                                       died, in which phase)
  workloads                                            list workload names
  version";

/// Dispatch `nersc-cr <subcommand> ...`.
pub fn run(args: Vec<String>) -> Result<()> {
    match args.first().map(|s| s.as_str()) {
        None | Some("help") | Some("--help") => {
            println!("{USAGE}");
            Ok(())
        }
        Some("version") => {
            println!("nersc-cr {}", env!("CARGO_PKG_VERSION"));
            Ok(())
        }
        Some("coordinator") => cmd_coordinator(&args[1..]),
        Some("daemon") => cmd_daemon(&args[1..]),
        Some("command") => cmd_command(&args[1..]),
        Some("inspect") => cmd_inspect(&args[1..]),
        Some("sbatch") => cmd_sbatch(&args[1..]),
        Some("run") => cmd_run(&args[1..]),
        Some("campaign") => cmd_campaign(&args[1..]),
        Some("fig2") => cmd_fig2(&args[1..]),
        Some("trace") => cmd_trace(&args[1..]),
        Some("workloads") => {
            for k in crate::workload::WorkloadKind::all() {
                println!("{}", k.label());
            }
            println!("{}", crate::workload::CP2K_SCF_LABEL);
            println!("{}", crate::workload::STENCIL_LABEL);
            Ok(())
        }
        Some(other) => Err(Error::Usage(format!(
            "unknown subcommand {other:?}\n{USAGE}"
        ))),
    }
}

fn cmd_coordinator(args: &[String]) -> Result<()> {
    let o = Opts::parse(args, &["no-gzip"])?;
    let jobid = o
        .get("jobid")
        .ok_or_else(|| Error::Usage("coordinator needs --jobid".into()))?;
    let workdir = PathBuf::from(o.get_or("workdir", "."));
    let mut cfg = crate::cr::CrConfig::new(jobid, workdir);
    cfg.gzip = !o.has_flag("no-gzip");
    let (coord, env) = crate::cr::start_coordinator(&cfg)?;
    println!("coordinator listening on {}", coord.addr());
    println!("rendezvous file: {}", coord.command_file().unwrap().display());
    for (k, v) in env {
        println!("export {k}={v}");
    }
    println!("(blocking; `nersc-cr command --file ... quit` to stop)");
    loop {
        std::thread::sleep(Duration::from_millis(200));
        let (clients, last, _) = coord.status();
        log::debug!("clients={clients} last_ckpt={last}");
    }
}

/// `nersc-cr daemon`: one long-lived event-driven coordinator daemon
/// multiplexing any number of jobs over a single port. Jobs are
/// auto-registered on first tagged Hello (checkpoints land under
/// `<ckpt-root>/<job>`); sessions in other processes attach by exporting
/// `DMTCP_COORD_HOST/PORT` and a unique `DMTCP_JOB`. Blocks until a
/// `quit` command arrives on the port.
fn cmd_daemon(args: &[String]) -> Result<()> {
    let o = Opts::parse(args, &[])?;
    let ckpt_root = PathBuf::from(o.get_or(
        "ckpt-root",
        &std::env::temp_dir()
            .join("nersc_cr_daemon_ckpt")
            .to_string_lossy(),
    ));
    let timeout_ms: u64 = o
        .get_or("phase-timeout-ms", "30000")
        .parse()
        .map_err(|_| Error::Usage("bad --phase-timeout-ms".into()))?;
    let daemon = crate::dmtcp::CoordinatorDaemon::start(crate::dmtcp::DaemonConfig {
        bind: o.get_or("bind", "127.0.0.1:0"),
        retry_ephemeral: true,
        auto_register_jobs: true,
        auto_ckpt_dir: ckpt_root.clone(),
        auto_phase_timeout: Duration::from_millis(timeout_ms),
    })?;
    println!("multi-tenant coordinator daemon on {}", daemon.addr());
    println!(
        "auto-registered jobs checkpoint under {}",
        ckpt_root.display()
    );
    println!(
        "clients: export DMTCP_COORD_HOST={} DMTCP_COORD_PORT={} DMTCP_JOB=<unique-id>",
        daemon.addr().ip(),
        daemon.addr().port()
    );
    println!("(blocking; `nersc-cr command --file ... quit` or a Quit frame stops it)");
    while !daemon.shutdown_flag() {
        std::thread::sleep(Duration::from_millis(200));
        log::debug!(
            "daemon: jobs={} connections={}",
            daemon.num_jobs(),
            daemon.num_connections()
        );
    }
    println!("daemon shut down");
    Ok(())
}

fn cmd_command(args: &[String]) -> Result<()> {
    let o = Opts::parse(args, &[])?;
    let file = o
        .get("file")
        .ok_or_else(|| Error::Usage("command needs --file".into()))?;
    let cmd = crate::dmtcp::DmtcpCommand::from_command_file(std::path::Path::new(file))?;
    match o.positional.first().map(String::as_str) {
        Some("status") | None => {
            let s = cmd.status()?;
            println!(
                "clients={} last_ckpt_id={} epoch={}",
                s.clients, s.last_ckpt_id, s.epoch
            );
        }
        Some("checkpoint") => {
            let r = cmd.checkpoint()?;
            println!(
                "checkpoint #{}: {} images, {} stored",
                r.ckpt_id,
                r.images,
                crate::report::human_bytes(r.total_stored_bytes)
            );
        }
        Some("quit") => {
            cmd.quit()?;
            println!("coordinator asked to quit");
        }
        Some(other) => return Err(Error::Usage(format!("unknown command {other:?}"))),
    }
    Ok(())
}

fn cmd_inspect(args: &[String]) -> Result<()> {
    let o = Opts::parse(args, &[])?;
    let path = o
        .positional
        .first()
        .ok_or_else(|| Error::Usage("inspect needs an image path".into()))?;
    let h = crate::dmtcp::inspect_image(std::path::Path::new(path))?;
    println!("image: {path}");
    println!("  process : {} (vpid {})", h.name, h.vpid);
    println!("  ckpt id : {} (generation {})", h.ckpt_id, h.generation);
    println!("  progress: {} steps", h.steps_done);
    println!("  env     : {} vars", h.env.len());
    println!("  fds     : {}", h.fds.len());
    println!(
        "  plugins : {:?}",
        h.plugin_records.keys().collect::<Vec<_>>()
    );
    Ok(())
}

fn cmd_trace(args: &[String]) -> Result<()> {
    let o = Opts::parse(args, &[])?;
    let root = o
        .positional
        .first()
        .ok_or_else(|| Error::Usage("trace needs a workdir".into()))?;
    let dumps = crate::trace::flight::scan(std::path::Path::new(root));
    if dumps.is_empty() {
        println!("no flight dumps under {root}");
        return Ok(());
    }
    println!("{} flight dump(s) under {root}", dumps.len());
    for d in dumps {
        let rank = d
            .failed_rank
            .map(|r| r.to_string())
            .unwrap_or_else(|| "-".into());
        let phase = d.failed_phase.clone().unwrap_or_else(|| "-".into());
        println!(
            "  {}  job {}  rank {rank}  phase {phase}  spans {}  reason: {}",
            d.path.display(),
            d.job,
            d.n_spans,
            d.reason
        );
    }
    Ok(())
}

fn cmd_sbatch(args: &[String]) -> Result<()> {
    let o = Opts::parse(args, &[])?;
    let script_path = o
        .positional
        .first()
        .ok_or_else(|| Error::Usage("sbatch needs a script path".into()))?;
    let text = std::fs::read_to_string(script_path)?;
    let spec = crate::slurm::parse_script(&text)?;
    let nodes: usize = o.get_or("cluster-nodes", "4").parse().unwrap_or(4);
    let mut sim = crate::slurm::SlurmSim::new(nodes, crate::slurm::Partition::standard_set());
    let id = sim.submit(spec)?;
    sim.run(u64::MAX);
    let j = sim.job(id).unwrap();
    println!("job {id} on a {nodes}-node simulated cluster:");
    println!("  state      : {:?}", j.state);
    println!("  requeues   : {}", j.requeues);
    println!("  checkpoints: {}", j.checkpoints);
    println!(
        "  end        : {}",
        j.end_time
            .map(crate::util::format_hms)
            .unwrap_or_else(|| "-".into())
    );
    println!("  work lost  : {}s", j.work_lost);
    Ok(())
}

fn cmd_run(args: &[String]) -> Result<()> {
    let o = Opts::parse(args, &["incremental"])?;
    let wl_name = o.get_or("workload", "water-phantom");
    let steps: u64 = o.get_or("steps", "480").parse().unwrap_or(480);
    let workdir = PathBuf::from(o.get_or(
        "workdir",
        &std::env::temp_dir()
            .join(format!("ncr_cli_{}", std::process::id()))
            .to_string_lossy(),
    ));

    // Gang mode: `--ranks N` (or the gang workload by name) drives every
    // rank of one halo-stencil computation through gang C/R.
    let ranks: Option<u32> = match o.get("ranks") {
        Some(v) => Some(v.parse().map_err(|_| Error::Usage("bad --ranks".into()))?),
        None => None,
    };
    if ranks == Some(0) {
        // Same contract as CampaignSpec::validate: a zero-rank gang is a
        // usage error, not a silent 1-rank run.
        return Err(Error::Usage("--ranks must be >= 1".into()));
    }
    if wl_name == crate::workload::STENCIL_LABEL || ranks.map(|r| r > 1).unwrap_or(false) {
        if let Some(explicit) = o.get("workload") {
            if explicit != crate::workload::STENCIL_LABEL {
                return Err(Error::Usage(format!(
                    "--ranks > 1 needs the gang workload ({}), not {explicit:?}",
                    crate::workload::STENCIL_LABEL
                )));
            }
        }
        return cmd_run_gang(&o, ranks.unwrap_or(4), steps, &workdir);
    }
    if ranks.is_some() {
        // --ranks 1 on a single-process workload is just the normal path.
        log::info!("--ranks 1: driving a plain single-process session");
    }
    let mut policy = crate::cr::CrPolicy::default();
    if let Some(ms) = o.get("preempt") {
        let ms: u64 = ms.parse().map_err(|_| Error::Usage("bad --preempt".into()))?;
        policy.preempt_after = vec![Duration::from_millis(ms)];
    }
    if o.has_flag("incremental") {
        policy.incremental_ckpt = true;
        if let Some(n) = o.get("full-every") {
            policy.full_image_every = n
                .parse()
                .map_err(|_| Error::Usage("bad --full-every".into()))?;
        }
        if let Some(spec) = o.get("chunker") {
            policy.chunker = spec.parse()?;
        }
    } else if o.get("full-every").is_some() {
        return Err(Error::Usage(
            "--full-every only applies with --incremental".into(),
        ));
    } else if o.get("chunker").is_some() {
        return Err(Error::Usage(
            "--chunker only applies with --incremental".into(),
        ));
    }

    // The CP2K-analog drives through the same session API as Geant4 —
    // that is the point of the CrApp boundary.
    if wl_name == crate::workload::CP2K_SCF_LABEL {
        let app = crate::workload::Cp2kApp::new(24);
        let report = crate::cr::CrSession::builder(&app)
            .strategy(crate::cr::CrStrategy::Auto(policy))
            .workdir(&workdir)
            .target_steps(steps)
            .seed(7)
            .build()?
            .run()?;
        println!(
            "completed={} incarnations={} checkpoints={} images={} wall={:.2}s \
             iterations={} digest={:016x}",
            report.completed,
            report.incarnations,
            report.checkpoints,
            crate::report::human_bytes(report.total_image_bytes),
            report.wall_secs,
            report.final_state.iterations,
            report.final_state.digest()
        );
        return Ok(());
    }

    let kind = crate::workload::WorkloadKind::all()
        .into_iter()
        .find(|k| k.label() == wl_name)
        .ok_or_else(|| Error::Usage(format!("unknown workload {wl_name:?} (see `workloads`)")))?;
    let version = match o.get_or("g4", "10.7").as_str() {
        "10.5" => crate::workload::G4Version::V10_5,
        "10.7" => crate::workload::G4Version::V10_7,
        "11.0" => crate::workload::G4Version::V11_0,
        v => return Err(Error::Usage(format!("unknown g4 version {v:?}"))),
    };
    let h = crate::runtime::service::shared()?;
    let app = crate::workload::G4App::build(kind, version, h.manifest().grid_d);
    let report = crate::cr::CrSession::builder(&app)
        .strategy(crate::cr::CrStrategy::Auto(policy))
        .workdir(&workdir)
        .target_steps(steps)
        .seed(7)
        .build()?
        .run()?;
    println!(
        "completed={} incarnations={} checkpoints={} images={} wall={:.2}s steps={}",
        report.completed,
        report.incarnations,
        report.checkpoints,
        crate::report::human_bytes(report.total_image_bytes),
        report.wall_secs,
        report.final_state.particles.steps_done
    );
    let (roi, total, hits) = h.score_roi(
        report.final_state.particles.edep.clone(),
        app.workload.roi.clone(),
    )?;
    let det = crate::workload::reading(&app.workload, roi, total, hits);
    println!(
        "detector: roi={roi:.2} MeV total={total:.2} MeV hits={hits} counts={}",
        det.counts
    );
    Ok(())
}

/// Drive an N-rank halo-stencil gang: submit, periodic gang checkpoints,
/// an optional mid-run preemption (`--preempt MS` kills one rank, which
/// aborts the generation, then gang-restarts every rank from the last
/// committed cut), and a final bitwise verification against the
/// uninterrupted reference.
fn cmd_run_gang(o: &Opts, ranks: u32, steps: u64, workdir: &std::path::Path) -> Result<()> {
    let cells: usize = o
        .get_or("stencil-cells", "64")
        .parse()
        .map_err(|_| Error::Usage("bad --stencil-cells".into()))?;
    let mana = o.get("mana").map(|v| v != "off").unwrap_or(true);
    let ckpt_every = Duration::from_millis(
        o.get_or("ckpt-ms", "60")
            .parse()
            .map_err(|_| Error::Usage("bad --ckpt-ms".into()))?,
    );
    let preempt_at: Option<Duration> = match o.get("preempt") {
        Some(ms) => Some(Duration::from_millis(
            ms.parse().map_err(|_| Error::Usage("bad --preempt".into()))?,
        )),
        None => None,
    };
    let app = crate::workload::StencilApp::new(ranks, cells);
    let mut builder = crate::cr::GangSession::builder(&app)
        .workdir(workdir)
        .target_steps(steps)
        .seed(7)
        .mana_exclusion(mana);
    if o.has_flag("incremental") {
        let full_every = match o.get("full-every") {
            Some(n) => n.parse().map_err(|_| Error::Usage("bad --full-every".into()))?,
            None => 0,
        };
        builder = builder.incremental_images(full_every);
        if let Some(spec) = o.get("chunker") {
            builder = builder.chunker(spec.parse()?);
        }
    } else if o.get("full-every").is_some() {
        return Err(Error::Usage(
            "--full-every only applies with --incremental".into(),
        ));
    } else if o.get("chunker").is_some() {
        return Err(Error::Usage(
            "--chunker only applies with --incremental".into(),
        ));
    }
    let mut session = builder.build()?;
    session.submit()?;

    let t0 = std::time::Instant::now();
    let mut checkpoints = 0u64;
    let mut stored = 0u64;
    let mut preempted = false;
    // Scheduled from "now" after each checkpoint (like the campaign
    // executor), so time spent in a gang restart does not produce a burst
    // of back-to-back catch-up barriers afterwards.
    let mut next_ckpt = std::time::Instant::now() + ckpt_every;
    loop {
        std::thread::sleep(Duration::from_millis(5));
        let st = session.monitor()?;
        if st.done {
            break;
        }
        let ran = t0.elapsed();
        if std::time::Instant::now() >= next_ckpt {
            match session.checkpoint_now() {
                Ok(ck) => {
                    checkpoints += 1;
                    stored += ck.manifest.stored_bytes();
                }
                Err(e) => log::warn!("gang checkpoint failed: {e}"),
            }
            next_ckpt = std::time::Instant::now() + ckpt_every;
        }
        if let Some(p) = preempt_at {
            if !preempted && ran >= p && session.latest_checkpoint()?.is_some() {
                let victim = (ranks / 2).min(ranks - 1);
                println!(
                    "preempting: killing rank {victim} (aborts the generation), \
                     gang-restarting all {ranks} ranks"
                );
                session.kill_rank(victim)?;
                session.kill()?;
                let resumed = session.resubmit_from_checkpoint()?;
                println!("gang restarted at the cut: {resumed}/{steps} steps");
                preempted = true;
                next_ckpt = std::time::Instant::now() + ckpt_every;
            }
        }
    }
    let finals = session.final_states()?;
    let verified = session.verify_final(&finals).is_ok();
    let generations = session.generation() + 1;
    session.finish();
    println!(
        "completed=true ranks={ranks} mana={} generations={generations} \
         gang_checkpoints={checkpoints} stored={} wall={:.2}s bitwise={}",
        if mana { "on" } else { "off" },
        crate::report::human_bytes(stored),
        t0.elapsed().as_secs_f64(),
        if verified { "ok" } else { "DIVERGED" }
    );
    if !verified {
        return Err(Error::Workload(
            "gang final state diverged from the uninterrupted reference".into(),
        ));
    }
    Ok(())
}

fn cmd_campaign(args: &[String]) -> Result<()> {
    let o = Opts::parse(args, &["json", "print-spec"])?;
    let mut spec = match o.get("spec") {
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            crate::campaign::CampaignSpec::parse(&text)?
        }
        None => crate::campaign::CampaignSpec::default(),
    };
    // Command-line overrides on top of the (possibly default) spec.
    if let Some(n) = o.get("sessions") {
        spec.sessions = n.parse().map_err(|_| Error::Usage("bad --sessions".into()))?;
    }
    if let Some(s) = o.get("seed") {
        spec.seed = s.parse().map_err(|_| Error::Usage("bad --seed".into()))?;
    }
    if let Some(wd) = o.get("workdir") {
        spec.workdir = Some(PathBuf::from(wd));
    }
    if let Some(a) = o.get("arrival") {
        spec.arrival = crate::campaign::ArrivalSpec::parse(a)?;
    }
    if let Some(s) = o.get("scheduler") {
        spec.scheduler = crate::campaign::SchedulerKind::parse(s)?;
    }
    if let Some(n) = o.get("admit-max") {
        spec.admit_max = match n {
            "off" => None,
            n => Some(n.parse().map_err(|_| Error::Usage("bad --admit-max".into()))?),
        };
    }
    if let Some(d) = o.get("preempt-signal") {
        spec.preempt_signal = match d {
            "off" => None,
            d => Some(crate::slurm::parse_signal_directive(d)?),
        };
    }
    if let Some(c) = o.get("chunker") {
        spec.chunker = c.parse()?;
    }
    spec.validate()?;
    if o.has_flag("print-spec") {
        print!("{}", spec.to_text());
        return Ok(());
    }
    let report = crate::campaign::run_campaign(&spec)?;
    if o.has_flag("json") {
        print!("{}", report.to_json());
        return Ok(());
    }
    println!(
        "== campaign {:?}: {} sessions x {} (K={}, {}), seed {} ==\n",
        spec.name,
        spec.sessions,
        spec.workload.label(),
        spec.concurrency,
        spec.substrate.name(),
        spec.seed
    );
    println!("{}", report.table().render());
    println!("{}", report.summary_table().render());
    println!("{}", report.slo_table().render());
    Ok(())
}

fn cmd_fig2(args: &[String]) -> Result<()> {
    let o = Opts::parse(args, &[])?;
    let max_ranks: u32 = o.get_or("ranks", "512").parse().unwrap_or(512);
    let mut r = 1u32;
    println!(
        "{:>6} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8}",
        "ranks", "HOME", "SCRATCH", "module", "CVMFS", "shifter", "podman"
    );
    while r <= max_ranks {
        let row: Vec<f64> = crate::fsmodel::Environment::all()
            .iter()
            .map(|e| e.import_time(r))
            .collect();
        println!(
            "{:>6} {:>8.2} {:>8.2} {:>8.2} {:>8.2} {:>8.2} {:>8.2}",
            r, row[0], row[1], row[2], row[3], row[4], row[5]
        );
        r *= 2;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opts_parsing() {
        let args: Vec<String> = ["pos1", "--key", "val", "--k2=v2", "--no-gzip", "pos2"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let o = Opts::parse(&args, &["no-gzip"]).unwrap();
        assert_eq!(o.positional, vec!["pos1", "pos2"]);
        assert_eq!(o.get("key"), Some("val"));
        assert_eq!(o.get("k2"), Some("v2"));
        assert!(o.has_flag("no-gzip"));
        assert!(!o.has_flag("other"));
    }

    #[test]
    fn missing_value_rejected() {
        let args = vec!["--key".to_string()];
        assert!(Opts::parse(&args, &[]).is_err());
    }

    #[test]
    fn unknown_subcommand_errors() {
        assert!(run(vec!["frobnicate".into()]).is_err());
    }

    #[test]
    fn version_and_workloads_run() {
        run(vec!["version".into()]).unwrap();
        run(vec!["workloads".into()]).unwrap();
        run(vec!["fig2".into(), "--ranks".into(), "8".into()]).unwrap();
    }

    #[test]
    fn campaign_print_spec_and_overrides() {
        run(vec![
            "campaign".into(),
            "--sessions".into(),
            "5".into(),
            "--print-spec".into(),
        ])
        .unwrap();
        assert!(run(vec![
            "campaign".into(),
            "--sessions".into(),
            "0".into(),
            "--print-spec".into(),
        ])
        .is_err());
    }

    #[test]
    fn campaign_scheduler_overrides_parse_and_validate() {
        run(vec![
            "campaign".into(),
            "--arrival".into(),
            "poisson:4".into(),
            "--scheduler".into(),
            "ckpt-aware".into(),
            "--admit-max".into(),
            "3".into(),
            "--preempt-signal".into(),
            "TERM@120".into(),
            "--print-spec".into(),
        ])
        .unwrap();
        for bad in [
            vec!["campaign", "--scheduler", "lottery", "--print-spec"],
            vec!["campaign", "--arrival", "poisson:0", "--print-spec"],
            vec!["campaign", "--admit-max", "0", "--print-spec"],
            // The offset is required and consumed, not silently dropped.
            vec!["campaign", "--preempt-signal", "TERM", "--print-spec"],
        ] {
            assert!(
                run(bad.iter().map(|s| s.to_string()).collect()).is_err(),
                "{bad:?}"
            );
        }
    }

    #[test]
    fn campaign_chunker_override_parses_and_rejects_bad_specs() {
        run(vec![
            "campaign".into(),
            "--chunker".into(),
            "cdc:4096:16384:65536".into(),
            "--print-spec".into(),
        ])
        .unwrap();
        for bad in [
            vec!["campaign", "--chunker", "rolling", "--print-spec"],
            vec!["campaign", "--chunker", "cdc:0:8:16", "--print-spec"],
            // --chunker without --incremental on `run` is a usage error.
            vec!["run", "--chunker", "cdc"],
        ] {
            assert!(
                run(bad.iter().map(|s| s.to_string()).collect()).is_err(),
                "{bad:?}"
            );
        }
    }

    #[test]
    fn run_gang_smoke() {
        let dir = std::env::temp_dir().join(format!("ncr_cli_gang_{}", std::process::id()));
        run(vec![
            "run".into(),
            "--ranks".into(),
            "2".into(),
            "--steps".into(),
            "30".into(),
            "--stencil-cells".into(),
            "8".into(),
            "--ckpt-ms".into(),
            "20".into(),
            "--workdir".into(),
            dir.to_string_lossy().into_owned(),
        ])
        .unwrap();
        // A non-gang workload with --ranks > 1 is a usage error.
        assert!(run(vec![
            "run".into(),
            "--ranks".into(),
            "2".into(),
            "--workload".into(),
            "cp2k-scf".into(),
        ])
        .is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn trace_lists_flight_dumps() {
        let dir = std::env::temp_dir().join(format!("ncr_cli_trace_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // No dumps: still succeeds (prints the empty notice).
        run(vec!["trace".into(), dir.to_string_lossy().into_owned()]).unwrap();
        // A dump written through the real path is then listed without error.
        crate::trace::install(crate::trace::TraceConfig::default());
        crate::trace::event(crate::trace::names::PHASE_FAIL, |a| {
            a.str("job", "cli-trace-job");
            a.u64("rank", 1);
            a.str("phase", "Drain");
            a.str("error", "injected");
        });
        crate::trace::flight::dump_for_job("cli-trace-job", "test dump", &dir)
            .expect("dump written");
        run(vec!["trace".into(), dir.to_string_lossy().into_owned()]).unwrap();
        // Missing workdir argument is a usage error.
        assert!(run(vec!["trace".into()]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn campaign_runs_a_tiny_fleet_from_a_spec_file() {
        let dir = std::env::temp_dir().join(format!("ncr_cli_campaign_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let spec_path = dir.join("tiny.campaign");
        std::fs::write(
            &spec_path,
            "name = cli-tiny\nsessions = 2\nconcurrency = 2\nsteps = 200\n\
             interval = 10\nmtbf-ms = off\nstraggler-timeout-ms = 60000\n",
        )
        .unwrap();
        run(vec![
            "campaign".into(),
            "--spec".into(),
            spec_path.to_string_lossy().into_owned(),
            "--workdir".into(),
            dir.join("wd").to_string_lossy().into_owned(),
            "--json".into(),
        ])
        .unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }
}
