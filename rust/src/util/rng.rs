//! Deterministic pseudo-random number generation for the simulators.
//!
//! SplitMix64 (Steele, Lea, Flood 2014): tiny state, passes BigCrush when
//! used as a stream, and — critically for this repo — *seedable and
//! reproducible*, so every scheduler trace, workload and property test is
//! replayable from its seed. Not for cryptography.

/// SplitMix64 generator.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed. Equal seeds yield equal streams.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Next 32-bit value.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)`. `n` must be non-zero.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free variant is overkill here;
        // modulo bias at these ranges (n << 2^64) is negligible for sims.
        self.next_u64() % n
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn gen_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Exponentially distributed sample with the given mean.
    pub fn gen_exp(&mut self, mean: f64) -> f64 {
        let u = self.next_f64().max(1e-12);
        -mean * u.ln()
    }

    /// Standard-normal sample (Box–Muller; one value per call for
    /// reproducibility simplicity).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fork a statistically-independent child stream (e.g. per job/process).
    pub fn fork(&mut self) -> SplitMix64 {
        SplitMix64::new(self.next_u64() ^ 0xA076_1D64_78BD_642F)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproducible_from_seed() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = SplitMix64::new(9);
        for _ in 0..10_000 {
            assert!(r.gen_range(13) < 13);
        }
    }

    #[test]
    fn exp_mean_roughly_right() {
        let mut r = SplitMix64::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.gen_exp(5.0)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.2, "mean={mean}");
    }

    #[test]
    fn fork_independent() {
        let mut parent = SplitMix64::new(3);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(17);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
