//! A minimal property-based testing harness.
//!
//! `proptest` is not in the offline dependency closure, so this module
//! provides the 10% of it this crate needs: seeded random case generation,
//! a configurable number of cases, and first-failure reporting with the
//! case's seed so it can be replayed by pinning `PROPTEST_LITE_SEED`.
//!
//! ```no_run
//! use nersc_cr::util::proptest_lite::{run_cases, Gen};
//! run_cases("my invariant", 100, |g: &mut Gen| {
//!     let xs = g.vec_u64(0..50, 0..1000);
//!     let mut sorted = xs.clone();
//!     sorted.sort_unstable();
//!     assert_eq!(sorted.len(), xs.len());
//! });
//! ```

use std::ops::Range;

use crate::util::rng::SplitMix64;

/// Per-case random value source handed to the property body.
pub struct Gen {
    rng: SplitMix64,
    /// Case index (0-based) — handy for logging.
    pub case: usize,
}

impl Gen {
    /// Uniform u64 in `range`.
    pub fn u64_in(&mut self, range: Range<u64>) -> u64 {
        debug_assert!(range.end > range.start);
        range.start + self.rng.gen_range(range.end - range.start)
    }

    /// Uniform usize in `range`.
    pub fn usize_in(&mut self, range: Range<usize>) -> usize {
        self.u64_in(range.start as u64..range.end as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.gen_f64(lo, hi)
    }

    /// Bernoulli with probability `p`.
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.rng.next_f64() < p
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0..xs.len())]
    }

    /// Vector of uniform u64s; length drawn from `len`, values from `vals`.
    pub fn vec_u64(&mut self, len: Range<usize>, vals: Range<u64>) -> Vec<u64> {
        let n = self.usize_in(len.start..len.end.max(len.start + 1));
        (0..n).map(|_| self.u64_in(vals.clone())).collect()
    }

    /// Vector of random bytes.
    pub fn bytes(&mut self, len: Range<usize>) -> Vec<u8> {
        let n = self.usize_in(len.start..len.end.max(len.start + 1));
        (0..n).map(|_| self.rng.next_u32() as u8).collect()
    }

    /// ASCII identifier-ish string (for names, paths, tags).
    pub fn ident(&mut self, len: Range<usize>) -> String {
        const CHARS: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789_-";
        let n = self.usize_in(len.start..len.end.max(len.start + 1));
        (0..n)
            .map(|_| CHARS[self.usize_in(0..CHARS.len())] as char)
            .collect()
    }

    /// Access the underlying stream (for custom distributions).
    pub fn rng(&mut self) -> &mut SplitMix64 {
        &mut self.rng
    }
}

/// Run `cases` random cases of `body`. Panics (re-raising the property's
/// panic) on the first failing case with its replay seed.
///
/// `PROPTEST_LITE_CASES` raises the case count above the in-code default
/// (it never lowers it): the nightly CI lane sets it to run every property
/// suite deeper than the per-push budget allows.
pub fn run_cases<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut body: F) {
    let cases = std::env::var("PROPTEST_LITE_CASES")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .map(|n| n.max(cases))
        .unwrap_or(cases);
    let base_seed = std::env::var("PROPTEST_LITE_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xC0FF_EE00_D15E_A5E5);
    let mut master = SplitMix64::new(base_seed);
    for case in 0..cases {
        let case_seed = master.next_u64();
        let mut g = Gen {
            rng: SplitMix64::new(case_seed),
            case,
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut g)));
        if let Err(payload) = result {
            eprintln!(
                "property {name:?} failed at case {case}/{cases} \
                 (replay: PROPTEST_LITE_SEED={base_seed}, case seed {case_seed})"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut n = 0;
        run_cases("count", 25, |_g| n += 1);
        // PROPTEST_LITE_CASES can only deepen a suite, never shrink it.
        assert!(n >= 25, "ran {n} of 25 cases");
    }

    #[test]
    fn gen_ranges_respected() {
        run_cases("ranges", 50, |g| {
            assert!(g.u64_in(5..10) >= 5 && g.u64_in(5..10) < 10);
            let v = g.vec_u64(1..4, 0..100);
            assert!(!v.is_empty() && v.len() < 4);
            let s = g.ident(3..8);
            assert!(s.len() >= 3 && s.len() < 8);
        });
    }

    #[test]
    #[should_panic]
    fn failure_propagates() {
        run_cases("fails", 10, |g| {
            assert!(g.u64_in(0..100) > 1000, "always fails");
        });
    }
}
