//! Human-readable walltime formatting, Slurm-style.
//!
//! The paper's job script "converts execution time into a human-readable
//! format [and calculates] the remaining time for job scheduling"; these are
//! those conversions, matching `sbatch --time` syntax:
//! `MM`, `MM:SS`, `HH:MM:SS`, `D-HH`, `D-HH:MM`, `D-HH:MM:SS`.

use crate::error::{Error, Result};

/// Format seconds as `[D-]HH:MM:SS` (Slurm `squeue`-style).
pub fn format_hms(total_secs: u64) -> String {
    let days = total_secs / 86_400;
    let h = (total_secs % 86_400) / 3_600;
    let m = (total_secs % 3_600) / 60;
    let s = total_secs % 60;
    if days > 0 {
        format!("{days}-{h:02}:{m:02}:{s:02}")
    } else {
        format!("{h:02}:{m:02}:{s:02}")
    }
}

/// Parse a Slurm walltime string into seconds.
pub fn parse_hms(s: &str) -> Result<u64> {
    let bad = || Error::Slurm(format!("invalid time spec: {s:?}"));
    let s = s.trim();
    if s.is_empty() {
        return Err(bad());
    }
    let (days, rest) = match s.split_once('-') {
        Some((d, rest)) => (d.parse::<u64>().map_err(|_| bad())?, rest),
        None => (0, s),
    };
    let parts: Vec<&str> = rest.split(':').collect();
    let nums: Vec<u64> = parts
        .iter()
        .map(|p| p.parse::<u64>().map_err(|_| bad()))
        .collect::<Result<_>>()?;
    let secs = if days > 0 {
        // D-HH, D-HH:MM, D-HH:MM:SS
        match nums.as_slice() {
            [h] => h * 3_600,
            [h, m] => h * 3_600 + m * 60,
            [h, m, sec] => h * 3_600 + m * 60 + sec,
            _ => return Err(bad()),
        }
    } else {
        // MM, MM:SS, HH:MM:SS
        match nums.as_slice() {
            [m] => m * 60,
            [m, sec] => m * 60 + sec,
            [h, m, sec] => h * 3_600 + m * 60 + sec,
            _ => return Err(bad()),
        }
    };
    Ok(days * 86_400 + secs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats() {
        assert_eq!(format_hms(0), "00:00:00");
        assert_eq!(format_hms(59), "00:00:59");
        assert_eq!(format_hms(3_661), "01:01:01");
        assert_eq!(format_hms(86_400 + 3_600), "1-01:00:00");
    }

    #[test]
    fn parses_slurm_forms() {
        assert_eq!(parse_hms("30").unwrap(), 1_800); // 30 minutes
        assert_eq!(parse_hms("30:15").unwrap(), 1_815); // MM:SS
        assert_eq!(parse_hms("02:00:00").unwrap(), 7_200);
        assert_eq!(parse_hms("1-12").unwrap(), 86_400 + 12 * 3_600);
        assert_eq!(parse_hms("1-12:30").unwrap(), 86_400 + 12 * 3_600 + 1_800);
        assert_eq!(parse_hms("2-00:00:30").unwrap(), 2 * 86_400 + 30);
    }

    #[test]
    fn roundtrip() {
        for secs in [0, 1, 60, 3_599, 3_600, 86_399, 86_400, 200_000] {
            assert_eq!(parse_hms(&format_hms(secs)).unwrap(), secs);
        }
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "abc", "1:2:3:4", "-5", "1-"] {
            assert!(parse_hms(s).is_err(), "{s:?} should fail");
        }
    }
}
