//! Small shared utilities: deterministic RNG, human-readable durations,
//! byte helpers, and an in-repo property-testing harness.
//!
//! The offline build environment only carries the `xla` crate's vendored
//! dependency closure, so `rand`/`proptest`/`humantime` are reimplemented
//! here at the small scale this crate needs.

pub mod bytes;
pub mod humantime;
pub mod proptest_lite;
pub mod rng;

pub use humantime::{format_hms, parse_hms};
pub use rng::SplitMix64;
