//! Little-endian byte (de)serialization helpers for checkpoint images.
//!
//! Checkpoint images are raw memory dumps plus typed metadata; everything is
//! little-endian on the wire/disk (DMTCP images are likewise
//! host-endianness; we pin LE for cross-host restore determinism).

use crate::error::{Error, Result};

/// Append helpers over a growable buffer.
pub trait PutBytes {
    fn put_u8(&mut self, v: u8);
    fn put_u32(&mut self, v: u32);
    fn put_u64(&mut self, v: u64);
    fn put_i64(&mut self, v: i64);
    fn put_f64(&mut self, v: f64);
    fn put_bytes(&mut self, v: &[u8]);
    /// Length-prefixed (u32) byte string.
    fn put_lp_bytes(&mut self, v: &[u8]);
    /// Length-prefixed UTF-8 string.
    fn put_lp_str(&mut self, v: &str) {
        self.put_lp_bytes(v.as_bytes());
    }
}

impl PutBytes for Vec<u8> {
    fn put_u8(&mut self, v: u8) {
        self.push(v);
    }
    fn put_u32(&mut self, v: u32) {
        self.extend_from_slice(&v.to_le_bytes());
    }
    fn put_u64(&mut self, v: u64) {
        self.extend_from_slice(&v.to_le_bytes());
    }
    fn put_i64(&mut self, v: i64) {
        self.extend_from_slice(&v.to_le_bytes());
    }
    fn put_f64(&mut self, v: f64) {
        self.extend_from_slice(&v.to_le_bytes());
    }
    fn put_bytes(&mut self, v: &[u8]) {
        self.extend_from_slice(v);
    }
    fn put_lp_bytes(&mut self, v: &[u8]) {
        self.put_u32(v.len() as u32);
        self.extend_from_slice(v);
    }
}

/// Cursor-style reader over a byte slice with range checks.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn position(&self) -> usize {
        self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::Image(format!(
                "truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn get_bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }

    pub fn get_lp_bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.get_u32()? as usize;
        self.take(n)
    }

    pub fn get_lp_str(&mut self) -> Result<String> {
        let b = self.get_lp_bytes()?;
        String::from_utf8(b.to_vec()).map_err(|e| Error::Image(format!("bad utf8: {e}")))
    }
}

/// Reinterpret a `&[f32]` as little-endian bytes (copy).
pub fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Reinterpret little-endian bytes as `Vec<f32>`.
pub fn bytes_to_f32s(b: &[u8]) -> Result<Vec<f32>> {
    if b.len() % 4 != 0 {
        return Err(Error::Image(format!("f32 blob length {} not /4", b.len())));
    }
    Ok(b.chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Reinterpret a `&[u32]` as little-endian bytes (copy).
pub fn u32s_to_bytes(v: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Reinterpret little-endian bytes as `Vec<u32>`.
pub fn bytes_to_u32s(b: &[u8]) -> Result<Vec<u32>> {
    if b.len() % 4 != 0 {
        return Err(Error::Image(format!("u32 blob length {} not /4", b.len())));
    }
    Ok(b.chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        let mut buf = Vec::new();
        buf.put_u8(7);
        buf.put_u32(0xDEAD_BEEF);
        buf.put_u64(u64::MAX - 1);
        buf.put_i64(-42);
        buf.put_f64(3.25);
        buf.put_lp_str("hello");
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.get_u8().unwrap(), 7);
        assert_eq!(r.get_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.get_u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.get_i64().unwrap(), -42);
        assert_eq!(r.get_f64().unwrap(), 3.25);
        assert_eq!(r.get_lp_str().unwrap(), "hello");
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn truncation_detected() {
        let mut buf = Vec::new();
        buf.put_u32(10);
        let mut r = ByteReader::new(&buf[..2]);
        assert!(r.get_u32().is_err());
    }

    #[test]
    fn lp_bytes_truncation_detected() {
        let mut buf = Vec::new();
        buf.put_lp_bytes(&[1, 2, 3, 4, 5]);
        let mut r = ByteReader::new(&buf[..6]);
        assert!(r.get_lp_bytes().is_err());
    }

    #[test]
    fn f32_u32_blobs() {
        let f = vec![1.0f32, -2.5, 3.25e-9];
        assert_eq!(bytes_to_f32s(&f32s_to_bytes(&f)).unwrap(), f);
        let u = vec![0u32, 1, u32::MAX];
        assert_eq!(bytes_to_u32s(&u32s_to_bytes(&u)).unwrap(), u);
        assert!(bytes_to_f32s(&[0, 1, 2]).is_err());
        assert!(bytes_to_u32s(&[0, 1, 2]).is_err());
    }
}
