//! Seeded fleet campaigns on the batch-scheduler simulator.
//!
//! One harness, three consumers: the Young/Daly tuner's brute-force
//! validation sweeps ([`crate::campaign::tune`]), the `campaign_sweep`
//! bench, and the `preemptible_queue` example. A [`SimFleetSpec`] submits
//! a fleet of preemptable "science" jobs plus an optional stream of
//! higher-priority "urgent" jobs that force preemptions, runs the
//! discrete-event [`SlurmSim`], and folds the accounting into a
//! [`SimFleetOutcome`]. Everything is seeded, so a spec replays the same
//! trace — the property the tuner tests and the bench lean on.

use crate::simclock::SimTime;
use crate::slurm::{CrMode, JobId, JobSpec, JobState, Partition, Signal, SlurmSim};
use crate::util::rng::SplitMix64;

/// Higher-priority load injected to preempt the science fleet: `n` jobs
/// submitted at seeded-uniform times in `[0, window)` on the `realtime`
/// partition.
#[derive(Debug, Clone, PartialEq)]
pub struct UrgentLoad {
    /// Number of urgent jobs over the window.
    pub n: u32,
    /// Minimum nodes per urgent job.
    pub nodes_min: u32,
    /// Extra nodes drawn uniformly from `[0, nodes_spread)`.
    pub nodes_spread: u64,
    /// Minimum work per urgent job (seconds).
    pub work_min: SimTime,
    /// Extra work drawn uniformly from `[0, work_spread)`.
    pub work_spread: SimTime,
    /// Walltime limit per urgent job.
    pub time_limit: SimTime,
    /// Submission window: arrivals are uniform in `[0, window)`.
    pub window: SimTime,
}

/// A seeded fleet campaign on the scheduler simulator.
#[derive(Debug, Clone, PartialEq)]
pub struct SimFleetSpec {
    /// Cluster size (whole nodes).
    pub nodes: usize,
    /// Science jobs in the fleet (submitted on the `preempt` partition).
    pub n_jobs: u32,
    /// Science job nodes drawn uniformly from `[1, nodes_max]`.
    pub nodes_max: u32,
    /// Minimum work per science job (seconds).
    pub work_min: SimTime,
    /// Extra work drawn uniformly from `[0, work_spread)`.
    pub work_spread: SimTime,
    /// Walltime limit per science job.
    pub time_limit: SimTime,
    /// `--time-min` for backfill shrink-to-fit (None = rigid).
    pub time_min: Option<SimTime>,
    /// Pre-timelimit `--signal` directive.
    pub signal: Option<(Signal, SimTime)>,
    /// `--requeue` eligibility of the science jobs.
    pub requeue: bool,
    /// Checkpoint-restart mode of the science jobs (the comparison axis).
    pub cr: CrMode,
    /// Science submissions are uniform in `[0, submit_spread)`.
    pub submit_spread: SimTime,
    /// Simulation horizon.
    pub horizon: SimTime,
    /// Trace seed: equal specs replay equal traces.
    pub seed: u64,
    /// Optional preemption pressure.
    pub urgent: Option<UrgentLoad>,
    /// Override every partition's preemption grace period (``Some(0)`` =
    /// hard kills, where recovery rides on the last *periodic*
    /// checkpoint — the regime the checkpoint interval matters in).
    pub grace_override: Option<SimTime>,
}

impl SimFleetSpec {
    /// The tuner's laboratory: a small fleet of single-node science jobs
    /// under hard-kill (zero-grace) preemption waves with mean
    /// inter-arrival `mtbf`. Each wave takes the whole cluster, so every
    /// running science job loses the work since its last periodic
    /// checkpoint — the textbook renewal process Young/Daly optimizes.
    pub fn preemption_lab(interval: SimTime, ckpt_cost: SimTime, mtbf: SimTime, seed: u64) -> Self {
        let nodes = 4usize;
        let work: SimTime = 20_000;
        // Enough urgent arrivals to cover the stretched makespan; extras
        // after the fleet finishes just run to completion harmlessly.
        let window = 6 * work;
        let n = (window / mtbf.max(1)).max(1) as u32;
        Self {
            nodes,
            n_jobs: nodes as u32,
            nodes_max: 1,
            work_min: work,
            work_spread: 1,
            time_limit: 80_000,
            time_min: None,
            signal: None,
            requeue: true,
            cr: CrMode::CheckpointRestart {
                interval,
                overhead: ckpt_cost,
            },
            submit_spread: 1,
            horizon: SimTime::MAX,
            seed,
            urgent: Some(UrgentLoad {
                n,
                nodes_min: nodes as u32,
                nodes_spread: 1,
                work_min: 60,
                work_spread: 60,
                time_limit: 3_600,
                window,
            }),
            grace_override: Some(0),
        }
    }
}

/// Fleet-level accounting folded out of one simulated campaign.
#[derive(Debug, Clone, PartialEq)]
pub struct SimFleetOutcome {
    /// Cluster utilization over the measured window.
    pub utilization: f64,
    /// Science jobs that completed.
    pub completed: u32,
    /// Science jobs submitted.
    pub n_jobs: u32,
    /// Compute seconds the science fleet lost to preemptions/timeouts.
    pub work_lost: u64,
    /// Walltime seconds the fleet paid writing checkpoints.
    pub ckpt_overhead_paid: u64,
    /// Checkpoints taken across the fleet.
    pub checkpoints: u64,
    /// Requeues across the fleet.
    pub requeues: u64,
    /// Latest science-job end time (0 when none finished).
    pub makespan: SimTime,
    /// Mean queue wait of the urgent jobs that started (seconds).
    pub urgent_wait_mean: f64,
    /// Total wasted seconds: lost work plus checkpoint overhead — the
    /// quantity the Young/Daly interval minimizes.
    pub waste: u64,
}

/// Run one seeded fleet campaign to its horizon.
pub fn run_fleet_sim(spec: &SimFleetSpec) -> SimFleetOutcome {
    let mut parts = Partition::standard_set();
    if let Some(g) = spec.grace_override {
        for p in parts.iter_mut() {
            p.grace_period = g;
        }
    }
    let mut sim = SlurmSim::new(spec.nodes, parts);
    let mut rng = SplitMix64::new(spec.seed);

    let mut science: Vec<JobId> = Vec::new();
    let mut science_rng = rng.fork();
    for i in 0..spec.n_jobs {
        let id = sim
            .submit_at(
                JobSpec {
                    name: format!("science{i}"),
                    partition: "preempt".into(),
                    nodes: 1 + science_rng.gen_range(spec.nodes_max.max(1) as u64) as u32,
                    work_total: spec.work_min + science_rng.gen_range(spec.work_spread.max(1)),
                    time_limit: spec.time_limit,
                    time_min: spec.time_min,
                    signal: spec.signal,
                    requeue: spec.requeue,
                    comment: String::new(),
                    cr: spec.cr,
                },
                science_rng.gen_range(spec.submit_spread.max(1)),
            )
            .expect("science submission");
        science.push(id);
    }

    let mut urgent: Vec<JobId> = Vec::new();
    if let Some(u) = &spec.urgent {
        let mut urgent_rng = rng.fork();
        for k in 0..u.n {
            let id = sim
                .submit_at(
                    JobSpec {
                        name: format!("urgent{k}"),
                        partition: "realtime".into(),
                        nodes: u.nodes_min + urgent_rng.gen_range(u.nodes_spread.max(1)) as u32,
                        work_total: u.work_min + urgent_rng.gen_range(u.work_spread.max(1)),
                        time_limit: u.time_limit,
                        ..Default::default()
                    },
                    urgent_rng.gen_range(u.window.max(1)),
                )
                .expect("urgent submission");
            urgent.push(id);
        }
    }

    sim.run(spec.horizon);

    let mut out = SimFleetOutcome {
        utilization: sim.utilization(),
        completed: 0,
        n_jobs: spec.n_jobs,
        work_lost: 0,
        ckpt_overhead_paid: 0,
        checkpoints: 0,
        requeues: 0,
        makespan: 0,
        urgent_wait_mean: 0.0,
        waste: 0,
    };
    for id in &science {
        let j = sim.job(*id).expect("science job");
        if j.state == JobState::Completed {
            out.completed += 1;
        }
        out.work_lost += j.work_lost;
        out.checkpoints += j.checkpoints as u64;
        out.ckpt_overhead_paid += j.checkpoints as u64 * j.spec.cr.overhead();
        out.requeues += j.requeues as u64;
        if let Some(t) = j.end_time {
            out.makespan = out.makespan.max(t);
        }
    }
    let waits: Vec<f64> = urgent
        .iter()
        .filter_map(|id| {
            let j = sim.job(*id).expect("urgent job");
            j.start_time.map(|st| (st - j.submit_time) as f64)
        })
        .collect();
    if !waits.is_empty() {
        out.urgent_wait_mean = waits.iter().sum::<f64>() / waits.len() as f64;
    }
    out.waste = out.work_lost + out.ckpt_overhead_paid;
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_replays_are_identical() {
        let spec = SimFleetSpec::preemption_lab(600, 10, 2_000, 42);
        let a = run_fleet_sim(&spec);
        let b = run_fleet_sim(&spec);
        assert_eq!(a, b);
    }

    #[test]
    fn seeds_change_the_trace() {
        let a = run_fleet_sim(&SimFleetSpec::preemption_lab(600, 10, 2_000, 1));
        let b = run_fleet_sim(&SimFleetSpec::preemption_lab(600, 10, 2_000, 2));
        assert_ne!(a, b);
    }

    #[test]
    fn lab_fleet_completes_under_cr_despite_hard_kills() {
        let o = run_fleet_sim(&SimFleetSpec::preemption_lab(600, 10, 2_000, 42));
        assert_eq!(o.completed, o.n_jobs, "C/R must carry the fleet through");
        assert!(o.requeues > 0, "the lab must actually preempt");
        assert!(o.work_lost > 0, "hard kills must cost something");
    }

    #[test]
    fn interval_extremes_trade_overhead_for_loss() {
        // Frequent checkpoints pay more overhead; rare ones lose more
        // work — the trade the lab exists to expose.
        let fast = run_fleet_sim(&SimFleetSpec::preemption_lab(30, 10, 2_000, 42));
        let slow = run_fleet_sim(&SimFleetSpec::preemption_lab(8_000, 10, 2_000, 42));
        assert!(
            fast.ckpt_overhead_paid > slow.ckpt_overhead_paid,
            "fast={} slow={}",
            fast.ckpt_overhead_paid,
            slow.ckpt_overhead_paid
        );
        assert!(
            slow.work_lost > fast.work_lost,
            "slow={} fast={}",
            slow.work_lost,
            fast.work_lost
        );
    }
}
