//! Checkpoint-interval auto-tuning: the Young/Daly optimum with a
//! measured-cost feedback loop.
//!
//! For a job that checkpoints every `τ` seconds at cost `C` and fails
//! with mean time between failures `M`, the first-order optimum of the
//! wasted-time rate (overhead `C/τ` plus expected loss `τ/2M` per unit
//! work) is Young's interval `τ* = sqrt(2·C·M)` (Daly's higher-order
//! correction matters only once `C` approaches `M`, far from the regime
//! checkpointable HPC jobs run in). The campaign executor does not trust
//! an operator-supplied `C`: a [`DalyTuner`] starts from a prior,
//! measures every real checkpoint it takes, folds the measurement in
//! (EWMA), and re-derives the interval — so a workload whose state grows
//! over the run drifts its interval with it.
//!
//! The formula is validated, not assumed: [`brute_force_optimal`] sweeps
//! a fixed-interval grid through the seeded
//! [`crate::campaign::sim::SimFleetSpec::preemption_lab`] renewal process
//! on the `slurm` simulator, and the property tests assert the tuned
//! interval's waste lands within tolerance of the brute-force optimum
//! (and monotonicity of `τ*` in both `C` and `M`).

use std::time::Duration;

use crate::campaign::sim::{run_fleet_sim, SimFleetSpec};
use crate::simclock::SimTime;

/// Young's optimal checkpoint interval `sqrt(2·ckpt_cost·mtbf)`, in
/// seconds. Degenerate inputs clamp to `ckpt_cost` (never checkpoint
/// more often than a checkpoint takes to write).
pub fn young_daly_interval_secs(ckpt_cost: f64, mtbf: f64) -> f64 {
    let c = ckpt_cost.max(0.0);
    let m = mtbf.max(0.0);
    (2.0 * c * m).sqrt().max(c)
}

/// How a campaign chooses its checkpoint cadence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IntervalPolicy {
    /// Checkpoint every fixed duration (the paper's static default).
    Fixed(Duration),
    /// Young/Daly auto-tuning seeded with a prior checkpoint-cost guess,
    /// refined by measuring every checkpoint actually taken.
    Daly {
        /// Initial checkpoint-cost estimate before any measurement.
        cost_prior: Duration,
    },
}

/// Live Young/Daly interval tuner (see the module docs).
#[derive(Debug, Clone)]
pub struct DalyTuner {
    mtbf_secs: f64,
    cost_secs: f64,
    /// EWMA smoothing factor for measured checkpoint costs.
    alpha: f64,
    lo: Duration,
    hi: Duration,
    observed: u64,
}

impl DalyTuner {
    /// Tuner for a failure process with mean time between failures
    /// `mtbf`, starting from the cost estimate `cost_prior`.
    pub fn new(mtbf: Duration, cost_prior: Duration) -> Self {
        Self {
            mtbf_secs: mtbf.as_secs_f64(),
            cost_secs: cost_prior.as_secs_f64(),
            alpha: 0.3,
            lo: Duration::from_millis(1),
            hi: Duration::from_secs(24 * 3_600),
            observed: 0,
        }
    }

    /// Clamp tuned intervals into `[lo, hi]` (campaigns bound the cadence
    /// so a wild cost measurement cannot stall checkpointing entirely).
    pub fn clamp(mut self, lo: Duration, hi: Duration) -> Self {
        self.lo = lo;
        self.hi = hi.max(lo);
        self
    }

    /// Fold one measured checkpoint cost into the estimate. The first
    /// measurement replaces the prior outright; later ones are smoothed.
    pub fn observe_cost(&mut self, measured: Duration) {
        let m = measured.as_secs_f64();
        self.cost_secs = if self.observed == 0 {
            m
        } else {
            self.alpha * m + (1.0 - self.alpha) * self.cost_secs
        };
        self.observed += 1;
    }

    /// The current checkpoint-cost estimate.
    pub fn cost_estimate(&self) -> Duration {
        Duration::from_secs_f64(self.cost_secs.max(0.0))
    }

    /// Checkpoint-cost measurements folded in so far.
    pub fn observations(&self) -> u64 {
        self.observed
    }

    /// The tuned interval for the current cost estimate, clamped.
    pub fn interval(&self) -> Duration {
        let secs = young_daly_interval_secs(self.cost_secs, self.mtbf_secs);
        Duration::from_secs_f64(secs).clamp(self.lo, self.hi)
    }
}

/// One interval's preemption-lab outcome averaged over several trace
/// seeds — a single hard-kill trace is noisy at long MTBFs (few kills),
/// so sweeps compare seed-averaged waste. Every field comes from the
/// same runs, so `lost + overhead == waste` holds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SweepPoint {
    /// The fixed checkpoint interval this point measured (seconds).
    pub interval: SimTime,
    /// Mean wasted seconds (lost work plus checkpoint overhead).
    pub waste: f64,
    /// Mean compute seconds lost to kills.
    pub lost: f64,
    /// Mean walltime seconds paid writing checkpoints.
    pub overhead: f64,
    /// Fewest science jobs completed across the trace seeds.
    pub completed_min: u32,
    /// Science jobs per trace.
    pub n_jobs: u32,
}

/// Run the seeded preemption lab at one interval, averaged over
/// `rounds` derived trace seeds (`seed`, `seed + 101`, ...).
pub fn averaged_lab(
    interval: SimTime,
    ckpt_cost: SimTime,
    mtbf: SimTime,
    seed: u64,
    rounds: u32,
) -> SweepPoint {
    assert!(rounds > 0, "averaged_lab needs at least one round");
    let mut p = SweepPoint {
        interval,
        waste: 0.0,
        lost: 0.0,
        overhead: 0.0,
        completed_min: u32::MAX,
        n_jobs: 0,
    };
    for r in 0..rounds as u64 {
        let o = run_fleet_sim(&SimFleetSpec::preemption_lab(
            interval,
            ckpt_cost,
            mtbf,
            seed.wrapping_add(101 * r),
        ));
        p.waste += o.waste as f64;
        p.lost += o.work_lost as f64;
        p.overhead += o.ckpt_overhead_paid as f64;
        p.completed_min = p.completed_min.min(o.completed);
        p.n_jobs = o.n_jobs;
    }
    p.waste /= rounds as f64;
    p.lost /= rounds as f64;
    p.overhead /= rounds as f64;
    p
}

/// Sweep `intervals` through the seeded preemption lab (each point
/// averaged over `rounds` trace seeds — see [`averaged_lab`]) and return
/// `(best_interval, best_waste, per-interval points)` — waste being lost
/// work plus checkpoint overhead, the quantity Young/Daly minimizes.
/// This is the brute-force baseline the tuner's property tests and the
/// `campaign_sweep` bench validate the closed form against.
pub fn brute_force_optimal(
    ckpt_cost: SimTime,
    mtbf: SimTime,
    seed: u64,
    intervals: &[SimTime],
    rounds: u32,
) -> (SimTime, f64, Vec<SweepPoint>) {
    assert!(!intervals.is_empty(), "sweep needs at least one interval");
    let points: Vec<SweepPoint> = intervals
        .iter()
        .map(|&iv| averaged_lab(iv, ckpt_cost, mtbf, seed, rounds))
        .collect();
    let best = points
        .iter()
        .min_by(|a, b| a.waste.total_cmp(&b.waste))
        .expect("nonempty sweep");
    (best.interval, best.waste, points)
}

/// The default fixed-interval grid the sweeps and the `campaign_sweep`
/// bench walk (seconds, log-spaced around realistic HPC cadences).
pub const SWEEP_GRID: [SimTime; 8] = [30, 60, 120, 300, 600, 1_200, 2_400, 4_800];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{run_cases, Gen};

    #[test]
    fn formula_matches_closed_form() {
        // sqrt(2 * 10 * 2000) ≈ 200
        let iv = young_daly_interval_secs(10.0, 2_000.0);
        assert!((iv - 200.0).abs() < 1e-9, "{iv}");
        // Degenerate inputs stay sane.
        assert_eq!(young_daly_interval_secs(10.0, 0.0), 10.0);
        assert_eq!(young_daly_interval_secs(0.0, 1_000.0), 0.0);
    }

    #[test]
    fn interval_monotone_in_mtbf_and_cost() {
        run_cases("young-daly monotone", 200, |g: &mut Gen| {
            let c = g.f64_in(0.1, 120.0);
            let m1 = g.f64_in(10.0, 50_000.0);
            let m2 = m1 + g.f64_in(0.0, 50_000.0);
            assert!(
                young_daly_interval_secs(c, m1) <= young_daly_interval_secs(c, m2),
                "not monotone in MTBF: c={c} m1={m1} m2={m2}"
            );
            let c2 = c + g.f64_in(0.0, 120.0);
            let m = g.f64_in(10.0, 50_000.0);
            assert!(
                young_daly_interval_secs(c, m) <= young_daly_interval_secs(c2, m),
                "not monotone in cost: c={c} c2={c2} m={m}"
            );
        });
    }

    #[test]
    fn tuner_feedback_converges_to_measured_cost() {
        let mut t = DalyTuner::new(Duration::from_secs(2_000), Duration::from_secs(60));
        // Prior is far off; the first measurement replaces it.
        t.observe_cost(Duration::from_secs(10));
        assert!((t.cost_estimate().as_secs_f64() - 10.0).abs() < 1e-9);
        // A drifting cost pulls the estimate along.
        for _ in 0..40 {
            t.observe_cost(Duration::from_secs(20));
        }
        let c = t.cost_estimate().as_secs_f64();
        assert!((c - 20.0).abs() < 0.5, "cost EWMA stuck at {c}");
        let iv = t.interval().as_secs_f64();
        let want = young_daly_interval_secs(c, 2_000.0);
        assert!((iv - want).abs() < 1.0, "iv={iv} want={want}");
    }

    #[test]
    fn brute_force_returns_the_grid_minimum() {
        let (best_iv, best_waste, points) =
            brute_force_optimal(10, 2_000, 7, &[60, 600, 4_800], 2);
        assert_eq!(points.len(), 3);
        assert!(points
            .iter()
            .any(|p| p.interval == best_iv && p.waste == best_waste));
        assert!(points.iter().all(|p| p.waste >= best_waste));
        // Every point's accounting is internally consistent.
        for p in &points {
            assert!((p.lost + p.overhead - p.waste).abs() < 1e-6, "{p:?}");
        }
    }

    #[test]
    fn tuner_clamps() {
        let mut t = DalyTuner::new(Duration::from_secs(10_000), Duration::from_secs(1))
            .clamp(Duration::from_secs(5), Duration::from_secs(30));
        assert_eq!(t.interval(), Duration::from_secs(30), "hi clamp");
        t.observe_cost(Duration::from_millis(1));
        assert_eq!(t.interval(), Duration::from_secs(5), "lo clamp");
    }

    #[test]
    fn daly_within_tolerance_of_brute_force_on_sim_traces() {
        // The headline validation: on seeded slurm-sim renewal traces the
        // tuned interval's waste must land within tolerance of the
        // brute-force grid optimum, and strictly beat the worst fixed
        // choice (on both waste and lost work). Few cases — each runs a
        // full discrete-event sweep, 3 trace seeds per grid point.
        run_cases("daly vs brute force", 5, |g: &mut Gen| {
            // Costs stay below the grid's shortest interval (30 s): an
            // interval at or under the checkpoint cost cannot progress at
            // all, and that degenerate grid point would dominate the
            // sweep's runtime without informing the comparison. MTBF is
            // capped so every trace sees enough kills to measure.
            let cost = g.u64_in(5..25);
            let mtbf = g.u64_in(800..2_500);
            let seed = g.u64_in(1..1 << 40);
            let (_, best, sweep) = brute_force_optimal(cost, mtbf, seed, &SWEEP_GRID, 3);
            let daly_iv = young_daly_interval_secs(cost as f64, mtbf as f64).round() as SimTime;
            let daly = averaged_lab(daly_iv, cost, mtbf, seed, 3);
            let (daly_waste, daly_lost) = (daly.waste, daly.lost);
            let worst = sweep.iter().map(|p| p.waste).fold(0.0, f64::max);
            let worst_lost = sweep.iter().map(|p| p.lost).fold(0.0, f64::max);
            assert!(
                daly_waste < worst,
                "daly({daly_iv}s)={daly_waste} must beat the worst fixed interval ({worst}) \
                 [cost={cost} mtbf={mtbf} seed={seed}]"
            );
            assert!(
                daly_lost < worst_lost,
                "daly({daly_iv}s) lost {daly_lost}, worst fixed lost {worst_lost} \
                 [cost={cost} mtbf={mtbf} seed={seed}]"
            );
            // The waste curve is flat near its optimum (square-root
            // trade), so a generous multiplicative tolerance is the
            // robust check; the brute-force grid is itself discrete.
            // Margin validated by an offline model sweep: worst observed
            // averaged ratio ~1.14 over 60 randomized (cost, MTBF) draws.
            assert!(
                daly_waste <= best * 1.8 + 300.0,
                "daly({daly_iv}s)={daly_waste} too far above brute-force optimum ({best}) \
                 [cost={cost} mtbf={mtbf} seed={seed}]"
            );
        });
    }
}
