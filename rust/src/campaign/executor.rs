//! The fleet executor: a bounded worker pool driving many live
//! [`CrSession`]s concurrently, with seeded failure injection,
//! checkpoint-interval auto-tuning, and (since the `sched` subsystem) a
//! scheduler-driven dispatch loop: sessions enter through the spec's
//! arrival process and admission control, freed workers ask the
//! configured `dyn Scheduler` which request to run, checkpoint barriers
//! go through the fleet `BarrierPlacer` under the ckpt-aware policy,
//! and a `preempt_signal` walltime notice triggers one final
//! checkpoint plus an immediate requeue (DESIGN §12).
//!
//! Each worker owns one session at a time and drives it through the
//! manual (§V.B.2) strategy — submit, periodic `checkpoint_now` at the
//! cadence the [`IntervalPolicy`] dictates (measuring every checkpoint's
//! real cost and feeding it back to the [`DalyTuner`]), injected
//! `kill`/`resubmit_from_checkpoint` cycles from the
//! [`crate::campaign::faults::FaultPlan`], and
//! teardown. Coordinators bind ephemeral ports per incarnation, so any
//! concurrency level fits on one host; sessions either get per-session
//! workdirs or share one (nonce-scoped job ids and image discovery keep
//! them isolated; the content-addressed chunk store is then shared and
//! deduplicates across the fleet).
//!
//! The pool is cancellation-aware ([`CancelToken`]) and bounds every
//! session by the spec's straggler timeout: a fleet run always
//! terminates, and the [`CampaignReport`] says exactly how.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::campaign::faults::{FaultInjector, NodeFaults};
use crate::campaign::report::{CampaignReport, SessionDisposition, SessionOutcome};
use crate::campaign::sched::{
    AdmitOutcome, BarrierPlacer, BurstMeter, ReadyQueue, Scheduler, SchedulerKind, SessionRequest,
};
use crate::campaign::spec::{CampaignSpec, SubstrateSpec, WorkloadSpec};
use crate::campaign::tune::{DalyTuner, IntervalPolicy};
use crate::container::{Image, PodmanHpc, Registry, RunSpec, Shifter, EMBED_DMTCP_SNIPPET};
use crate::cr::{CoordinatorHandle, CrApp, CrSession, GangApp, GangSession, Substrate};
use crate::dmtcp::{CoordinatorDaemon, DaemonConfig};
use crate::error::{Error, Result};
use crate::util::rng::SplitMix64;
use crate::workload::{Cp2kApp, G4App, StencilApp};

/// Poll cadence of the per-session drive loop.
const POLL: Duration = Duration::from_millis(2);

/// Hard cap on preemption-notice cycles per session: a campaign must
/// terminate even if a session never fits inside one walltime.
const MAX_PREEMPT_CYCLES: u32 = 32;

/// Fleet-shared scheduling context: the checkpoint-barrier placer (only
/// for the ckpt-aware policy), the burst-collision meter wrapped around
/// every `checkpoint_now`, and the campaign epoch the placer's clock
/// runs on.
struct SchedCtx {
    placer: Option<BarrierPlacer>,
    meter: BurstMeter,
    epoch: Instant,
    /// Node-domain fault material, precomputed once per campaign: the
    /// seeded session→node placement and each node's shared kill
    /// schedule (`None` under the default session fault domain).
    node_faults: Option<NodeFaults>,
}

impl SchedCtx {
    fn for_spec(spec: &CampaignSpec, epoch: Instant) -> Self {
        SchedCtx {
            placer: (spec.scheduler == SchedulerKind::CkptAware).then(BarrierPlacer::new),
            meter: BurstMeter::new(),
            epoch,
            node_faults: spec.faults.node_faults(spec.seed),
        }
    }

    /// Where this session's next checkpoint barrier goes: the cadence
    /// interval from now, shifted by the fleet placer when one is
    /// active (ckpt-aware scheduling staggers bursts on the shared
    /// store).
    fn next_ckpt_at(&self, cadence: &Cadence) -> Instant {
        let interval = cadence.interval();
        match &self.placer {
            None => Instant::now() + interval,
            Some(placer) => {
                let now_s = self.epoch.elapsed().as_secs_f64();
                let cost_s = (cadence.measured_cost_ms().max(1) as f64) / 1_000.0;
                let at = placer.place(now_s, interval.as_secs_f64(), cost_s);
                self.epoch + Duration::from_secs_f64(at.max(now_s))
            }
        }
    }
}

/// Cooperative cancellation for a running campaign: clone the token,
/// hand it to [`run_fleet`], and flip it from any thread. Workers finish
/// their current poll step, tear their sessions down, and report
/// [`SessionDisposition::Cancelled`] for everything not yet complete.
#[derive(Debug, Clone, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
}

impl CancelToken {
    /// A fresh, uncancelled token.
    pub fn new() -> Self {
        Self::default()
    }

    /// Request cancellation (idempotent).
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::Relaxed);
    }

    /// Whether cancellation was requested.
    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::Relaxed)
    }
}

/// Run the campaign a spec describes, constructing its workload: the
/// CP2K-analog is self-contained; the Geant4-analog serves through the
/// shared compute service.
pub fn run_campaign(spec: &CampaignSpec) -> Result<CampaignReport> {
    run_campaign_cancellable(spec, &CancelToken::new())
}

/// [`run_campaign`] with an external [`CancelToken`].
pub fn run_campaign_cancellable(
    spec: &CampaignSpec,
    cancel: &CancelToken,
) -> Result<CampaignReport> {
    match spec.workload {
        WorkloadSpec::Cp2kScf { n } => {
            let app = Cp2kApp::new(n);
            run_fleet(spec, &app, cancel)
        }
        WorkloadSpec::Geant4 { kind, version } => {
            let h = crate::runtime::service::shared()?;
            let app = G4App::build(kind, version, h.manifest().grid_d);
            run_fleet(spec, &app, cancel)
        }
        WorkloadSpec::HaloStencil { cells_per_rank } => {
            // Each worker needs its own app instance: the fabric inside a
            // StencilApp is per-gang, and concurrent gangs must not share
            // a communication plane.
            run_gang_fleet(spec, cells_per_rank, cancel)
        }
    }
}

/// Drive a fleet of sessions of `app` per `spec` on a worker pool of
/// `spec.concurrency` threads. Session `i` runs with seed
/// `spec.seed.wrapping_add(i)` and the kill schedule derived from
/// `(spec.seed, i)`, so equal specs replay equal campaigns.
/// Orchestration failures are folded into per-session outcomes, not
/// bubbled: the returned report always covers every session.
pub fn run_fleet<A: CrApp + Sync>(
    spec: &CampaignSpec,
    app: &A,
    cancel: &CancelToken,
) -> Result<CampaignReport> {
    let coord = fleet_coordinator(spec)?;
    let report = run_session_pool(spec, "ncr_campaign", |i, root, ctx| {
        drive_session(app, spec, i, root, cancel, &coord, ctx)
    });
    if let CoordinatorHandle::Shared(daemon) = &coord {
        daemon.shutdown();
    }
    report
}

/// The fleet's coordinator plan: with `shared_coordinator` ONE
/// multi-tenant daemon serves every session's jobs over a single port
/// (O(1) coordinator threads for the whole fleet); otherwise each
/// incarnation boots a private coordinator as before.
fn fleet_coordinator(spec: &CampaignSpec) -> Result<CoordinatorHandle> {
    Ok(if spec.shared_coordinator {
        CoordinatorHandle::Shared(CoordinatorDaemon::start(DaemonConfig::default())?)
    } else {
        CoordinatorHandle::Private
    })
}

/// Shared dispatch state: the arrival cursor, the bounded ready queue,
/// and the pluggable policy choosing which admitted request a freed
/// worker runs next.
struct Dispatch {
    next_arrival: usize,
    queue: ReadyQueue,
    sched: Box<dyn Scheduler>,
}

/// What one dispatch tick told a worker to do.
enum Tick {
    /// Drive this request (dispatched at the given campaign second).
    Run(SessionRequest, f64),
    /// Nothing ready yet (arrivals pending or queue starved); poll.
    Idle,
    /// Every session is dispatched or rejected; the worker can exit.
    Done,
}

/// The bounded worker pool behind [`run_fleet`] and [`run_gang_fleet`]:
/// a `dyn Scheduler` tick loop over the spec's arrival process —
/// workers admit due arrivals into the bounded ready queue (rejections
/// become [`SessionDisposition::Rejected`] outcomes on the spot), ask
/// the policy which request to run, and drive it to completion.
/// `drive(index, root, ctx)` produces one session's outcome; the pool
/// fills every slot, so the returned report always covers every
/// session. The default spec (static arrival, FIFO, unbounded queue)
/// reproduces the old drain exactly: index order, all ready at `t = 0`.
///
/// Admission timing: arrivals meet the bounded queue when a worker next
/// polls for work, not at their nominal arrival instant — with every
/// worker busy, due arrivals accumulate and are offered in one burst at
/// the next free tick, so `admit_max` rejections under full load depend
/// on worker availability. Use the deterministic lab for studies where
/// exact arrival-time admission matters.
fn run_session_pool(
    spec: &CampaignSpec,
    root_tag: &str,
    drive: impl Fn(u32, &Path, &SchedCtx) -> SessionOutcome + Sync,
) -> Result<CampaignReport> {
    spec.validate()?;
    let root = match &spec.workdir {
        Some(p) => p.clone(),
        None => {
            // A wall clock reading before the Unix epoch (NTP step, VM
            // snapshot resume) must not abort the whole campaign over a
            // directory-name nonce: fall back to a zero offset and
            // leave a trace of the skew instead.
            let nanos = std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_nanos())
                .unwrap_or_else(|e| {
                    log::warn!(
                        "system clock reads before the Unix epoch ({e}); \
                         using a zero workdir-tag offset"
                    );
                    crate::trace::event(crate::trace::names::CLOCK_SKEW, |a| {
                        a.str("context", format!("workdir tag for {root_tag}"));
                    });
                    0
                });
            std::env::temp_dir().join(format!("{root_tag}_{}_{nanos}", std::process::id()))
        }
    };
    std::fs::create_dir_all(&root)?;
    let t0 = Instant::now();
    let ctx = SchedCtx::for_spec(spec, t0);
    let offsets = spec.arrival.arrival_offsets(spec.sessions, spec.seed);
    // Remaining-work and checkpoint-cost hints for cost-aware policies:
    // a uniform fleet ties everywhere, and ties dispatch in fleet order.
    let ckpt_cost_hint = match spec.interval {
        IntervalPolicy::Fixed(_) => 0.0,
        IntervalPolicy::Daly { cost_prior } => cost_prior.as_secs_f64(),
    };
    let dispatch = Mutex::new(Dispatch {
        next_arrival: 0,
        queue: ReadyQueue::new(spec.admit_max.map(|n| n as usize))?,
        sched: spec.scheduler.build(),
    });
    let outcomes: Mutex<Vec<Option<SessionOutcome>>> =
        Mutex::new((0..spec.sessions).map(|_| None).collect());
    let workers = spec.concurrency.min(spec.sessions).max(1);
    std::thread::scope(|sc| {
        for _ in 0..workers {
            sc.spawn(|| loop {
                let tick = {
                    // A panicking fleet-mate must not take the whole
                    // campaign down with a poisoned lock: the guarded
                    // state (arrival cursor, ready queue, outcome slots)
                    // is consistent between statements, so recover the
                    // inner value and keep dispatching.
                    let mut d = dispatch.lock().unwrap_or_else(|p| p.into_inner());
                    // Reborrow through the guard so `d.sched` and
                    // `d.queue` below are disjoint field borrows.
                    let d = &mut *d;
                    let now = ctx.epoch.elapsed().as_secs_f64();
                    // Admission control over everything that has
                    // arrived. Admission is lazy: arrivals are offered
                    // to the bounded queue when a worker next polls, so
                    // while every worker is busy, due arrivals batch up
                    // and rejection reflects the queue depth at that
                    // poll, not at each arrival's nominal instant. The
                    // lab (`sched/lab.rs`) admits on a per-second
                    // virtual clock and is the ground truth for
                    // arrival-time admission semantics.
                    while d.next_arrival < offsets.len() && offsets[d.next_arrival] <= now {
                        let i = d.next_arrival as u32;
                        d.next_arrival += 1;
                        let req = SessionRequest {
                            index: i,
                            arrival_secs: offsets[i as usize],
                            work_estimate_secs: spec.target_steps as f64,
                            ckpt_cost_secs: ckpt_cost_hint,
                        };
                        match d.queue.offer(req) {
                            AdmitOutcome::Rejected(reason) => {
                                crate::trace::event(crate::trace::names::SCHED_REJECT, |a| {
                                    a.u64("session", i as u64);
                                    a.str("reason", reason.label());
                                    a.f64("at_secs", now);
                                });
                                log::warn!("campaign session {i}: {reason}");
                                let mut o = SessionOutcome::unstarted(
                                    i,
                                    spec.seed.wrapping_add(i as u64),
                                    spec.ranks,
                                    spec.target_steps,
                                );
                                o.disposition = SessionDisposition::Rejected;
                                outcomes.lock().unwrap_or_else(|p| p.into_inner())
                                    [i as usize] = Some(o);
                            }
                            AdmitOutcome::Admitted => {
                                crate::trace::event(crate::trace::names::SCHED_ADMIT, |a| {
                                    a.u64("session", i as u64);
                                    a.f64("at_secs", now);
                                });
                            }
                        }
                    }
                    match d.sched.pick(&d.queue, now) {
                        Some(pos) => {
                            let req = d.queue.take(pos).expect("scheduler picked a live slot");
                            Tick::Run(req, now)
                        }
                        None if d.next_arrival >= offsets.len() && d.queue.is_empty() => Tick::Done,
                        None => Tick::Idle,
                    }
                };
                match tick {
                    Tick::Done => break,
                    Tick::Idle => std::thread::sleep(POLL),
                    Tick::Run(req, dispatched_at) => {
                        crate::trace::event(crate::trace::names::SCHED_DISPATCH, |a| {
                            a.u64("session", req.index as u64);
                            a.f64("at_secs", dispatched_at);
                            a.f64(
                                "queue_wait_secs",
                                (dispatched_at - req.arrival_secs).max(0.0),
                            );
                        });
                        // Contain a panicking drive to its own session:
                        // `thread::scope` would re-raise the panic at
                        // join and abort the whole campaign, so catch it
                        // here and fold it into a typed per-session
                        // failure instead. The drive owns no state that
                        // outlives the unwind (its session is dropped by
                        // it), so the assertion is sound.
                        let mut outcome = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| drive(req.index, &root, &ctx)),
                        )
                        .unwrap_or_else(|p| {
                            let msg = panic_message(p.as_ref());
                            log::warn!(
                                "campaign session {}: worker panicked: {msg}",
                                req.index
                            );
                            let mut o = SessionOutcome::unstarted(
                                req.index,
                                spec.seed.wrapping_add(req.index as u64),
                                spec.ranks,
                                spec.target_steps,
                            );
                            o.disposition = SessionDisposition::Failed(
                                Error::Campaign(format!("worker panicked: {msg}")).to_string(),
                            );
                            o
                        });
                        outcome.dispatched_at_secs = dispatched_at;
                        outcome.queue_wait_secs = (dispatched_at - req.arrival_secs).max(0.0);
                        outcomes.lock().unwrap_or_else(|p| p.into_inner())
                            [req.index as usize] = Some(outcome);
                    }
                }
            });
        }
    });
    let sessions = outcomes
        .into_inner()
        .unwrap_or_else(|p| p.into_inner())
        .into_iter()
        .enumerate()
        .map(|(i, o)| {
            // Every slot is normally filled; an empty one means its
            // worker died in a way even catch_unwind could not report
            // (e.g. a panic while the slot lock was held). Fail that
            // session, not the campaign.
            o.unwrap_or_else(|| {
                let mut o = SessionOutcome::unstarted(
                    i as u32,
                    spec.seed.wrapping_add(i as u64),
                    spec.ranks,
                    spec.target_steps,
                );
                o.disposition = SessionDisposition::Failed(
                    Error::Campaign("worker never filled the outcome slot".into()).to_string(),
                );
                o
            })
        })
        .collect();
    Ok(CampaignReport {
        name: spec.name.clone(),
        sessions,
        wall_secs: t0.elapsed().as_secs_f64(),
        burst_collisions: ctx.meter.collisions(),
    })
}

/// Build one session's execution environment (mirrors the robustness
/// matrix's container setup: DMTCP embedded, checkpoint volume mapped).
fn build_substrate(which: SubstrateSpec, session_wd: &Path) -> Result<Substrate> {
    if which == SubstrateSpec::Bare {
        return Ok(Substrate::bare());
    }
    let mut registry = Registry::new();
    registry.push(Image::base("my_application_container", "latest", 64 << 20));
    let mut pm = PodmanHpc::new();
    pm.build("campaign-cr", "v1", EMBED_DMTCP_SNIPPET, &registry)?;
    pm.migrate("campaign-cr:v1")?;
    let spec = RunSpec::default()
        .volume(session_wd.join("ckpt").to_string_lossy(), "/ckpt")
        .env("DMTCP_CHECKPOINT_DIR", "/ckpt");
    match which {
        SubstrateSpec::PodmanHpc => Ok(Substrate::container(pm.run("campaign-cr:v1", spec)?)),
        SubstrateSpec::Shifter => {
            pm.push(&mut registry, "campaign-cr:v1")?;
            let mut sh = Shifter::new();
            sh.pull(&registry, "campaign-cr:v1")?;
            Ok(Substrate::container(sh.run("campaign-cr:v1", spec)?))
        }
        SubstrateSpec::Bare => unreachable!("handled above"),
    }
}

/// The per-session interval source: a constant, or a live Daly tuner.
enum Cadence {
    Fixed(Duration),
    Daly(DalyTuner),
}

impl Cadence {
    fn for_spec(spec: &CampaignSpec) -> Self {
        match spec.interval {
            IntervalPolicy::Fixed(d) => Cadence::Fixed(d),
            IntervalPolicy::Daly { cost_prior } => {
                // Without a fault plan there is nothing to tune against;
                // an effectively-infinite MTBF pushes the interval to the
                // hi clamp (checkpoint rarely, as theory says to).
                let mtbf = spec
                    .faults
                    .mtbf
                    .unwrap_or(Duration::from_secs(30 * 24 * 3_600));
                Cadence::Daly(DalyTuner::new(mtbf, cost_prior).clamp(
                    Duration::from_millis(2),
                    // Guarantee several checkpoints fit before the
                    // straggler deadline would reap the session.
                    spec.straggler_timeout / 8,
                ))
            }
        }
    }

    fn interval(&self) -> Duration {
        match self {
            Cadence::Fixed(d) => *d,
            Cadence::Daly(t) => t.interval(),
        }
    }

    fn observe_cost(&mut self, measured: Duration) {
        if let Cadence::Daly(t) = self {
            t.observe_cost(measured);
        }
    }

    fn measured_cost_ms(&self) -> u64 {
        match self {
            Cadence::Fixed(_) => 0,
            Cadence::Daly(t) if t.observations() == 0 => 0,
            Cadence::Daly(t) => t.cost_estimate().as_millis() as u64,
        }
    }
}

/// Where a drive loop's kill instants come from. The session domain
/// draws an independent exponential schedule per session (the
/// pre-existing behavior); the node domain replays the session's *node*
/// schedule — absolute offsets from the campaign epoch that every
/// co-located session shares, so one node event fells them all in the
/// same tick.
enum KillSource<'a> {
    /// Independent per-session schedule.
    Session(&'a mut FaultInjector),
    /// Shared per-node schedule (campaign-epoch offsets, cumulative).
    Node {
        schedule: &'a [Duration],
        cursor: usize,
        epoch: Instant,
        node: u32,
    },
}

impl<'a> KillSource<'a> {
    fn new(injector: &'a mut FaultInjector, ctx: &'a SchedCtx, index: u32) -> Self {
        match &ctx.node_faults {
            Some(nf) => {
                let node = nf.map().node_of_session(index);
                KillSource::Node {
                    schedule: nf.schedule_for_session(index),
                    cursor: 0,
                    epoch: ctx.epoch,
                    node,
                }
            }
            None => KillSource::Session(injector),
        }
    }

    /// The node this source replays, `None` in the session domain.
    fn node(&self) -> Option<u32> {
        match self {
            KillSource::Session(_) => None,
            KillSource::Node { node, .. } => Some(*node),
        }
    }

    /// The next kill instant. For the node domain this first skips node
    /// events that fired before this session was dispatched (a session
    /// arriving late does not replay its node's history), and after an
    /// executed kill it collapses every event that elapsed while the
    /// session was down into the one kill that already happened — a
    /// dead session cannot die twice.
    fn arm(&mut self) -> Option<Instant> {
        match self {
            KillSource::Session(inj) => inj.next_kill_in().map(|d| Instant::now() + d),
            KillSource::Node {
                schedule,
                cursor,
                epoch,
                ..
            } => {
                let now = Instant::now();
                while *cursor < schedule.len() && *epoch + schedule[*cursor] <= now {
                    *cursor += 1;
                }
                schedule.get(*cursor).map(|d| *epoch + *d)
            }
        }
    }
}

/// Best-effort text of a panic payload (`&str` and `String` cover
/// essentially every real panic message).
fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".into()
    }
}

/// Flight dumps attributable to *this* session. In a shared workdir the
/// scan sees every fleet-mate's dumps, so the count is filtered by the
/// session's nonce-scoped job prefix (`…s<nonce>i` / `…g<nonce>i` —
/// the literal `i` terminator keeps one nonce from prefix-matching a
/// longer one). An empty prefix (the session never built) contributes
/// zero rather than claiming the whole directory.
fn flight_dumps_for(wd: &Path, job_prefix: &str) -> u32 {
    if job_prefix.is_empty() {
        return 0;
    }
    crate::trace::flight::scan(wd)
        .iter()
        .filter(|d| d.job.starts_with(job_prefix))
        .count() as u32
}

/// Fold the active coordinator's lifetime store totals into the outcome
/// (called once per incarnation, just before its teardown — coordinator
/// totals do not survive the incarnation).
fn harvest_store<A: CrApp>(out: &mut SessionOutcome, session: &CrSession<A>) {
    if let Ok(c) = session.coordinator() {
        let t = c.store_totals();
        out.stored_bytes += t.stored_bytes;
        out.logical_bytes += t.logical_bytes;
        out.chunks_written += t.chunks_written;
        out.chunks_deduped += t.chunks_deduped;
    }
}

/// Drive one session start to finish; every failure mode lands in the
/// outcome's disposition instead of unwinding the pool.
fn drive_session<A: CrApp>(
    app: &A,
    spec: &CampaignSpec,
    index: u32,
    root: &Path,
    cancel: &CancelToken,
    coord: &CoordinatorHandle,
    ctx: &SchedCtx,
) -> SessionOutcome {
    let seed = spec.seed.wrapping_add(index as u64);
    let wd: PathBuf = if spec.shared_workdir {
        root.to_path_buf()
    } else {
        root.join(format!("s{index:03}"))
    };
    let mut out = SessionOutcome::unstarted(index, seed, 1, spec.target_steps);
    let t0 = Instant::now();
    let mut cadence = Cadence::for_spec(spec);
    let mut injector = spec.faults.injector(spec.seed, index);

    // A cancellation that lands while this session is still queued must
    // not boot a whole stack (substrate, coordinator, workers) just to
    // tear it down one poll later.
    if cancel.is_cancelled() {
        out.disposition = SessionDisposition::Cancelled;
        out.final_interval_ms = cadence.interval().as_millis() as u64;
        return out;
    }

    let result = drive_session_inner(
        app, spec, seed, &wd, cancel, coord, ctx, &mut cadence, &mut injector, &mut out,
    );
    if let Err(e) = result {
        out.disposition = SessionDisposition::Failed(e.to_string());
        log::warn!("campaign session {index}: {e}");
    }
    // Flight dumps written under this session's workdir (failed barriers,
    // boot errors) — surfaced in the report so `nersc-cr trace` has a
    // reason to be pointed here. The scan is filtered by this session's
    // job prefix: under `shared_workdir` every fleet-mate dumps into the
    // same directory, and an unfiltered count would attribute the whole
    // fleet's dumps to every session.
    out.flight_dumps = flight_dumps_for(&wd, &out.job);
    out.final_interval_ms = cadence.interval().as_millis() as u64;
    out.measured_ckpt_cost_ms = cadence.measured_cost_ms();
    out.wall_secs = t0.elapsed().as_secs_f64();
    out
}

#[allow(clippy::too_many_arguments)]
fn drive_session_inner<A: CrApp>(
    app: &A,
    spec: &CampaignSpec,
    seed: u64,
    wd: &Path,
    cancel: &CancelToken,
    coord: &CoordinatorHandle,
    ctx: &SchedCtx,
    cadence: &mut Cadence,
    injector: &mut FaultInjector,
    out: &mut SessionOutcome,
) -> Result<()> {
    let substrate = build_substrate(spec.substrate, wd)?;
    let mut builder = CrSession::builder(app)
        .substrate(substrate)
        .workdir(wd)
        .target_steps(spec.target_steps)
        .seed(seed)
        .gc_grace(spec.gc_grace)
        .coordinator(coord.clone());
    if let Some(full_every) = spec.incremental {
        builder = builder.incremental_images(full_every).chunker(spec.chunker);
    }
    let mut session = builder.build()?;
    out.job = session.job_prefix();
    session.submit()?;

    // Without a preemption signal the straggler timeout is an absolute
    // deadline; with one it is the per-incarnation walltime the grace
    // notice fires against (`offset` seconds before the limit).
    let notice_offset = spec
        .preempt_signal
        .map(|(_, offset)| Duration::from_secs(offset));
    let mut deadline = Instant::now() + spec.straggler_timeout;
    let mut notice_at = notice_offset.map(|off| deadline - off);
    let mut next_ckpt = ctx.next_ckpt_at(cadence);
    let mut kills = KillSource::new(injector, ctx, out.index);
    let mut next_kill = kills.arm();
    let mut steps_at_ckpt = 0u64;

    let completed = loop {
        std::thread::sleep(POLL);
        let status = session.monitor()?;
        out.steps_done = status.steps_done;
        if status.done {
            break true;
        }
        if cancel.is_cancelled() {
            break false;
        }
        let now = Instant::now();
        if let Some(at) = notice_at {
            if now >= at {
                // SLURM grace notice: one final checkpoint when it is
                // strictly better than riding the cadence into the
                // kill (unsaved work exists, or no image at all), then
                // an immediate requeue into a fresh walltime.
                crate::trace::event(crate::trace::names::SCHED_PREEMPT_NOTICE, |a| {
                    a.u64("session", out.index as u64);
                    a.f64("at_secs", ctx.epoch.elapsed().as_secs_f64());
                });
                let at_notice = status.steps_done;
                let no_image = session.session_images()?.is_empty();
                if at_notice > steps_at_ckpt || no_image {
                    if let Some(placer) = &ctx.placer {
                        placer.place_final(
                            ctx.epoch.elapsed().as_secs_f64(),
                            (cadence.measured_cost_ms().max(1) as f64) / 1_000.0,
                        );
                    }
                    ctx.meter.begin();
                    let r = session.checkpoint_now();
                    ctx.meter.end();
                    match r {
                        Ok(_) => {
                            out.checkpoints += 1;
                            out.notice_ckpts += 1;
                            steps_at_ckpt = at_notice;
                        }
                        Err(e) => log::warn!(
                            "campaign session {}: notice checkpoint failed: {e}",
                            out.index
                        ),
                    }
                }
                if out.preempts >= MAX_PREEMPT_CYCLES || session.session_images()?.is_empty() {
                    // Cannot (or may no longer) restart: reap as a
                    // straggler rather than loop forever.
                    break false;
                }
                let at_kill = session.monitor()?.steps_done;
                harvest_store(out, &session);
                let t_kill = Instant::now();
                session.kill()?;
                out.preempts += 1;
                // The checkpoint-free counterfactual restarts from step
                // 0: this cycle would have cost its full progress.
                out.steps_lost_nockpt += at_kill;
                std::thread::sleep(spec.requeue_delay);
                let resumed = session.resubmit_from_checkpoint()?;
                let lat = t_kill.elapsed().as_secs_f64();
                out.restart_latencies_secs.push(lat);
                out.restart_events
                    .push((ctx.epoch.elapsed().as_secs_f64(), lat));
                out.steps_lost += at_kill.saturating_sub(resumed);
                steps_at_ckpt = resumed;
                deadline = Instant::now() + spec.straggler_timeout;
                notice_at = notice_offset.map(|off| deadline - off);
                next_ckpt = ctx.next_ckpt_at(cadence);
                continue;
            }
        } else if now > deadline {
            break false;
        }
        if now >= next_ckpt {
            let t = Instant::now();
            ctx.meter.begin();
            let r = session.checkpoint_now();
            ctx.meter.end();
            match r {
                Ok(_) => {
                    out.checkpoints += 1;
                    steps_at_ckpt = status.steps_done;
                    cadence.observe_cost(t.elapsed());
                }
                Err(e) => log::warn!("campaign session {}: checkpoint failed: {e}", out.index),
            }
            next_ckpt = ctx.next_ckpt_at(cadence);
        }
        if let Some(kill_at) = next_kill {
            if now >= kill_at {
                if session.session_images()?.is_empty() {
                    // Nothing to restart from yet: defer the kill past
                    // the next checkpoint (see campaign::faults docs).
                    // Node schedules keep their cursor, so the deferred
                    // event is still the same node event when it lands.
                    next_kill = Some(now + cadence.interval());
                } else {
                    let at_kill = session.monitor()?.steps_done;
                    harvest_store(out, &session);
                    let t_kill = Instant::now();
                    if let Some(node) = kills.node() {
                        out.node_kills += 1;
                        crate::trace::event(crate::trace::names::NODE_KILL, |a| {
                            a.u64("node", node as u64);
                            a.u64("session", out.index as u64);
                        });
                        crate::trace::flight::dump_for_job_in_domain(
                            &session.jobid(),
                            &format!("node {node} fault felled the session"),
                            &wd.join("ckpt"),
                            "node",
                        );
                    }
                    session.kill()?;
                    out.kills += 1;
                    // The checkpoint-free counterfactual restarts from
                    // step 0: each kill charges its full progress.
                    out.steps_lost_nockpt += at_kill;
                    std::thread::sleep(spec.requeue_delay);
                    let resumed = session.resubmit_from_checkpoint()?;
                    let lat = t_kill.elapsed().as_secs_f64();
                    out.restart_latencies_secs.push(lat);
                    out.restart_events
                        .push((ctx.epoch.elapsed().as_secs_f64(), lat));
                    out.steps_lost += at_kill.saturating_sub(resumed);
                    steps_at_ckpt = resumed;
                    next_kill = kills.arm();
                    next_ckpt = ctx.next_ckpt_at(cadence);
                }
            }
        }
    };

    out.corrupt_fallbacks = session.image_fallbacks();

    harvest_store(out, &session);
    // Assigned once (not accumulated per harvest): the session's phase
    // counters already span every restart of every incarnation.
    out.restore_phase_secs = session.restore_phase_secs();
    out.incarnations = session.incarnation() + 1;
    if completed {
        let final_state = session.final_state()?;
        session.finish();
        out.verified = app
            .verify_final(&final_state, spec.target_steps, seed)
            .is_ok();
        out.disposition = SessionDisposition::Completed;
    } else {
        session.finish();
        out.disposition = if cancel.is_cancelled() {
            SessionDisposition::Cancelled
        } else {
            SessionDisposition::Straggler
        };
    }
    out.series = session.series();
    Ok(())
}

/// Drive a fleet of `spec.sessions` *gangs* of `spec.ranks` halo-stencil
/// ranks each, on the same bounded pool, with the same seeding contract
/// as [`run_fleet`]. Each worker builds its own [`StencilApp`] — a gang's
/// fabric is private to it.
pub fn run_gang_fleet(
    spec: &CampaignSpec,
    cells_per_rank: usize,
    cancel: &CancelToken,
) -> Result<CampaignReport> {
    let coord = fleet_coordinator(spec)?;
    let report = run_session_pool(spec, "ncr_gangfleet", |i, root, ctx| {
        drive_gang(spec, cells_per_rank, i, root, cancel, &coord, ctx)
    });
    if let CoordinatorHandle::Shared(daemon) = &coord {
        daemon.shutdown();
    }
    report
}

/// Drive one gang start to finish; every failure mode lands in the
/// outcome's disposition, mirroring [`drive_session`].
fn drive_gang(
    spec: &CampaignSpec,
    cells_per_rank: usize,
    index: u32,
    root: &Path,
    cancel: &CancelToken,
    coord: &CoordinatorHandle,
    ctx: &SchedCtx,
) -> SessionOutcome {
    let seed = spec.seed.wrapping_add(index as u64);
    let wd: PathBuf = if spec.shared_workdir {
        root.to_path_buf()
    } else {
        root.join(format!("g{index:03}"))
    };
    let mut out = SessionOutcome::unstarted(index, seed, spec.ranks, spec.target_steps);
    let t0 = Instant::now();
    let mut cadence = Cadence::for_spec(spec);
    let mut injector = spec.faults.injector(spec.seed, index);
    if cancel.is_cancelled() {
        out.disposition = SessionDisposition::Cancelled;
        out.final_interval_ms = cadence.interval().as_millis() as u64;
        return out;
    }
    let result = drive_gang_inner(
        spec,
        cells_per_rank,
        seed,
        &wd,
        cancel,
        coord,
        ctx,
        &mut cadence,
        &mut injector,
        &mut out,
    );
    if let Err(e) = result {
        out.disposition = SessionDisposition::Failed(e.to_string());
        log::warn!("campaign gang {index}: {e}");
    }
    out.flight_dumps = flight_dumps_for(&wd, &out.job);
    out.final_interval_ms = cadence.interval().as_millis() as u64;
    out.measured_ckpt_cost_ms = cadence.measured_cost_ms();
    out.wall_secs = t0.elapsed().as_secs_f64();
    out
}

/// Fold the gang coordinator's store totals into the outcome (per
/// incarnation, before teardown — totals die with the coordinator).
fn harvest_gang_store<A: GangApp>(out: &mut SessionOutcome, session: &GangSession<A>) {
    if let Ok(c) = session.coordinator() {
        let t = c.store_totals();
        out.stored_bytes += t.stored_bytes;
        out.logical_bytes += t.logical_bytes;
        out.chunks_written += t.chunks_written;
        out.chunks_deduped += t.chunks_deduped;
    }
}

#[allow(clippy::too_many_arguments)]
fn drive_gang_inner(
    spec: &CampaignSpec,
    cells_per_rank: usize,
    seed: u64,
    wd: &Path,
    cancel: &CancelToken,
    coord: &CoordinatorHandle,
    ctx: &SchedCtx,
    cadence: &mut Cadence,
    injector: &mut FaultInjector,
    out: &mut SessionOutcome,
) -> Result<()> {
    let app = StencilApp::new(spec.ranks, cells_per_rank);
    let substrate = build_substrate(spec.substrate, wd)?;
    let mut builder = GangSession::builder(&app)
        .substrate(substrate)
        .workdir(wd)
        .target_steps(spec.target_steps)
        .seed(seed)
        .gc_grace(spec.gc_grace)
        .coordinator(coord.clone());
    if let Some(full_every) = spec.incremental {
        builder = builder.incremental_images(full_every).chunker(spec.chunker);
    }
    let mut session = builder.build()?;
    out.job = session.job_prefix();
    session.submit()?;

    // Which rank each injected fault lands on: seeded like the kill
    // schedule itself, so equal specs replay equal campaigns.
    let mut rank_rng = SplitMix64::new(spec.seed ^ (out.index as u64).rotate_left(23) ^ 0x6A16);

    let notice_offset = spec
        .preempt_signal
        .map(|(_, offset)| Duration::from_secs(offset));
    let mut deadline = Instant::now() + spec.straggler_timeout;
    let mut notice_at = notice_offset.map(|off| deadline - off);
    let mut next_ckpt = ctx.next_ckpt_at(cadence);
    let mut kills = KillSource::new(injector, ctx, out.index);
    let mut next_kill = kills.arm();
    let mut steps_at_ckpt = 0u64;

    let completed = loop {
        std::thread::sleep(POLL);
        let status = session.monitor()?;
        out.steps_done = status.steps_done;
        if status.done {
            break true;
        }
        if cancel.is_cancelled() {
            break false;
        }
        let now = Instant::now();
        if let Some(at) = notice_at {
            if now >= at {
                // Grace notice for the whole gang: one final
                // coordinated checkpoint if strictly better, then an
                // immediate gang requeue into a fresh walltime.
                crate::trace::event(crate::trace::names::SCHED_PREEMPT_NOTICE, |a| {
                    a.u64("session", out.index as u64);
                    a.f64("at_secs", ctx.epoch.elapsed().as_secs_f64());
                });
                let at_notice = status.steps_done;
                let no_image = session.latest_checkpoint()?.is_none();
                if at_notice > steps_at_ckpt || no_image {
                    if let Some(placer) = &ctx.placer {
                        placer.place_final(
                            ctx.epoch.elapsed().as_secs_f64(),
                            (cadence.measured_cost_ms().max(1) as f64) / 1_000.0,
                        );
                    }
                    ctx.meter.begin();
                    let r = session.checkpoint_now();
                    ctx.meter.end();
                    match r {
                        Ok(_) => {
                            out.checkpoints += 1;
                            out.notice_ckpts += 1;
                            steps_at_ckpt = at_notice;
                        }
                        Err(e) => log::warn!(
                            "campaign gang {}: notice checkpoint failed: {e}",
                            out.index
                        ),
                    }
                }
                if out.preempts >= MAX_PREEMPT_CYCLES || session.latest_checkpoint()?.is_none() {
                    break false;
                }
                let at_kill = session.monitor()?.steps_done;
                harvest_gang_store(out, &session);
                let t_kill = Instant::now();
                session.kill()?;
                out.preempts += 1;
                // The checkpoint-free counterfactual restarts from step
                // 0: this cycle would have cost its full progress.
                out.steps_lost_nockpt += at_kill;
                std::thread::sleep(spec.requeue_delay);
                let resumed = session.resubmit_from_checkpoint()?;
                let lat = t_kill.elapsed().as_secs_f64();
                out.restart_latencies_secs.push(lat);
                out.restart_events
                    .push((ctx.epoch.elapsed().as_secs_f64(), lat));
                out.steps_lost += at_kill.saturating_sub(resumed);
                steps_at_ckpt = resumed;
                deadline = Instant::now() + spec.straggler_timeout;
                notice_at = notice_offset.map(|off| deadline - off);
                next_ckpt = ctx.next_ckpt_at(cadence);
                continue;
            }
        } else if now > deadline {
            break false;
        }
        if now >= next_ckpt {
            let t = Instant::now();
            ctx.meter.begin();
            let r = session.checkpoint_now();
            ctx.meter.end();
            match r {
                Ok(_) => {
                    out.checkpoints += 1;
                    steps_at_ckpt = status.steps_done;
                    cadence.observe_cost(t.elapsed());
                }
                Err(e) => log::warn!("campaign gang {}: checkpoint failed: {e}", out.index),
            }
            next_ckpt = ctx.next_ckpt_at(cadence);
        }
        if let Some(kill_at) = next_kill {
            if now >= kill_at {
                if session.latest_checkpoint()?.is_none() {
                    // Nothing to gang-restart from yet: defer the kill.
                    next_kill = Some(now + cadence.interval());
                } else {
                    let at_kill = session.monitor()?.steps_done;
                    // Losing one rank aborts the generation: the whole
                    // gang is torn down and restarted from the last cut.
                    // A node event fells every rank co-located on the
                    // felled node in the same tick (possibly none — the
                    // gang still loses its node-resident coordinator);
                    // the session domain picks one seeded victim.
                    match kills.node() {
                        Some(node) => {
                            let map = ctx
                                .node_faults
                                .as_ref()
                                .expect("node kill source implies node faults")
                                .map();
                            let victims: Vec<u32> = (0..spec.ranks)
                                .filter(|&r| map.node_of_rank(out.index, r) == node)
                                .collect();
                            for &v in &victims {
                                session.kill_rank(v)?;
                            }
                            out.node_kills += 1;
                            crate::trace::event(crate::trace::names::NODE_KILL, |a| {
                                a.u64("node", node as u64);
                                a.u64("session", out.index as u64);
                            });
                            crate::trace::flight::dump_for_job_in_domain(
                                &session.jobid(),
                                &format!(
                                    "node {node} fault felled ranks {victims:?} of the gang"
                                ),
                                &wd.join("ckpt"),
                                "node",
                            );
                        }
                        None => {
                            let victim = rank_rng.gen_range(spec.ranks as u64) as u32;
                            session.kill_rank(victim)?;
                        }
                    }
                    harvest_gang_store(out, &session);
                    let t_kill = Instant::now();
                    session.kill()?;
                    out.kills += 1;
                    // The checkpoint-free counterfactual restarts from
                    // step 0: each kill charges its full progress.
                    out.steps_lost_nockpt += at_kill;
                    std::thread::sleep(spec.requeue_delay);
                    let resumed = session.resubmit_from_checkpoint()?;
                    let lat = t_kill.elapsed().as_secs_f64();
                    out.restart_latencies_secs.push(lat);
                    out.restart_events
                        .push((ctx.epoch.elapsed().as_secs_f64(), lat));
                    out.steps_lost += at_kill.saturating_sub(resumed);
                    steps_at_ckpt = resumed;
                    next_kill = kills.arm();
                    next_ckpt = ctx.next_ckpt_at(cadence);
                }
            }
        }
    };

    out.corrupt_fallbacks = session.manifest_fallbacks();

    harvest_gang_store(out, &session);
    // Assigned once, like the single-process driver: the counters span
    // every rank restart of every incarnation.
    out.restore_phase_secs = session.restore_phase_secs();
    out.incarnations = session.generation() + 1;
    if completed {
        let finals = session.final_states()?;
        session.finish();
        out.verified = session.verify_final(&finals).is_ok();
        out.disposition = SessionDisposition::Completed;
    } else {
        session.finish();
        out.disposition = if cancel.is_cancelled() {
            SessionDisposition::Cancelled
        } else {
            SessionDisposition::Straggler
        };
    }
    out.series = session.series();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::faults::FaultPlan;

    fn test_workdir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ncr_exec_{tag}_{}", std::process::id()))
    }

    #[test]
    fn cancel_token_flips_once_for_all_clones() {
        let t = CancelToken::new();
        let u = t.clone();
        assert!(!u.is_cancelled());
        t.cancel();
        assert!(u.is_cancelled());
    }

    #[test]
    fn small_bare_fleet_completes_and_verifies() {
        let wd = test_workdir("small");
        let spec = CampaignSpec {
            name: "unit".into(),
            sessions: 3,
            concurrency: 2,
            target_steps: 300,
            seed: 1_000,
            workdir: Some(wd.clone()),
            faults: FaultPlan::exponential(Duration::from_millis(25), 1),
            interval: IntervalPolicy::Fixed(Duration::from_millis(10)),
            straggler_timeout: Duration::from_secs(120),
            ..Default::default()
        };
        let report = run_campaign(&spec).unwrap();
        assert_eq!(report.sessions.len(), 3);
        for s in &report.sessions {
            assert_eq!(
                s.disposition,
                SessionDisposition::Completed,
                "s{}: {:?}",
                s.index,
                s.disposition
            );
            assert!(s.verified, "s{} diverged", s.index);
            assert!(s.checkpoints > 0, "s{} never checkpointed", s.index);
        }
        assert!(report.availability() > 0.0);
        std::fs::remove_dir_all(&wd).ok();
    }

    #[test]
    fn cancellation_stops_the_fleet_early() {
        let wd = test_workdir("cancel");
        let spec = CampaignSpec {
            name: "cancel".into(),
            sessions: 4,
            concurrency: 2,
            // Far more work than the test allows to finish.
            target_steps: 2_000_000,
            seed: 2_000,
            workdir: Some(wd.clone()),
            straggler_timeout: Duration::from_secs(600),
            ..Default::default()
        };
        let cancel = CancelToken::new();
        let killer = cancel.clone();
        std::thread::scope(|sc| {
            sc.spawn(move || {
                std::thread::sleep(Duration::from_millis(120));
                killer.cancel();
            });
            let report = run_campaign_cancellable(&spec, &cancel).unwrap();
            assert_eq!(report.sessions.len(), 4);
            assert!(
                report
                    .sessions
                    .iter()
                    .all(|s| s.disposition == SessionDisposition::Cancelled),
                "{:?}",
                report
                    .sessions
                    .iter()
                    .map(|s| s.disposition.clone())
                    .collect::<Vec<_>>()
            );
        });
        std::fs::remove_dir_all(&wd).ok();
    }

    #[test]
    fn straggler_timeout_reaps_unfinishable_sessions() {
        let wd = test_workdir("straggler");
        let spec = CampaignSpec {
            name: "straggler".into(),
            sessions: 1,
            concurrency: 1,
            target_steps: 2_000_000,
            seed: 3_000,
            workdir: Some(wd.clone()),
            straggler_timeout: Duration::from_millis(150),
            ..Default::default()
        };
        let report = run_campaign(&spec).unwrap();
        assert_eq!(report.sessions.len(), 1);
        assert_eq!(report.sessions[0].disposition, SessionDisposition::Straggler);
        assert!(report.completed() == 0);
        std::fs::remove_dir_all(&wd).ok();
    }
}
