//! Fleet-scale C/R campaign orchestration (L4).
//!
//! The paper's operational case (§II, §V) is not one job but *campaigns*:
//! fleets of long-running preemptable computations whose efficiency is
//! set by the checkpoint cadence versus the failure/preemption rate. This
//! subsystem connects the repo's two halves — it drives many *real*
//! [`crate::cr::session::CrSession`]s concurrently (the live stack:
//! coordinators on ephemeral ports, checkpoint images on disk, bare or
//! containerized) and chooses the checkpoint interval with the same
//! Young/Daly analysis it validates by brute force on the [`crate::slurm`]
//! simulator.
//!
//! * [`spec`] — the declarative [`CampaignSpec`] (N sessions × workload ×
//!   substrate × policy, seeded), parseable from `key = value` text for
//!   `nersc-cr campaign`.
//! * [`executor`] — the bounded worker pool ([`run_campaign`],
//!   [`run_fleet`], and [`run_gang_fleet`] for multi-rank gang sessions)
//!   with cancellation and straggler timeouts.
//! * [`faults`] — the seeded MTBF kill injector driving the §V.B.2
//!   `kill`/`resubmit_from_checkpoint` path.
//! * [`tune`] — the Young/Daly interval policy with measured-cost
//!   feedback ([`DalyTuner`]), validated against brute-force sweeps.
//! * [`sim`] — the seeded fleet harness on the scheduler simulator the
//!   sweeps, the `campaign_sweep` bench and the `preemptible_queue`
//!   example share.
//! * [`report`] — per-session outcomes aggregated into a
//!   [`CampaignReport`] (tables, JSON, LDMS rollups).
//! * [`sched`] — checkpoint-aware fleet scheduling: seeded arrival/size
//!   models, bounded-queue admission control with pluggable policies,
//!   and the barrier placer that staggers checkpoint bursts and heeds
//!   SLURM preemption notices (DESIGN §12).

#![deny(missing_docs)]

pub mod executor;
pub mod faults;
pub mod report;
pub mod sched;
pub mod sim;
pub mod spec;
pub mod tune;

pub use executor::{run_campaign, run_campaign_cancellable, run_fleet, run_gang_fleet, CancelToken};
pub use faults::{
    CorruptionEvent, CorruptionKind, FaultDomain, FaultInjector, FaultPlan, NodeFaults, NodeMap,
    StoreCorruptor,
};
pub use report::{CampaignReport, LdmsRollup, SessionDisposition, SessionOutcome};
pub use sched::{
    run_lab, ArrivalSpec, BarrierPlacer, BurstMeter, LabOutcome, LabSpec, RandomVariable,
    ReadyQueue, Scheduler, SchedulerKind, SessionRequest,
};
pub use sim::{run_fleet_sim, SimFleetOutcome, SimFleetSpec, UrgentLoad};
pub use spec::{CampaignSpec, SubstrateSpec, WorkloadSpec};
pub use tune::{
    averaged_lab, brute_force_optimal, young_daly_interval_secs, DalyTuner, IntervalPolicy,
    SweepPoint, SWEEP_GRID,
};
