//! Seeded failure injection for live campaigns.
//!
//! A campaign's efficiency question only exists because sessions die:
//! preemption by higher-priority work, node failure, walltime eviction.
//! This module turns that into a reproducible experiment — a [`FaultPlan`]
//! describes the failure process (exponential inter-kill times around an
//! MTBF, the classic renewal model behind Young/Daly), and mints one
//! deterministic [`FaultInjector`] per session from `(campaign seed,
//! session index)`, so the same spec replays the same kill schedule.
//!
//! The executor applies a kill through the session's own operator path —
//! [`crate::cr::session::CrSession::kill`] followed by
//! [`crate::cr::session::CrSession::resubmit_from_checkpoint`] — which is
//! exactly the §V.B.2 flow, bare or containerized. Kills are *deferred*
//! until the session has at least one checkpoint image: a session killed
//! before its first checkpoint has nothing to restart from (the
//! real-world analog is a job failing before `dmtcp_command --checkpoint`
//! ever ran, which simply reruns from scratch — a case the session API
//! models as a fresh submission, not a restart).
//!
//! Real outages are *correlated*, though, not independent (DESIGN §9):
//! a node dies and takes every rank and session placed on it, and a
//! filesystem hiccup damages many chunks of a shared store at once. The
//! correlated half of the model lives here too:
//!
//! - [`FaultDomain::Node`] + [`NodeMap`] + [`NodeFaults`]: sessions and
//!   gang ranks are deterministically placed on `nodes` simulated nodes,
//!   and each *node* draws one absolute kill timeline — every session and
//!   rank co-located on a node observes the same event at the same
//!   offset, so they fall in the same tick.
//! - [`StoreCorruptor`]: a seeded fleet-scale corruptor that flips bytes,
//!   truncates, or deletes chunk files of a shared content-addressed
//!   store between rounds; restores over damaged chunks must surface
//!   typed [`crate::error::Error::Corrupt`] and fall back (never panic).

use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::error::{Error, Result};
use crate::util::rng::SplitMix64;

/// Which correlation domain injected kills strike.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultDomain {
    /// Independent per-session kills (the classic renewal model).
    Session,
    /// Node-scoped kills: the campaign's sessions and gang ranks are
    /// placed on `nodes` simulated nodes, and one kill event fells every
    /// co-located session and rank in the same tick.
    Node {
        /// Number of simulated nodes in the fleet (≥ 1).
        nodes: u32,
    },
}

/// The failure process of one campaign, applied per session (or, in the
/// node domain, per simulated node).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    /// Mean time between injected kills per session (`None` = no faults).
    pub mtbf: Option<Duration>,
    /// Stop injecting after this many kills per session (bounds the
    /// incarnation count so a short straggler timeout stays meaningful).
    /// In the node domain: at most this many kill events per node.
    pub max_kills_per_session: u32,
    /// Which correlation domain kill events strike (default: independent
    /// per-session kills).
    pub domain: FaultDomain,
}

impl FaultPlan {
    /// A plan that never kills anything.
    pub fn none() -> Self {
        Self {
            mtbf: None,
            max_kills_per_session: 0,
            domain: FaultDomain::Session,
        }
    }

    /// Exponential kills around `mtbf`, at most `max_kills` per session.
    pub fn exponential(mtbf: Duration, max_kills: u32) -> Self {
        Self {
            mtbf: Some(mtbf),
            max_kills_per_session: max_kills,
            domain: FaultDomain::Session,
        }
    }

    /// Node-scoped exponential kills: `nodes` simulated nodes each draw
    /// their own kill timeline around `mtbf` (at most `max_kills` events
    /// per node), and every co-located session/rank dies together.
    pub fn node_scoped(mtbf: Duration, max_kills: u32, nodes: u32) -> Self {
        Self {
            mtbf: Some(mtbf),
            max_kills_per_session: max_kills,
            domain: FaultDomain::Node { nodes },
        }
    }

    /// Mint the deterministic injector for one session of the campaign.
    /// Equal `(campaign_seed, session_index)` pairs yield equal kill
    /// schedules.
    pub fn injector(&self, campaign_seed: u64, session_index: u32) -> FaultInjector {
        // Decorrelate per-session streams the same way SplitMix64::fork
        // does, but keyed so the schedule survives executor reordering.
        let seed = campaign_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(0xFAu64 << 32)
            .wrapping_add(session_index as u64);
        FaultInjector {
            rng: SplitMix64::new(seed),
            mtbf: self.mtbf,
            kills_left: self.max_kills_per_session,
        }
    }

    /// Precompute the fleet's node kill timelines, or `None` when the
    /// plan is not node-scoped (or fault-free).
    pub fn node_faults(&self, campaign_seed: u64) -> Option<NodeFaults> {
        let FaultDomain::Node { nodes } = self.domain else {
            return None;
        };
        let mtbf = self.mtbf?;
        Some(NodeFaults::new(
            campaign_seed,
            nodes.max(1),
            mtbf,
            self.max_kills_per_session,
        ))
    }
}

/// Per-session kill schedule generator (see [`FaultPlan::injector`]).
#[derive(Debug, Clone)]
pub struct FaultInjector {
    rng: SplitMix64,
    mtbf: Option<Duration>,
    kills_left: u32,
}

impl FaultInjector {
    /// Draw the delay from now until the next injected kill, consuming
    /// one kill from the budget. `None` once the plan is exhausted (or
    /// was fault-free to begin with).
    pub fn next_kill_in(&mut self) -> Option<Duration> {
        let mtbf = self.mtbf?;
        if self.kills_left == 0 {
            return None;
        }
        self.kills_left -= 1;
        let secs = self.rng.gen_exp(mtbf.as_secs_f64());
        Some(Duration::from_secs_f64(secs))
    }

    /// Kills still available in this session's budget.
    pub fn kills_left(&self) -> u32 {
        self.kills_left
    }
}

/// Deterministic placement of sessions and gang ranks onto simulated
/// nodes. Equal `(campaign_seed, nodes)` pairs place identically, so a
/// spec replays the same co-location pattern.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeMap {
    seed: u64,
    nodes: u32,
}

impl NodeMap {
    /// Build the placement for a fleet of `nodes` simulated nodes.
    pub fn new(campaign_seed: u64, nodes: u32) -> Self {
        Self {
            seed: campaign_seed,
            nodes: nodes.max(1),
        }
    }

    /// Number of simulated nodes.
    pub fn nodes(&self) -> u32 {
        self.nodes
    }

    fn place(&self, tag: u64, a: u64, b: u64) -> u32 {
        let mixed = self
            .seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(tag << 48)
            .wrapping_add(a.wrapping_mul(0x2545_F491_4F6C_DD1D))
            .wrapping_add(b);
        (SplitMix64::new(mixed).next_u64() % self.nodes as u64) as u32
    }

    /// The node a single-process session runs on.
    pub fn node_of_session(&self, session_index: u32) -> u32 {
        self.place(0x5E, session_index as u64, 0)
    }

    /// The node one rank of a gang session runs on (gang ranks spread
    /// over nodes, so a node event fells a *subset* of the gang).
    pub fn node_of_rank(&self, session_index: u32, rank: u32) -> u32 {
        self.place(0x4A, session_index as u64, rank as u64 + 1)
    }

    /// Every co-located session of a fleet of `n_sessions`, grouped as
    /// `(node, session indices)` — diagnostic/report helper.
    pub fn colocated_sessions(&self, n_sessions: u32) -> Vec<(u32, Vec<u32>)> {
        let mut groups: Vec<Vec<u32>> = vec![Vec::new(); self.nodes as usize];
        for s in 0..n_sessions {
            groups[self.node_of_session(s) as usize].push(s);
        }
        groups
            .into_iter()
            .enumerate()
            .filter(|(_, g)| !g.is_empty())
            .map(|(n, g)| (n as u32, g))
            .collect()
    }
}

/// The fleet's precomputed node kill timelines: one absolute schedule
/// (offsets from the campaign epoch, cumulative) per simulated node.
/// Everything placed on a node observes the *same* events, which is what
/// makes node kills correlated — co-located sessions fall in the same
/// tick, not merely at the same rate.
#[derive(Debug, Clone)]
pub struct NodeFaults {
    map: NodeMap,
    schedules: Vec<Vec<Duration>>,
}

impl NodeFaults {
    fn new(campaign_seed: u64, nodes: u32, mtbf: Duration, max_kills: u32) -> Self {
        let map = NodeMap::new(campaign_seed, nodes);
        let schedules = (0..nodes)
            .map(|node| {
                let seed = campaign_seed
                    .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    .wrapping_add(0x4E0Du64)
                    .wrapping_add((node as u64) << 8);
                let mut rng = SplitMix64::new(seed);
                let mut at = 0.0f64;
                (0..max_kills)
                    .map(|_| {
                        at += rng.gen_exp(mtbf.as_secs_f64());
                        Duration::from_secs_f64(at)
                    })
                    .collect()
            })
            .collect();
        Self { map, schedules }
    }

    /// The placement behind these timelines.
    pub fn map(&self) -> &NodeMap {
        &self.map
    }

    /// The absolute kill schedule (offsets from the campaign epoch,
    /// strictly increasing) of one node.
    pub fn schedule(&self, node: u32) -> &[Duration] {
        &self.schedules[node as usize % self.schedules.len()]
    }

    /// The kill schedule observed by a single-process session — the
    /// schedule of the node it is placed on.
    pub fn schedule_for_session(&self, session_index: u32) -> &[Duration] {
        self.schedule(self.map.node_of_session(session_index))
    }
}

/// How a [`StoreCorruptor`] strike damaged one chunk file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CorruptionKind {
    /// One payload byte XOR-flipped in place (magic intact: survives the
    /// store's write-time self-heal probe and is only caught by the
    /// restore-time CRC).
    FlipByte,
    /// File truncated below its payload length.
    Truncate,
    /// File deleted outright.
    Delete,
}

impl CorruptionKind {
    /// Stable lowercase label (for trace attrs and reports).
    pub fn label(&self) -> &'static str {
        match self {
            CorruptionKind::FlipByte => "flip_byte",
            CorruptionKind::Truncate => "truncate",
            CorruptionKind::Delete => "delete",
        }
    }
}

/// One chunk file damaged by a corruptor strike.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CorruptionEvent {
    /// The damaged chunk file.
    pub path: PathBuf,
    /// What was done to it.
    pub kind: CorruptionKind,
}

/// Chunk files begin with an 8-byte magic and a flag byte; flipping at or
/// past this offset hits payload bytes, which write-time self-healing
/// (magic probe only) cannot see.
const CHUNK_HEADER: u64 = 9;

/// A seeded fleet-scale chunk-store corruptor: one `strike` damages many
/// chunk files of a shared store in a single correlated event (the
/// filesystem-hiccup analog of a node kill). Deterministic per seed.
#[derive(Debug, Clone)]
pub struct StoreCorruptor {
    rng: SplitMix64,
}

impl StoreCorruptor {
    /// Build a corruptor replaying the same damage for the same seed.
    pub fn new(seed: u64) -> Self {
        Self {
            rng: SplitMix64::new(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(0xC0)),
        }
    }

    /// Damage up to `victims` distinct chunk files under `store_root` in
    /// one correlated event. Returns what was hit (possibly fewer than
    /// requested when the store is small). Errors only on I/O failures
    /// damaging a file; an absent or empty store yields an empty event
    /// list.
    pub fn strike(&mut self, store_root: &Path, victims: usize) -> Result<Vec<CorruptionEvent>> {
        let chunks = chunk_files(store_root)?;
        if chunks.is_empty() || victims == 0 {
            return Ok(Vec::new());
        }
        // Seeded distinct victim picks, order-stable over the sorted list.
        let mut picked: Vec<usize> = Vec::new();
        let wanted = victims.min(chunks.len());
        while picked.len() < wanted {
            let i = self.rng.gen_range(chunks.len() as u64) as usize;
            if !picked.contains(&i) {
                picked.push(i);
            }
        }
        let targets: Vec<PathBuf> = picked.into_iter().map(|i| chunks[i].clone()).collect();
        self.strike_paths(&targets)
    }

    /// Damage exactly the given chunk files in one correlated event (the
    /// targeted form the torture suites use to hit a known generation's
    /// chunks). Missing files are skipped.
    pub fn strike_paths(&mut self, paths: &[PathBuf]) -> Result<Vec<CorruptionEvent>> {
        let mut events = Vec::new();
        for path in paths {
            let len = match std::fs::metadata(path) {
                Ok(m) => m.len(),
                Err(_) => continue, // raced with GC — nothing to damage
            };
            let kind = match self.rng.gen_range(3) {
                0 if len > CHUNK_HEADER => CorruptionKind::FlipByte,
                1 if len > CHUNK_HEADER => CorruptionKind::Truncate,
                _ => CorruptionKind::Delete,
            };
            match kind {
                CorruptionKind::FlipByte => {
                    let mut bytes = std::fs::read(path)?;
                    let off =
                        (CHUNK_HEADER + self.rng.gen_range(len - CHUNK_HEADER)) as usize;
                    bytes[off] ^= 0xA5;
                    std::fs::write(path, bytes)?;
                }
                CorruptionKind::Truncate => {
                    let keep = self.rng.gen_range(CHUNK_HEADER);
                    let f = std::fs::OpenOptions::new().write(true).open(path)?;
                    f.set_len(keep)?;
                }
                CorruptionKind::Delete => {
                    std::fs::remove_file(path)?;
                }
            }
            crate::trace::event(crate::trace::names::FAULT_CORRUPT, |a| {
                a.str("chunk", path.display().to_string());
                a.str("kind", kind.label());
            });
            events.push(CorruptionEvent {
                path: path.clone(),
                kind,
            });
        }
        if events.is_empty() && !paths.is_empty() {
            return Err(Error::Corrupt(
                "corruptor strike matched no existing chunk files".into(),
            ));
        }
        Ok(events)
    }
}

/// All `*.chunk` files under a store root (2-hex fan-out), sorted by path
/// so victim picks are stable across platforms. Temp files are skipped.
fn chunk_files(store_root: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let buckets = match std::fs::read_dir(store_root) {
        Ok(rd) => rd,
        Err(_) => return Ok(out), // no store yet — nothing to corrupt
    };
    for bucket in buckets.flatten() {
        let p = bucket.path();
        if !p.is_dir() {
            continue;
        }
        for entry in std::fs::read_dir(&p)?.flatten() {
            let f = entry.path();
            if f.extension().and_then(|e| e.to_str()) == Some("chunk") {
                out.push(f);
            }
        }
    }
    out.sort();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_plan_never_kills() {
        let mut inj = FaultPlan::none().injector(7, 0);
        assert_eq!(inj.next_kill_in(), None);
    }

    #[test]
    fn injector_is_deterministic_per_seed_and_index() {
        let plan = FaultPlan::exponential(Duration::from_millis(100), 4);
        let mut a = plan.injector(42, 3);
        let mut b = plan.injector(42, 3);
        for _ in 0..4 {
            assert_eq!(a.next_kill_in(), b.next_kill_in());
        }
        assert_eq!(a.next_kill_in(), None, "budget of 4 exhausted");
    }

    #[test]
    fn sessions_get_distinct_schedules() {
        let plan = FaultPlan::exponential(Duration::from_millis(100), 1);
        let mut a = plan.injector(42, 0);
        let mut b = plan.injector(42, 1);
        assert_ne!(a.next_kill_in(), b.next_kill_in());
    }

    #[test]
    fn draws_cluster_around_mtbf() {
        let plan = FaultPlan::exponential(Duration::from_secs(10), u32::MAX);
        let mut inj = plan.injector(9, 0);
        let n = 4_000;
        let mean: f64 = (0..n)
            .map(|_| inj.next_kill_in().unwrap().as_secs_f64())
            .sum::<f64>()
            / n as f64;
        assert!((mean - 10.0).abs() < 0.6, "mean={mean}");
    }

    #[test]
    fn node_map_is_deterministic_and_in_range() {
        let a = NodeMap::new(42, 4);
        let b = NodeMap::new(42, 4);
        for s in 0..64 {
            assert_eq!(a.node_of_session(s), b.node_of_session(s));
            assert!(a.node_of_session(s) < 4);
            for r in 0..8 {
                assert_eq!(a.node_of_rank(s, r), b.node_of_rank(s, r));
                assert!(a.node_of_rank(s, r) < 4);
            }
        }
    }

    #[test]
    fn node_map_spreads_sessions() {
        let m = NodeMap::new(7, 4);
        let groups = m.colocated_sessions(64);
        assert!(groups.len() > 1, "64 sessions all landed on one node");
        let total: usize = groups.iter().map(|(_, g)| g.len()).sum();
        assert_eq!(total, 64);
    }

    #[test]
    fn node_schedules_are_shared_by_colocated_sessions() {
        let plan = FaultPlan::node_scoped(Duration::from_millis(50), 3, 2);
        let nf = plan.node_faults(42).expect("node domain");
        // Find two sessions placed on the same node; their observed
        // schedules must be identical (correlation, not just equal rate).
        let groups = nf.map().colocated_sessions(16);
        let (_, together) = groups
            .iter()
            .find(|(_, g)| g.len() >= 2)
            .expect("16 sessions on 2 nodes must co-locate somewhere");
        let s0 = nf.schedule_for_session(together[0]);
        let s1 = nf.schedule_for_session(together[1]);
        assert_eq!(s0, s1);
        assert_eq!(s0.len(), 3);
        assert!(s0.windows(2).all(|w| w[0] < w[1]), "cumulative offsets");
    }

    #[test]
    fn node_faults_absent_outside_node_domain() {
        assert!(FaultPlan::none().node_faults(1).is_none());
        assert!(FaultPlan::exponential(Duration::from_secs(1), 2)
            .node_faults(1)
            .is_none());
    }

    #[test]
    fn corruptor_is_deterministic_and_typed() {
        let dir = std::env::temp_dir().join(format!("ncr_corr_{}", std::process::id()));
        let bucket = dir.join("ab");
        std::fs::create_dir_all(&bucket).unwrap();
        for i in 0..6 {
            let mut bytes = b"NCRCHNK1\0".to_vec();
            bytes.extend_from_slice(&[i as u8; 32]);
            std::fs::write(bucket.join(format!("abc{i}.chunk")), bytes).unwrap();
        }
        let ev_a = StoreCorruptor::new(9).strike(&dir, 3).unwrap();
        assert_eq!(ev_a.len(), 3);
        // Replay against identical content: same victims, same kinds.
        for e in &ev_a {
            let mut bytes = b"NCRCHNK1\0".to_vec();
            let i: u8 = e
                .path
                .file_name()
                .unwrap()
                .to_str()
                .unwrap()
                .as_bytes()[3]
                - b'0';
            bytes.extend_from_slice(&[i; 32]);
            std::fs::write(&e.path, bytes).unwrap();
        }
        let ev_b = StoreCorruptor::new(9).strike(&dir, 3).unwrap();
        assert_eq!(ev_a, ev_b);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corruptor_on_empty_store_is_empty_not_error() {
        let dir = std::env::temp_dir().join(format!("ncr_corr_empty_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let ev = StoreCorruptor::new(1).strike(&dir, 4).unwrap();
        assert!(ev.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }
}
