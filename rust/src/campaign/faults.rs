//! Seeded failure injection for live campaigns.
//!
//! A campaign's efficiency question only exists because sessions die:
//! preemption by higher-priority work, node failure, walltime eviction.
//! This module turns that into a reproducible experiment — a [`FaultPlan`]
//! describes the failure process (exponential inter-kill times around an
//! MTBF, the classic renewal model behind Young/Daly), and mints one
//! deterministic [`FaultInjector`] per session from `(campaign seed,
//! session index)`, so the same spec replays the same kill schedule.
//!
//! The executor applies a kill through the session's own operator path —
//! [`crate::cr::session::CrSession::kill`] followed by
//! [`crate::cr::session::CrSession::resubmit_from_checkpoint`] — which is
//! exactly the §V.B.2 flow, bare or containerized. Kills are *deferred*
//! until the session has at least one checkpoint image: a session killed
//! before its first checkpoint has nothing to restart from (the
//! real-world analog is a job failing before `dmtcp_command --checkpoint`
//! ever ran, which simply reruns from scratch — a case the session API
//! models as a fresh submission, not a restart).

use std::time::Duration;

use crate::util::rng::SplitMix64;

/// The failure process of one campaign, applied per session.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    /// Mean time between injected kills per session (`None` = no faults).
    pub mtbf: Option<Duration>,
    /// Stop injecting after this many kills per session (bounds the
    /// incarnation count so a short straggler timeout stays meaningful).
    pub max_kills_per_session: u32,
}

impl FaultPlan {
    /// A plan that never kills anything.
    pub fn none() -> Self {
        Self {
            mtbf: None,
            max_kills_per_session: 0,
        }
    }

    /// Exponential kills around `mtbf`, at most `max_kills` per session.
    pub fn exponential(mtbf: Duration, max_kills: u32) -> Self {
        Self {
            mtbf: Some(mtbf),
            max_kills_per_session: max_kills,
        }
    }

    /// Mint the deterministic injector for one session of the campaign.
    /// Equal `(campaign_seed, session_index)` pairs yield equal kill
    /// schedules.
    pub fn injector(&self, campaign_seed: u64, session_index: u32) -> FaultInjector {
        // Decorrelate per-session streams the same way SplitMix64::fork
        // does, but keyed so the schedule survives executor reordering.
        let seed = campaign_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(0xFAu64 << 32)
            .wrapping_add(session_index as u64);
        FaultInjector {
            rng: SplitMix64::new(seed),
            mtbf: self.mtbf,
            kills_left: self.max_kills_per_session,
        }
    }
}

/// Per-session kill schedule generator (see [`FaultPlan::injector`]).
#[derive(Debug, Clone)]
pub struct FaultInjector {
    rng: SplitMix64,
    mtbf: Option<Duration>,
    kills_left: u32,
}

impl FaultInjector {
    /// Draw the delay from now until the next injected kill, consuming
    /// one kill from the budget. `None` once the plan is exhausted (or
    /// was fault-free to begin with).
    pub fn next_kill_in(&mut self) -> Option<Duration> {
        let mtbf = self.mtbf?;
        if self.kills_left == 0 {
            return None;
        }
        self.kills_left -= 1;
        let secs = self.rng.gen_exp(mtbf.as_secs_f64());
        Some(Duration::from_secs_f64(secs))
    }

    /// Kills still available in this session's budget.
    pub fn kills_left(&self) -> u32 {
        self.kills_left
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_plan_never_kills() {
        let mut inj = FaultPlan::none().injector(7, 0);
        assert_eq!(inj.next_kill_in(), None);
    }

    #[test]
    fn injector_is_deterministic_per_seed_and_index() {
        let plan = FaultPlan::exponential(Duration::from_millis(100), 4);
        let mut a = plan.injector(42, 3);
        let mut b = plan.injector(42, 3);
        for _ in 0..4 {
            assert_eq!(a.next_kill_in(), b.next_kill_in());
        }
        assert_eq!(a.next_kill_in(), None, "budget of 4 exhausted");
    }

    #[test]
    fn sessions_get_distinct_schedules() {
        let plan = FaultPlan::exponential(Duration::from_millis(100), 1);
        let mut a = plan.injector(42, 0);
        let mut b = plan.injector(42, 1);
        assert_ne!(a.next_kill_in(), b.next_kill_in());
    }

    #[test]
    fn draws_cluster_around_mtbf() {
        let plan = FaultPlan::exponential(Duration::from_secs(10), u32::MAX);
        let mut inj = plan.injector(9, 0);
        let n = 4_000;
        let mean: f64 = (0..n)
            .map(|_| inj.next_kill_in().unwrap().as_secs_f64())
            .sum::<f64>()
            / n as f64;
        assert!((mean - 10.0).abs() < 0.6, "mean={mean}");
    }
}
